"""Kernel vs reference oracles — the CORE correctness signal (L1).

Random-case sweeps over shapes (hypothesis-style: many seeded cases with
growing sizes; the `hypothesis` package is not in the image, so the sweep
is explicit and exhaustive over a shape grid × seeds).
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import attention, compress, ref

RTOL = 1e-5
ATOL = 1e-5


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def lens_mask(rng, b, c):
    lens = rng.integers(1, c + 1, size=(b,))
    valid = np.arange(c)[None, :] < lens[:, None]
    add = np.where(valid, 0.0, ref.NEG_INF).astype(np.float32)
    return jnp.asarray(add), jnp.asarray(valid.astype(np.float32)), lens


DECODE_SHAPES = [
    (1, 1, 4, 4),
    (2, 2, 8, 8),
    (3, 4, 16, 8),
    (4, 2, 48, 32),
    (2, 8, 33, 16),  # non-power-of-two cache
]


@pytest.mark.parametrize("b,h,c,d", DECODE_SHAPES)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_decode_attention_matches_ref(b, h, c, d, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, b, h, d)
    k = rand(rng, b, h, c, d)
    v = rand(rng, b, h, c, d)
    mask, _, _ = lens_mask(rng, b, c)
    o1, p1 = attention.decode_attention(q, k, v, mask)
    o2, p2 = ref.decode_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(o1, o2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(p1, p2, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("b,h,c,d", DECODE_SHAPES[:3])
def test_decode_probs_are_distribution(b, h, c, d):
    rng = np.random.default_rng(7)
    q = rand(rng, b, h, d)
    k = rand(rng, b, h, c, d)
    v = rand(rng, b, h, c, d)
    mask, valid, _ = lens_mask(rng, b, c)
    _, p = attention.decode_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(p).sum(-1), 1.0, rtol=1e-5)
    # no probability mass on invalid slots
    dead = np.asarray(p) * (1.0 - np.asarray(valid))[:, None, :]
    assert np.abs(dead).max() < 1e-6


PREFILL_SHAPES = [
    (1, 1, 4, 4),
    (2, 2, 12, 8),
    (2, 4, 48, 16),
    (3, 2, 30, 8),
]


@pytest.mark.parametrize("b,h,t,d", PREFILL_SHAPES)
@pytest.mark.parametrize("seed", [0, 3])
def test_prefill_attention_matches_ref(b, h, t, d, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (rand(rng, b, h, t, d) for _ in range(3))
    km, qm, _ = lens_mask(rng, b, t)
    o1, c1 = attention.prefill_attention(q, k, v, qm, km)
    o2, c2 = ref.prefill_attention_ref(q, k, v, qm, km)
    np.testing.assert_allclose(o1, o2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(c1, c2, rtol=RTOL, atol=ATOL)


def test_prefill_colsum_conserves_query_mass():
    # Σ_slots colsum = number of valid queries (each row sums to 1)
    rng = np.random.default_rng(11)
    b, h, t, d = 3, 2, 20, 8
    q, k, v = (rand(rng, b, h, t, d) for _ in range(3))
    km, qm, lens = lens_mask(rng, b, t)
    _, colsum = attention.prefill_attention(q, k, v, qm, km)
    total = np.asarray(colsum).sum(-1)  # [b, h]
    np.testing.assert_allclose(total, np.broadcast_to(lens[:, None], total.shape), rtol=1e-4)


@pytest.mark.parametrize("wrt", [0, 1, 2])
def test_prefill_vjp_matches_ref_grad(wrt):
    rng = np.random.default_rng(5)
    b, h, t, d = 2, 2, 10, 8
    args = [rand(rng, b, h, t, d) for _ in range(3)]
    km, qm, _ = lens_mask(rng, b, t)

    def f_pallas(x):
        a = list(args)
        a[wrt] = x
        out, _ = attention.prefill_attention(*a, qm, km)
        return jnp.sum(out * out)

    def f_ref(x):
        a = list(args)
        a[wrt] = x
        out, _ = ref.prefill_attention_ref(*a, qm, km)
        return jnp.sum(out * out)

    g1 = jax.grad(f_pallas)(args[wrt])
    g2 = jax.grad(f_ref)(args[wrt])
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


RKV_SHAPES = [(1, 4, 4), (4, 16, 8), (6, 48, 32), (2, 33, 16)]


@pytest.mark.parametrize("g,c,d", RKV_SHAPES)
@pytest.mark.parametrize("lam", [0.0, 0.1, 0.9])
def test_rkv_scores_match_ref(g, c, d, lam):
    rng = np.random.default_rng(13)
    keys = rand(rng, g, c, d)
    imp = jnp.asarray(rng.uniform(size=(g, c)), jnp.float32)
    _, valid, _ = lens_mask(rng, g, c)
    s1 = compress.rkv_scores(keys, imp, valid, lam)
    s2 = ref.rkv_scores_ref(keys, imp, valid, lam)
    np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-5)


def test_rkv_prefers_low_redundancy():
    # two identical keys (a redundancy cluster) + two distinct keys:
    # with lam = 0 (pure redundancy), the clones must score lowest
    g, c, d = 1, 4, 8
    rng = np.random.default_rng(17)
    base = rng.normal(size=(d,))
    keys = np.stack([base, base, rng.normal(size=(d,)), rng.normal(size=(d,))])
    keys = jnp.asarray(keys[None, :, :], jnp.float32)
    imp = jnp.ones((g, c), jnp.float32)
    valid = jnp.ones((g, c), jnp.float32)
    s = np.asarray(compress.rkv_scores(keys, imp, valid, 0.0))[0]
    assert max(s[0], s[1]) < min(s[2], s[3]), f"clone scores {s}"


def test_redundancy_zero_for_single_valid_slot():
    rng = np.random.default_rng(19)
    keys = rand(rng, 2, 6, 4)
    valid = jnp.asarray([[1, 0, 0, 0, 0, 0], [1, 1, 0, 0, 0, 0]], jnp.float32)
    red = ref.redundancy_scores_ref(keys, valid)
    assert float(jnp.abs(red[0]).max()) == 0.0


def test_minmax_normalize_range():
    rng = np.random.default_rng(23)
    x = jnp.asarray(rng.normal(size=(3, 10)), jnp.float32)
    _, valid, _ = lens_mask(rng, 3, 10)
    n = np.asarray(ref.minmax_normalize_ref(x, valid))
    assert n.min() >= 0.0 and n.max() <= 1.0
    dead = n * (1.0 - np.asarray(valid))
    assert np.abs(dead).max() == 0.0


class TestSelectTopk:
    def setup_method(self):
        rng = np.random.default_rng(29)
        self.g, self.c = 5, 24
        self.score = jnp.asarray(rng.normal(size=(self.g, self.c)), jnp.float32)
        lens = rng.integers(10, self.c + 1, size=(self.g,))
        occ = np.arange(self.c)[None, :] < lens[:, None]
        self.valid = jnp.asarray(occ.astype(np.float32))
        self.birth = jnp.asarray(np.where(occ, np.arange(self.c)[None, :], -1), jnp.int32)
        self.score = jnp.where(self.valid > 0, self.score, ref.NEG_INF)

    def test_budget_slots_survive(self):
        idx, keep = compress.select_topk(self.score, self.birth, self.valid, 8, 2)
        assert idx.shape == (self.g, 8)
        np.testing.assert_array_equal(np.asarray(keep).sum(-1), 8)

    def test_only_valid_slots_selected(self):
        idx, _ = compress.select_topk(self.score, self.birth, self.valid, 8, 2)
        sel_valid = np.take_along_axis(np.asarray(self.valid), np.asarray(idx), axis=1)
        assert sel_valid.min() == 1.0

    def test_alpha_most_recent_retained(self):
        alpha = 3
        idx, _ = compress.select_topk(self.score, self.birth, self.valid, 8, alpha)
        birth = np.asarray(self.birth)
        for gi in range(self.g):
            occupied = birth[gi][birth[gi] >= 0]
            recent = set(np.sort(occupied)[-alpha:])
            kept_births = set(birth[gi][np.asarray(idx)[gi]])
            assert recent <= kept_births, f"group {gi}: {recent} not in {kept_births}"

    def test_order_preserved(self):
        idx, _ = compress.select_topk(self.score, self.birth, self.valid, 8, 2)
        b_at = np.take_along_axis(np.asarray(self.birth), np.asarray(idx), axis=1)
        assert (np.diff(b_at, axis=1) > 0).all(), "compacted order not by birth"

    def test_highest_scores_win(self):
        # with alpha=0-like tiny alpha, top scores dominate selection
        idx, keep = compress.select_topk(self.score, self.birth, self.valid, 8, 1)
        score = np.asarray(self.score)
        keep = np.asarray(keep)
        for gi in range(self.g):
            kept_scores = score[gi][keep[gi] > 0]
            dropped = score[gi][(keep[gi] == 0) & (np.asarray(self.valid)[gi] > 0)]
            if len(dropped) == 0:
                continue
            # all but the forced-keep slot must beat every dropped slot
            assert np.sort(kept_scores)[1:].min() >= dropped.max() - 1e-6


def test_streaming_scores_sinks_and_recency():
    birth = jnp.asarray([[0, 1, 2, 3, 4, 5, -1, -1]], jnp.int32)
    valid = (birth >= 0).astype(jnp.float32)
    s = np.asarray(compress.streaming_scores(birth, valid, 2))[0]
    # sinks (birth 0, 1) dominate
    assert s[0] > s[5] and s[1] > s[5]
    # recency is monotone among non-sinks
    assert s[5] > s[4] > s[3] > s[2]
    # invalid slots are NEG_INF
    assert s[6] == ref.NEG_INF and s[7] == ref.NEG_INF
