"""L2 model invariants: decode/teacher-forcing equivalence, compression
semantics, training-step correctness (the in-graph half of the three-policy
consistency the Rust integration tests check end-to-end)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import ModelConfig, RolloutShapes

CFG = ModelConfig("t", d_model=32, n_layers=2, n_heads=2, max_seq=32, prompt_len=8)


@pytest.fixture(scope="module")
def params():
    flat = model.init_params(CFG, jnp.int32(0))
    return flat, model.ParamLayout(CFG).unflatten(flat)


def mk_ids(seed, b, t, lo=3, hi=26):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi, size=(b, t)), jnp.int32)


class TestParamLayout:
    def test_layout_tiles_flat_vector(self):
        layout = model.ParamLayout(CFG)
        off = 0
        for e in layout.entries:
            assert e.offset == off
            off += e.size
        assert off == layout.total

    def test_init_deterministic(self):
        a = model.init_params(CFG, jnp.int32(3))
        b = model.init_params(CFG, jnp.int32(3))
        c = model.init_params(CFG, jnp.int32(4))
        np.testing.assert_array_equal(a, b)
        assert np.abs(np.asarray(a) - np.asarray(c)).max() > 0

    def test_ln_scales_are_ones(self):
        flat = model.init_params(CFG, jnp.int32(0))
        p = model.ParamLayout(CFG).unflatten(flat)
        np.testing.assert_array_equal(p["l0.ln1"], 1.0)
        np.testing.assert_array_equal(p["ln_f"], 1.0)


class TestDecodeEquivalence:
    """Dense decode must reproduce teacher forcing exactly (per token)."""

    def run_decode(self, p, ids, plen, capacity):
        B, T = ids.shape
        plens = jnp.full((B,), plen, jnp.int32)
        kv, sc, sw, birth, logp_last = model.prefill(CFG, p, ids[:, :plen], plens, capacity)
        cur = plens
        logps = [logp_last]
        for t in range(plen, T - 1):
            lp, kv, sc, sw, birth = model.decode_step(
                CFG, p, kv, sc, sw, birth, cur, jnp.full((B,), t, jnp.int32), ids[:, t]
            )
            cur = cur + 1
            logps.append(lp)
        return logps

    def test_matches_teacher_forcing(self, params):
        _, p = params
        B, P, T = 2, 8, 24
        ids = mk_ids(1, B, T)
        lens = jnp.full((B,), T, jnp.int32)
        logp_tf = jax.nn.log_softmax(model.forward_full(CFG, p, ids, lens), -1)
        logps = self.run_decode(p, ids, P, capacity=T)
        for i, lp in enumerate(logps):
            t = P - 1 + i  # prediction of token t+1 from context ≤ t
            np.testing.assert_allclose(lp, logp_tf[:, t, :], rtol=1e-4, atol=2e-5)

    def test_token_logprobs_consistent_with_forward(self, params):
        _, p = params
        ids = mk_ids(2, 3, 20)
        lens = jnp.asarray([20, 14, 9], jnp.int32)
        logp, ent = model.token_logprobs(CFG, p, ids, lens)
        full = jax.nn.log_softmax(model.forward_full(CFG, p, ids, lens), -1)
        for b in range(3):
            for t in range(1, int(lens[b])):
                want = full[b, t - 1, ids[b, t]]
                np.testing.assert_allclose(logp[b, t], want, rtol=1e-5, atol=1e-6)
        # entropies positive at valid positions
        assert float(ent[:, 1:].min()) >= 0.0
        # position 0 is padding by construction
        np.testing.assert_array_equal(logp[:, 0], 0.0)


class TestFusedSlotPrefill:
    """The fused slot-masked prefill must equal a batched prefill on the
    masked row (the contract Rust's fused `prefill_slot` relies on) and
    preserve every unmasked slot's planes bit-for-bit."""

    def test_masked_slot_matches_batched_row_others_untouched(self, params):
        _, p = params
        B, P, C = 3, 8, 16
        live_ids = mk_ids(7, B, P)
        live_lens = jnp.asarray([8, 5, 7], jnp.int32)
        kv, sc, sw, birth, _ = model.prefill(CFG, p, live_ids, live_lens, C)

        # new prompt for slot 1; scratch rows elsewhere (content must not
        # matter — batch rows are independent)
        new_ids = mk_ids(9, B, P)
        new_lens = jnp.asarray([1, 6, 1], jnp.int32)
        mask = jnp.asarray([0.0, 1.0, 0.0], jnp.float32)
        kv2, sc2, sw2, b2, logp = model.prefill_slot(
            CFG, p, kv, sc, sw, birth, new_ids, new_lens, mask, capacity=C
        )

        # reference: a plain batched prefill of the same scratch batch
        fkv, fsc, fsw, fb, flogp = model.prefill(CFG, p, new_ids, new_lens, C)
        np.testing.assert_array_equal(kv2[:, :, 1], fkv[:, :, 1])
        np.testing.assert_array_equal(sc2[:, 1], fsc[:, 1])
        np.testing.assert_array_equal(sw2[:, 1], fsw[:, 1])
        np.testing.assert_array_equal(b2[:, 1], fb[:, 1])
        np.testing.assert_array_equal(logp[1], flogp[1])

        # unmasked slots keep their live planes bit-for-bit
        for s in (0, 2):
            np.testing.assert_array_equal(kv2[:, :, s], kv[:, :, s])
            np.testing.assert_array_equal(sc2[:, s], sc[:, s])
            np.testing.assert_array_equal(sw2[:, s], sw[:, s])
            np.testing.assert_array_equal(b2[:, s], birth[:, s])

    def test_all_zero_mask_is_identity(self, params):
        _, p = params
        B, P, C = 2, 8, 12
        ids = mk_ids(11, B, P)
        lens = jnp.full((B,), P, jnp.int32)
        kv, sc, sw, birth, _ = model.prefill(CFG, p, ids, lens, C)
        kv2, sc2, sw2, b2, _ = model.prefill_slot(
            CFG, p, kv, sc, sw, birth, ids, lens, jnp.zeros((B,), jnp.float32),
            capacity=C
        )
        np.testing.assert_array_equal(kv2, kv)
        np.testing.assert_array_equal(sc2, sc)
        np.testing.assert_array_equal(sw2, sw)
        np.testing.assert_array_equal(b2, birth)


class TestCompression:
    def setup_cache(self, p, capacity=16, plen=8, extra=6):
        B = 2
        ids = mk_ids(5, B, plen + extra)
        plens = jnp.full((B,), plen, jnp.int32)
        kv, sc, sw, birth, _ = model.prefill(CFG, p, ids[:, :plen], plens, capacity)
        cur = plens
        for t in range(plen, plen + extra):
            _, kv, sc, sw, birth = model.decode_step(
                CFG, p, kv, sc, sw, birth, cur, jnp.full((B,), t, jnp.int32), ids[:, t]
            )
            cur = cur + 1
        return kv, sc, sw, birth, cur

    @pytest.mark.parametrize("method", ["rkv", "snapkv", "h2o", "streaming"])
    def test_budget_and_validity(self, params, method):
        _, p = params
        kv, sc, sw, birth, _ = self.setup_cache(p)
        shapes = RolloutShapes(budget=8, buffer=8, alpha=2)
        kv2, sc2, sw2, b2 = model.compress_step(
            kv, sc, sw, birth, jnp.asarray([1.0, 1.0]), method, shapes
        )
        occ = np.asarray(b2 >= 0)
        # exactly budget slots live, all in the first `budget` positions
        assert occ.sum(-1).min() == 8 and occ.sum(-1).max() == 8
        assert not occ[..., 8:].any()
        # stats_win reset, evicted kv zeroed
        np.testing.assert_array_equal(np.asarray(sw2), 0.0)
        kv2 = np.asarray(kv2)
        assert np.abs(kv2[:, :, :, :, 8:, :]).max() == 0.0

    def test_do_mask_passthrough(self, params):
        _, p = params
        kv, sc, sw, birth, _ = self.setup_cache(p)
        shapes = RolloutShapes(budget=8, buffer=8, alpha=2)
        kv2, sc2, sw2, b2 = model.compress_step(
            kv, sc, sw, birth, jnp.asarray([1.0, 0.0]), "rkv", shapes
        )
        # sequence 1 untouched
        np.testing.assert_array_equal(np.asarray(kv2)[:, :, 1], np.asarray(kv)[:, :, 1])
        np.testing.assert_array_equal(np.asarray(b2)[:, 1], np.asarray(birth)[:, 1])
        # sequence 0 compacted
        assert (np.asarray(b2)[:, 0] >= 0).sum(-1).max() == 8

    def test_alpha_recency_survives(self, params):
        _, p = params
        kv, sc, sw, birth, cur = self.setup_cache(p)
        shapes = RolloutShapes(budget=8, buffer=8, alpha=3)
        _, _, _, b2 = model.compress_step(
            kv, sc, sw, birth, jnp.asarray([1.0, 1.0]), "rkv", shapes
        )
        birth_np = np.asarray(birth)
        b2_np = np.asarray(b2)
        L, B, H, C = birth_np.shape
        for l in range(L):
            for b in range(B):
                for h in range(H):
                    occupied = birth_np[l, b, h][birth_np[l, b, h] >= 0]
                    recent = set(np.sort(occupied)[-3:].tolist())
                    kept = set(b2_np[l, b, h][b2_np[l, b, h] >= 0].tolist())
                    assert recent <= kept

    def test_compressed_decode_still_runs(self, params):
        _, p = params
        kv, sc, sw, birth, cur = self.setup_cache(p)
        shapes = RolloutShapes(budget=8, buffer=8, alpha=2)
        kv, sc, sw, birth = model.compress_step(
            kv, sc, sw, birth, jnp.asarray([1.0, 1.0]), "h2o", shapes
        )
        lens = jnp.asarray([8, 8], jnp.int32)
        pos = cur  # absolute positions keep advancing
        lp, *_ = model.decode_step(
            CFG, p, kv, sc, sw, birth, lens, pos, jnp.asarray([5, 6], jnp.int32)
        )
        assert np.isfinite(np.asarray(lp)).all()
        np.testing.assert_allclose(np.exp(np.asarray(lp)).sum(-1), 1.0, rtol=1e-5)


class TestTrainStep:
    def batch(self, seed=9, B=2, T=24, P=8):
        ids = mk_ids(seed, B, T)
        lens = jnp.asarray([T, T - 5], jnp.int32)
        mask = (
            (jnp.arange(T)[None, :] >= P) & (jnp.arange(T)[None, :] < lens[:, None])
        ).astype(jnp.float32)
        return ids, lens, mask

    def test_positive_advantage_raises_logp(self, params):
        flat, p = params
        ids, lens, mask = self.batch()
        logp_old, _ = model.token_logprobs(CFG, p, ids, lens)
        m0 = jnp.zeros_like(flat)
        hyp = jnp.asarray([1e-2, 0.2, 0.0, 1e9], jnp.float32)  # big lr, no KL
        adv = jnp.asarray([1.0, 1.0])
        out = model.train_step(
            CFG, flat, m0, m0, jnp.int32(0), ids, mask, lens, adv,
            jnp.ones_like(mask), jnp.ones((2,)), logp_old, hyp,
        )
        new_flat = out[0]
        p2 = model.ParamLayout(CFG).unflatten(new_flat)
        logp_new, _ = model.token_logprobs(CFG, p2, ids, lens)
        masked_delta = float(((logp_new - logp_old) * mask).sum())
        assert masked_delta > 0, f"positive advantage decreased logp ({masked_delta})"

    def test_rejected_rows_have_no_gradient(self, params):
        flat, p = params
        ids, lens, mask = self.batch()
        logp_old, _ = model.token_logprobs(CFG, p, ids, lens)
        m0 = jnp.zeros_like(flat)
        hyp = jnp.asarray([1e-3, 0.2, 0.0, 1e9], jnp.float32)
        out = model.train_step(
            CFG, flat, m0, m0, jnp.int32(0), ids, mask, lens,
            jnp.asarray([1.0, -1.0]), jnp.ones_like(mask), jnp.zeros((2,)),
            logp_old, hyp,
        )
        gnorm = float(out[5])
        assert gnorm < 1e-5, f"all-rejected batch produced grad norm {gnorm}"

    def test_xi_scales_gradient(self, params):
        flat, p = params
        ids, lens, mask = self.batch()
        logp_old, _ = model.token_logprobs(CFG, p, ids, lens)
        m0 = jnp.zeros_like(flat)
        hyp = jnp.asarray([1e-3, 10.0, 0.0, 1e9], jnp.float32)  # wide clip
        adv = jnp.asarray([1.0, -1.0])
        mrs = jnp.ones((2,))

        def gnorm_with_xi(scale):
            out = model.train_step(
                CFG, flat, m0, m0, jnp.int32(0), ids, mask, lens, adv,
                jnp.ones_like(mask) * scale, mrs, logp_old, hyp,
            )
            return float(out[5])

        g1 = gnorm_with_xi(1.0)
        g2 = gnorm_with_xi(2.0)
        np.testing.assert_allclose(g2, 2.0 * g1, rtol=1e-3)

    def test_clip_frac_responds_to_stale_policy(self, params):
        flat, p = params
        ids, lens, mask = self.batch()
        logp_old, _ = model.token_logprobs(CFG, p, ids, lens)
        # fake a very stale old policy -> ratios far from 1 -> clipping
        stale = logp_old - 1.0
        m0 = jnp.zeros_like(flat)
        hyp = jnp.asarray([1e-3, 0.2, 0.0, 1e9], jnp.float32)
        out = model.train_step(
            CFG, flat, m0, m0, jnp.int32(0), ids, mask, lens,
            jnp.asarray([1.0, 1.0]), jnp.ones_like(mask), jnp.ones((2,)),
            stale, hyp,
        )
        clip_frac = float(out[6])
        assert clip_frac > 0.5, f"expected heavy clipping, got {clip_frac}"

    def test_adam_state_advances(self, params):
        flat, _ = params
        ids, lens, mask = self.batch()
        m0 = jnp.zeros_like(flat)
        hyp = jnp.asarray([1e-3, 0.2, 1e-4, 1.0], jnp.float32)
        logp_old = jnp.zeros_like(mask)
        out = model.train_step(
            CFG, flat, m0, m0, jnp.int32(5), ids, mask, lens,
            jnp.asarray([1.0, 0.0]), jnp.ones_like(mask), jnp.ones((2,)),
            logp_old, hyp,
        )
        assert int(out[3]) == 6
        assert float(jnp.abs(out[1]).max()) > 0  # m updated


class TestLmStep:
    def test_loss_decreases(self, params):
        flat, _ = params
        ids = mk_ids(11, 2, 24)
        lens = jnp.full((2,), 24, jnp.int32)
        mask = jnp.ones((2, 24), jnp.float32).at[:, 0].set(0.0)
        hyp = jnp.asarray([5e-3, 0.2, 0.0, 1.0], jnp.float32)
        m = jnp.zeros_like(flat)
        v = jnp.zeros_like(flat)
        theta, step = flat, jnp.int32(0)
        losses = []
        for _ in range(8):
            theta, m, v, step, loss = model.lm_step(CFG, theta, m, v, step, ids, mask, lens, hyp)
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.2, losses

    def test_initial_loss_near_uniform(self, params):
        flat, _ = params
        ids = mk_ids(13, 2, 24)
        lens = jnp.full((2,), 24, jnp.int32)
        mask = jnp.ones((2, 24), jnp.float32).at[:, 0].set(0.0)
        hyp = jnp.asarray([0.0, 0.2, 0.0, 1.0], jnp.float32)
        m = jnp.zeros_like(flat)
        _, _, _, _, loss = model.lm_step(CFG, flat, m, m, jnp.int32(0), ids, mask, lens, hyp)
        assert abs(float(loss) - np.log(CFG.vocab)) < 0.3


class TestAdam:
    def test_matches_reference_formula(self):
        n = 16
        rng = np.random.default_rng(0)
        theta = jnp.asarray(rng.normal(size=n), jnp.float32)
        g = jnp.asarray(rng.normal(size=n), jnp.float32) * 0.01
        m = jnp.zeros(n, jnp.float32)
        v = jnp.zeros(n, jnp.float32)
        new, m1, v1, step1, gnorm = model.adam_update(
            theta, g, m, v, jnp.int32(0), 1e-3, max_grad_norm=1e9
        )
        # closed form at t=1: mhat = g, vhat = g^2 -> update ≈ lr * sign(g)
        expect = theta - 1e-3 * g / (jnp.abs(g) + 1e-8)
        np.testing.assert_allclose(new, expect, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(gnorm, jnp.sqrt((g * g).sum()), rtol=1e-5)

    def test_grad_clipping(self):
        theta = jnp.zeros(4, jnp.float32)
        g = jnp.asarray([3.0, 4.0, 0.0, 0.0], jnp.float32)  # norm 5
        m = jnp.zeros(4, jnp.float32)
        new, m1, _, _, gnorm = model.adam_update(
            theta, g, m, m, jnp.int32(0), 1.0, max_grad_norm=1.0
        )
        np.testing.assert_allclose(gnorm, 5.0, rtol=1e-6)
        # post-clip first moment reflects the scaled gradient
        np.testing.assert_allclose(m1, 0.1 * g / 5.0, rtol=1e-5)
