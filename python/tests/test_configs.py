"""Preset / shape-constant sanity (the contract the Rust side's manifest
consumers depend on)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import pytest

from compile.configs import PRESETS, ModelConfig, RolloutShapes


def test_presets_cover_paper_scales():
    assert set(PRESETS) == {"nano", "tiny", "small", "base", "e2e"}
    # monotone capacity ordering mirrors the paper's 1B < 1.5B < 3B < 7B
    order = ["nano", "tiny", "small", "base"]
    dims = [PRESETS[n].d_model for n in order]
    assert dims == sorted(dims) and len(set(dims)) == 4


@pytest.mark.parametrize("name", sorted(PRESETS))
def test_preset_invariants(name):
    c = PRESETS[name]
    assert c.d_model % c.n_heads == 0
    assert c.d_ff % 16 == 0 and c.d_ff > c.d_model
    assert c.prompt_len < c.max_seq
    assert c.vocab == 32


def test_sparse_capacity_accounts_budget_and_buffer():
    s = RolloutShapes(budget=32, buffer=16)
    assert s.sparse_capacity == 48
    s2 = RolloutShapes(budget=16, buffer=32)
    assert s2.sparse_capacity == 48  # fig4 low-budget points keep capacity


def test_default_ratio_matches_paper():
    # paper: budget 512 of ctx 4096 = 12.5%; ours: 32 (budget) of 256
    # effective window ≈ same order — assert the documented default
    s = RolloutShapes()
    c = ModelConfig("x")
    assert abs(s.budget / c.max_seq - 512 / 4096) < 0.05
