"""AOT pipeline sanity: manifests are complete and HLO text is loadable
(the parser-compatibility gotchas that bit during bring-up become tests)."""

import json
import os
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

ROOT = Path(__file__).resolve().parents[2]
ART = ROOT / "artifacts" / "nano"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(),
    reason="artifacts/nano not built (run `make artifacts`)",
)

EXPECTED_ENTRIES = {
    "init_params",
    "prefill_dense",
    "prefill_sparse",
    "prefill_slot_dense",
    "prefill_slot_sparse",
    "decode_dense",
    "decode_sparse",
    "compress_rkv",
    "compress_snapkv",
    "compress_h2o",
    "compress_streaming",
    "score",
    "train",
    "lm",
}


@pytest.fixture(scope="module")
def manifest():
    with open(ART / "manifest.json") as f:
        return json.load(f)


def test_all_entries_present(manifest):
    assert set(manifest["entries"]) == EXPECTED_ENTRIES


def test_artifact_files_exist(manifest):
    for e in manifest["entries"].values():
        assert (ART / e["file"]).exists(), e["file"]


def test_param_layout_covers_flat_vector(manifest):
    off = 0
    for p in manifest["params"]:
        assert p["offset"] == off
        size = 1
        for d in p["shape"]:
            size *= d
        assert size == p["size"]
        off += p["size"]
    assert off == manifest["config"]["n_params"]


def test_shapes_consistent(manifest):
    s = manifest["shapes"]
    c = manifest["config"]
    assert s["sparse_capacity"] == s["budget"] + s["buffer"]
    assert s["dense_capacity"] == c["max_seq"]
    assert c["d_head"] * c["n_heads"] == c["d_model"]
    # decode io shapes match the manifest dims
    dec = manifest["entries"]["decode_sparse"]
    kv = next(t for t in dec["inputs"] if t["name"] == "kv")
    assert kv["dims"] == [
        c["n_layers"], 2, s["decode_batch"], c["n_heads"],
        s["sparse_capacity"], c["d_head"],
    ]


def test_signature_symmetry(manifest):
    # decode outputs (minus logp) mirror the cache inputs — the Rust engine
    # relies on this to thread literals through
    for variant in ("dense", "sparse"):
        dec = manifest["entries"][f"decode_{variant}"]
        in_cache = {t["name"]: t for t in dec["inputs"] if t["name"] in
                    ("kv", "stats_cum", "stats_win", "birth")}
        out_cache = {t["name"]: t for t in dec["outputs"] if t["name"] in in_cache}
        assert set(in_cache) == set(out_cache)
        for name in in_cache:
            assert in_cache[name]["dims"] == out_cache[name]["dims"], name
            assert in_cache[name]["dtype"] == out_cache[name]["dtype"], name


def test_no_topk_instruction_in_hlo(manifest):
    """xla_extension 0.5.1's HLO text parser rejects the `topk` op
    (jax.lax.top_k lowers to it). The compress artifacts must use sort."""
    for name, e in manifest["entries"].items():
        text = (ART / e["file"]).read_text()
        for line in text.splitlines():
            stripped = line.strip()
            assert not stripped.startswith("topk") and " topk(" not in stripped, (
                f"{name} contains a topk instruction (0.5.1-incompatible)"
            )


def test_hlo_text_starts_with_module(manifest):
    for e in manifest["entries"].values():
        head = (ART / e["file"]).read_text()[:200]
        assert head.startswith("HloModule"), e["file"]
