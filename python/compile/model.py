"""L2: the JAX model — transformer LM forward/backward + RL training step.

Every function here is an AOT entry point (lowered to HLO text by aot.py)
or a building block of one. The rollout-path functions (prefill, decode,
compress) call the L1 Pallas kernels so the kernels lower into the same
HLO artifact the Rust coordinator executes.

Parameter handling: all weights live in ONE flat f32 vector. The layout is
computed deterministically from the ModelConfig (see `ParamLayout`) and
recorded in the artifact manifest, so the Rust side moves exactly one
buffer per call and never needs to know tensor names.

Policy triangle implemented here (paper §3):
  * π_sparse — `decode` over the compressed cache (sampler),
  * π_old    — `score_tokens` dense teacher forcing with θ_old (scorer),
  * π_θ      — `train_step` recomputes log-probs with the learner weights.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig, RolloutShapes
from .kernels import attention, compress
from .kernels.ref import NEG_INF

# ---------------------------------------------------------------------------
# parameter layout
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamEntry:
    name: str
    shape: Tuple[int, ...]
    offset: int

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


class ParamLayout:
    """Deterministic flat layout of all model weights.

    Order: tok_emb, pos_emb, per-layer (ln1, wq, wk, wv, wo, ln2, w1, w3,
    w2), final ln. The output projection is tied to tok_emb.
    """

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.max_seq
        entries: List[ParamEntry] = []
        off = 0

        def add(name, shape):
            nonlocal off
            e = ParamEntry(name, tuple(shape), off)
            entries.append(e)
            off += e.size

        add("tok_emb", (v, d))
        add("pos_emb", (s, d))
        for i in range(cfg.n_layers):
            add(f"l{i}.ln1", (d,))
            add(f"l{i}.wq", (d, d))
            add(f"l{i}.wk", (d, d))
            add(f"l{i}.wv", (d, d))
            add(f"l{i}.wo", (d, d))
            add(f"l{i}.ln2", (d,))
            add(f"l{i}.w1", (d, f))
            add(f"l{i}.w3", (d, f))
            add(f"l{i}.w2", (f, d))
        add("ln_f", (d,))
        self.entries = entries
        self.total = off
        self._by_name = {e.name: e for e in entries}

    def slice(self, flat: jnp.ndarray, name: str) -> jnp.ndarray:
        e = self._by_name[name]
        return jax.lax.dynamic_slice(flat, (e.offset,), (e.size,)).reshape(e.shape)

    def unflatten(self, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        return {e.name: self.slice(flat, e.name) for e in self.entries}

    def manifest(self) -> list:
        return [
            {"name": e.name, "shape": list(e.shape), "offset": e.offset, "size": e.size}
            for e in self.entries
        ]


def init_params(cfg: ModelConfig, seed: jnp.ndarray) -> jnp.ndarray:
    """Deterministic init from an i32 seed: N(0, 0.02), residual-output
    projections (wo, w2) scaled by 1/sqrt(2 * n_layers), ln scales = 1."""
    layout = ParamLayout(cfg)
    key = jax.random.PRNGKey(seed)
    parts = []
    resid_scale = 1.0 / jnp.sqrt(2.0 * cfg.n_layers)
    for i, e in enumerate(layout.entries):
        k = jax.random.fold_in(key, i)
        if e.name.endswith("ln1") or e.name.endswith("ln2") or e.name == "ln_f":
            parts.append(jnp.ones((e.size,), jnp.float32))
        else:
            w = jax.random.normal(k, (e.size,), jnp.float32) * 0.02
            if e.name.endswith(".wo") or e.name.endswith(".w2"):
                w = w * resid_scale
            parts.append(w)
    return jnp.concatenate(parts)


# ---------------------------------------------------------------------------
# shared blocks
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps=1e-6):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * scale


def swiglu(x, w1, w3, w2):
    return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2


def _split_heads(x, n_heads):
    # [..., D] -> [B, H, ..., Dh]; works for [B, D] and [B, T, D]
    *lead, d = x.shape
    dh = d // n_heads
    x = x.reshape(*lead, n_heads, dh)
    if len(lead) == 1:  # [B, H, Dh]
        return x
    return x.transpose(0, 2, 1, 3)  # [B, H, T, Dh]


def _merge_heads(x):
    if x.ndim == 3:  # [B, H, Dh]
        b, h, dh = x.shape
        return x.reshape(b, h * dh)
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


# ---------------------------------------------------------------------------
# full-sequence forward (training / scoring path)
# ---------------------------------------------------------------------------


def forward_full(cfg: ModelConfig, p: Dict[str, jnp.ndarray], ids, lens):
    """Causal forward over a padded batch.

    Args:
      ids:  [B, T] int32 token ids (right-padded).
      lens: [B]    int32 valid lengths.

    Returns:
      logits: [B, T, V]
    """
    B, T = ids.shape
    pos = jnp.arange(T, dtype=jnp.int32)
    x = p["tok_emb"][ids] + p["pos_emb"][pos][None, :, :]
    qmask = (pos[None, :] < lens[:, None]).astype(jnp.float32)
    kmask = jnp.where(qmask > 0, 0.0, NEG_INF).astype(jnp.float32)
    for i in range(cfg.n_layers):
        h = rms_norm(x, p[f"l{i}.ln1"])
        q = _split_heads(h @ p[f"l{i}.wq"], cfg.n_heads)
        k = _split_heads(h @ p[f"l{i}.wk"], cfg.n_heads)
        v = _split_heads(h @ p[f"l{i}.wv"], cfg.n_heads)
        att, _ = attention.prefill_attention(q, k, v, qmask, kmask)
        x = x + _merge_heads(att) @ p[f"l{i}.wo"]
        h2 = rms_norm(x, p[f"l{i}.ln2"])
        x = x + swiglu(h2, p[f"l{i}.w1"], p[f"l{i}.w3"], p[f"l{i}.w2"])
    x = rms_norm(x, p["ln_f"])
    return x @ p["tok_emb"].T


def token_logprobs(cfg: ModelConfig, p, ids, lens):
    """Per-token log-probs + entropies under teacher forcing.

    Returns:
      logp: [B, T] log π(ids[t] | ids[<t]); position 0 is 0.
      ent:  [B, T] entropy of the predictive distribution *for* position t
            (i.e. computed from context < t); position 0 is 0.
    """
    logits = forward_full(cfg, p, ids, lens)
    logall = jax.nn.log_softmax(logits, axis=-1)  # [B, T, V]
    pred = jnp.take_along_axis(
        logall[:, :-1, :], ids[:, 1:, None], axis=-1
    )[..., 0]  # [B, T-1]
    logp = jnp.pad(pred, ((0, 0), (1, 0)))
    probs = jnp.exp(logall)
    ent_src = -jnp.sum(probs * logall, axis=-1)  # [B, T] at context position
    ent = jnp.pad(ent_src[:, :-1], ((0, 0), (1, 0)))
    return logp, ent


# ---------------------------------------------------------------------------
# rollout path: prefill / decode / compress
# ---------------------------------------------------------------------------
#
# Cache state (all fixed-shape, device-resident across the whole rollout):
#   kv        [L, 2, B, H, C, Dh] keys (index 0) and values (index 1)
#   stats_cum [L, B, H, C]  cumulative attention mass   (H2O importance)
#   stats_win [L, B, H, C]  mass since last compression (SnapKV window)
#   birth     [L, B, H, C]  absolute position written in each slot, -1 empty
#
# Slot occupancy is uniform across layers/heads (compaction always leaves
# exactly `budget` slots, appends are lockstep), so a single per-sequence
# `lens` vector tracks the number of occupied slots.


def prefill(cfg: ModelConfig, p, ids, lens, capacity: int):
    """Run the prompt through the model, building the KV cache.

    Args:
      ids:  [B, P] right-padded prompt tokens.
      lens: [B] prompt lengths.
      capacity: cache capacity C >= P.

    Returns:
      (kv, stats_cum, stats_win, birth, logp_last [B, V])
    """
    B, P = ids.shape
    L, H, Dh, C = cfg.n_layers, cfg.n_heads, cfg.d_head, capacity
    pos = jnp.arange(P, dtype=jnp.int32)
    x = p["tok_emb"][ids] + p["pos_emb"][pos][None, :, :]
    qmask = (pos[None, :] < lens[:, None]).astype(jnp.float32)
    kmask = jnp.where(qmask > 0, 0.0, NEG_INF).astype(jnp.float32)

    kv = jnp.zeros((L, 2, B, H, C, Dh), jnp.float32)
    stats = jnp.zeros((L, B, H, C), jnp.float32)
    pad_c = C - P
    for i in range(cfg.n_layers):
        h = rms_norm(x, p[f"l{i}.ln1"])
        q = _split_heads(h @ p[f"l{i}.wq"], cfg.n_heads)
        k = _split_heads(h @ p[f"l{i}.wk"], cfg.n_heads)
        v = _split_heads(h @ p[f"l{i}.wv"], cfg.n_heads)
        att, colsum = attention.prefill_attention(q, k, v, qmask, kmask)
        # zero out padded-slot K/V so evicted/pad slots hold zeros
        kpad = k * qmask[:, None, :, None]
        vpad = v * qmask[:, None, :, None]
        kv = kv.at[i, 0, :, :, :P, :].set(kpad)
        kv = kv.at[i, 1, :, :, :P, :].set(vpad)
        stats = stats.at[i, :, :, :P].set(colsum * qmask[:, None, :])
        x = x + _merge_heads(att) @ p[f"l{i}.wo"]
        h2 = rms_norm(x, p[f"l{i}.ln2"])
        x = x + swiglu(h2, p[f"l{i}.w1"], p[f"l{i}.w3"], p[f"l{i}.w2"])
    x = rms_norm(x, p["ln_f"])
    logits = x @ p["tok_emb"].T  # [B, P, V]
    last = jnp.take_along_axis(
        logits, (lens - 1)[:, None, None].astype(jnp.int32), axis=1
    )[:, 0, :]
    logp_last = jax.nn.log_softmax(last, axis=-1)

    occupied = (pos[None, :] < lens[:, None])
    birth_row = jnp.where(occupied, pos[None, :], -1).astype(jnp.int32)
    birth_row = jnp.pad(birth_row, ((0, 0), (0, pad_c)), constant_values=-1)
    birth = jnp.broadcast_to(birth_row[None, :, None, :], (L, B, H, C))
    return kv, stats, stats, birth, logp_last


def decode_step(cfg: ModelConfig, p, kv, stats_cum, stats_win, birth, lens, pos, token):
    """One autoregressive step over the (possibly compressed) cache.

    Args:
      kv/stats_cum/stats_win/birth: cache state (see module comment).
      lens:  [B] i32 number of occupied slots (the write index).
      pos:   [B] i32 absolute position of `token` in the sequence.
      token: [B] i32 token to feed.

    Returns:
      (logp [B, V], kv', stats_cum', stats_win', birth')
    """
    L, _, B, H, C, Dh = kv.shape
    x = p["tok_emb"][token] + p["pos_emb"][pos]  # [B, D]
    slot_oh = jax.nn.one_hot(lens, C, dtype=jnp.float32)  # [B, C]
    for i in range(cfg.n_layers):
        h = rms_norm(x, p[f"l{i}.ln1"])
        q = _split_heads(h @ p[f"l{i}.wq"], cfg.n_heads)  # [B, H, Dh]
        k = _split_heads(h @ p[f"l{i}.wk"], cfg.n_heads)
        v = _split_heads(h @ p[f"l{i}.wv"], cfg.n_heads)
        # scatter the new K/V into slot lens[b]
        kv = kv.at[i, 0].add(slot_oh[:, None, :, None] * k[:, :, None, :])
        kv = kv.at[i, 1].add(slot_oh[:, None, :, None] * v[:, :, None, :])
        valid = (jnp.arange(C, dtype=jnp.int32)[None, :] <= lens[:, None])
        mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
        att, probs = attention.decode_attention(q, kv[i, 0], kv[i, 1], mask)
        stats_cum = stats_cum.at[i].add(probs)
        stats_win = stats_win.at[i].add(probs)
        x = x + _merge_heads(att) @ p[f"l{i}.wo"]
        h2 = rms_norm(x, p[f"l{i}.ln2"])
        x = x + swiglu(h2, p[f"l{i}.w1"], p[f"l{i}.w3"], p[f"l{i}.w2"])
    birth = birth + (
        slot_oh.astype(jnp.int32)[None, :, None, :]
        * (pos[None, :, None, None] + 1)
    )  # birth was -1: -1 + (pos+1) = pos
    x = rms_norm(x, p["ln_f"])
    logits = x @ p["tok_emb"].T
    return jax.nn.log_softmax(logits, axis=-1), kv, stats_cum, stats_win, birth


def prefill_slot(cfg: ModelConfig, p, kv, stats_cum, stats_win, birth, ids,
                 lens, slot_mask, capacity: int):
    """Fused slot-masked prefill: recycle decode slots in one device call.

    Runs the batched prefill over the scratch prompt batch `ids`/`lens`
    and writes ONLY the masked slots' cache planes into the live cache —
    the in-graph slot write (XLA lowers the batch-axis select into a
    masked dynamic-update-slice over the slot planes), so continuous
    batching's slot recycling costs one device call and zero host copies
    of cache state (vs. the Rust fallback's full-cache host round-trip).

    Args:
      kv/stats_cum/stats_win/birth: the LIVE cache state (see the module
        comment for layouts; slot axis is B everywhere).
      ids:  [B, P] scratch prompt batch — the new prompt in the target
        slot's row; other rows need only be valid (their fresh planes are
        discarded by the mask, and batch rows are independent).
      lens: [B] scratch prompt lengths.
      slot_mask: [B] f32, 1.0 for slots to (re)prefill, 0.0 to preserve.
      capacity: cache capacity C (must match the live cache).

    Returns:
      (kv', stats_cum', stats_win', birth', logp_last [B, V]) — unmasked
      slots' planes bit-identical to the inputs; logp_last rows are only
      meaningful for masked slots.
    """
    fkv, fsc, fsw, fb, logp_last = prefill(cfg, p, ids, lens, capacity=capacity)
    sel6 = slot_mask[None, None, :, None, None, None] > 0
    sel4 = slot_mask[None, :, None, None] > 0
    kv = jnp.where(sel6, fkv, kv)
    stats_cum = jnp.where(sel4, fsc, stats_cum)
    stats_win = jnp.where(sel4, fsw, stats_win)
    birth = jnp.where(sel4, fb, birth)
    return kv, stats_cum, stats_win, birth, logp_last


def prefill_chunk(cfg: ModelConfig, p, kv, stats_cum, stats_win, birth, ids,
                  lens, start, limit, slot_mask, capacity: int):
    """Fused PARTIAL-RANGE slot prefill: one chunk of a resumable prompt.

    The token-budgeted step packer splits a long prompt's prefill across
    several device steps; each step writes tokens `[start, limit)` of the
    prompt into the masked slot's cache planes, preserving the planes of
    earlier chunks, so a long prompt never head-of-line-blocks a step.

    Correctness rests on the causal-prefix property: a prompt position's
    K/V depends only on positions <= itself, so running the batched
    prefill over the VISIBLE prefix (`eff = min(lens, limit)` tokens) and
    keeping only the fresh range reproduces the monolithic prefill's
    planes for those positions bit-for-bit. The attention-mass stats are
    NOT prefix-local (a slot's colsum sums over later query rows), so
    they are rewritten over the whole prefix every chunk — intermediate
    values are provisional and never read; the final chunk (limit = lens)
    leaves them exactly monolithic.

    Args:
      kv/stats_cum/stats_win/birth: the LIVE cache state.
      ids:   [B, P] scratch prompt batch — the full prompt in the target
        slot's row (every chunk resends it; only the visible prefix is
        attended). Other rows need only be valid.
      lens:  [B] scratch prompt lengths (full prompt length per row).
      start: [B] i32 first fresh position per row (tokens already written;
        0 begins a fresh slot and clears stale planes past the prompt).
      limit: [B] i32 one past the last fresh position per row. Filler
        rows use the degenerate range [0, 1).
      slot_mask: [B] f32, 1.0 for the slot being chunk-prefilled.
      capacity: cache capacity C (must match the live cache).

    Returns:
      (kv', stats_cum', stats_win', birth', logp_last [B, V]) — the
      masked slot's logp_last row is the log-probs after its LAST VISIBLE
      token (position limit-1): meaningful — and bit-identical to
      `prefill_slot`'s — exactly when limit = lens (the final chunk).
    """
    eff = jnp.minimum(lens, limit)
    fkv, fsc, fsw, fb, logp_last = prefill(cfg, p, ids, eff, capacity=capacity)
    pos_c = jnp.arange(capacity, dtype=jnp.int32)
    fresh = pos_c[None, :] >= start[:, None]  # [B, C]
    sel_kv = (slot_mask[None, None, :, None, None, None] > 0) & \
        fresh[None, None, :, None, :, None]
    sel4 = slot_mask[None, :, None, None] > 0
    sel_birth = sel4 & fresh[None, :, None, :]
    kv = jnp.where(sel_kv, fkv, kv)
    stats_cum = jnp.where(sel4, fsc, stats_cum)
    stats_win = jnp.where(sel4, fsw, stats_win)
    birth = jnp.where(sel_birth, fb, birth)
    return kv, stats_cum, stats_win, birth, logp_last


def compress_step(
    kv, stats_cum, stats_win, birth, do, method: str, shapes: RolloutShapes
):
    """Compact each sequence's cache to `budget` slots (where do[b] = 1).

    The method determines the per-slot score; selection (force-keep the
    alpha most recent + top-k by score, order-preserving compaction) is
    shared. Sequences with do[b] = 0 pass through untouched, so the engine
    can batch heterogeneous trigger points.

    Returns (kv', stats_cum', stats_win', birth'); retained slots occupy
    indices [0, budget), all other slots are zeroed / invalidated.
    """
    L, _, B, H, C, Dh = kv.shape
    G = L * B * H
    keys = kv[:, 0].reshape(G, C, Dh)
    valid = (birth >= 0).astype(jnp.float32).reshape(G, C)
    cum = stats_cum.reshape(G, C)
    win = stats_win.reshape(G, C)
    birth_g = birth.reshape(G, C)

    if method == "rkv":
        score = compress.rkv_scores(keys, cum, valid, shapes.lam)
    elif method == "snapkv":
        score = jnp.where(valid > 0, win, NEG_INF)
    elif method == "h2o":
        score = jnp.where(valid > 0, cum, NEG_INF)
    elif method == "streaming":
        score = compress.streaming_scores(birth_g, valid, shapes.sinks)
    else:
        raise ValueError(f"unknown compression method {method!r}")

    idx, _ = compress.select_topk(score, birth_g, valid, shapes.budget, shapes.alpha)

    def compact(x_g, fill):
        kept = jnp.take_along_axis(x_g, idx, axis=1)
        pad = jnp.full((G, C - shapes.budget), fill, x_g.dtype)
        return jnp.concatenate([kept, pad], axis=1)

    k_new = jnp.take_along_axis(keys, idx[:, :, None], axis=1)
    v_new = jnp.take_along_axis(kv[:, 1].reshape(G, C, Dh), idx[:, :, None], axis=1)
    zpad = jnp.zeros((G, C - shapes.budget, Dh), jnp.float32)
    k_new = jnp.concatenate([k_new, zpad], axis=1).reshape(L, B, H, C, Dh)
    v_new = jnp.concatenate([v_new, zpad], axis=1).reshape(L, B, H, C, Dh)
    kv_new = jnp.stack([k_new, v_new], axis=1)
    cum_new = compact(cum, 0.0).reshape(L, B, H, C)
    win_new = jnp.zeros_like(stats_win)
    birth_new = compact(birth_g, jnp.int32(-1)).reshape(L, B, H, C)

    sel = do[None, None, :, None, None, None] > 0
    kv = jnp.where(sel, kv_new, kv)
    sel4 = do[None, :, None, None] > 0
    stats_cum = jnp.where(sel4, cum_new, stats_cum)
    stats_win = jnp.where(sel4, win_new, stats_win)
    birth = jnp.where(sel4, birth_new, birth)
    return kv, stats_cum, stats_win, birth


# ---------------------------------------------------------------------------
# RL training step (Eq. 7) + supervised LM step
# ---------------------------------------------------------------------------


def adam_update(flat_params, grads, m, v, step, lr, max_grad_norm=1.0,
                b1=0.9, b2=0.999, eps=1e-8):
    """Adam with global-norm gradient clipping on the flat vector.

    Returns (params', m', v', step', grad_norm_preclip).
    """
    gnorm = jnp.sqrt(jnp.sum(grads * grads))
    scale = jnp.minimum(1.0, max_grad_norm / jnp.maximum(gnorm, 1e-12))
    g = grads * scale
    step1 = step + 1
    m1 = b1 * m + (1 - b1) * g
    v1 = b2 * v + (1 - b2) * g * g
    t = step1.astype(jnp.float32)
    mhat = m1 / (1 - b1**t)
    vhat = v1 / (1 - b2**t)
    new = flat_params - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new, m1, v1, step1, gnorm


def train_step(
    cfg: ModelConfig,
    flat_params,
    m,
    v,
    step,
    ids,
    loss_mask,
    lens,
    adv,
    xi,
    mrs,
    logp_old,
    hyp,
):
    """One Sparse-RL policy update (paper Eq. 7) + Adam.

    Args:
      flat_params/m/v/step: learner weights and Adam state.
      ids:       [B, T] full (prompt + response) token ids, right-padded.
      loss_mask: [B, T] 1.0 on response tokens (positions t where ids[t]
                 was *generated*), 0 elsewhere.
      lens:      [B]    valid lengths.
      adv:       [B]    group-relative advantages Â_i (Eq. 10).
      xi:        [B, T] sparsity consistency ratios ξ_{i,t} = π_old/π_sparse
                 (Eq. 5), applied OUTSIDE the clip. Pass all-ones for the
                 GRPO-dense / naive-sparse baselines.
      mrs:       [B]    sequence-level rejection weights M^RS ∈ {0, 1}
                 (Eq. 6). Pass all-ones to disable rejection sampling.
      logp_old:  [B, T] dense old-policy log-probs (the w_{i,t} denominator).
      hyp:       [4] f32: (lr, clip_eps, kl_coef, max_grad_norm).

    Returns:
      (params', m', v', step', loss, grad_norm, clip_frac, entropy, kl)
    """
    layout = ParamLayout(cfg)
    lr, clip_eps, kl_coef, max_gn = hyp[0], hyp[1], hyp[2], hyp[3]

    def loss_fn(theta):
        p = layout.unflatten(theta)
        logp_new, ent = token_logprobs(cfg, p, ids, lens)
        w = jnp.exp(logp_new - logp_old)
        w_clip = jnp.clip(w, 1.0 - clip_eps, 1.0 + clip_eps)
        surr = jnp.minimum(w * adv[:, None], w_clip * adv[:, None])
        tok = xi * surr * loss_mask
        denom = jnp.maximum(jnp.sum(loss_mask, axis=1), 1.0)
        per_seq = jnp.sum(tok, axis=1) / denom * mrs
        objective = jnp.mean(per_seq)
        # k3 KL estimator vs the dense old policy (KL regularization)
        logr = logp_old - logp_new
        k3 = jnp.exp(logr) - logr - 1.0
        tokens = jnp.maximum(jnp.sum(loss_mask), 1.0)
        kl = jnp.sum(k3 * loss_mask) / tokens
        loss = -objective + kl_coef * kl
        clipped = (
            ((w > 1.0 + clip_eps) | (w < 1.0 - clip_eps)).astype(jnp.float32)
            * loss_mask
        )
        stats = (
            jnp.sum(clipped) / tokens,
            jnp.sum(ent * loss_mask) / tokens,
            kl,
        )
        return loss, stats

    (loss, (clip_frac, entropy, kl)), grads = jax.value_and_grad(
        loss_fn, has_aux=True
    )(flat_params)
    new, m1, v1, step1, gnorm = adam_update(
        flat_params, grads, m, v, step, lr, max_gn
    )
    return new, m1, v1, step1, loss, gnorm, clip_frac, entropy, kl


def lm_step(cfg: ModelConfig, flat_params, m, v, step, ids, mask, lens, hyp):
    """Supervised next-token cross-entropy step (base-model pretraining).

    Args:
      ids:  [B, T] tokens; mask [B, T] 1.0 at positions whose *prediction*
            counts toward the loss (i.e. target positions t >= 1).
      hyp:  [4] f32, only hyp[0] (lr) and hyp[3] (max grad norm) are used.

    Returns: (params', m', v', step', loss)
    """
    layout = ParamLayout(cfg)

    def loss_fn(theta):
        p = layout.unflatten(theta)
        logp, _ = token_logprobs(cfg, p, ids, lens)
        tokens = jnp.maximum(jnp.sum(mask), 1.0)
        return -jnp.sum(logp * mask) / tokens

    loss, grads = jax.value_and_grad(loss_fn)(flat_params)
    new, m1, v1, step1, _ = adam_update(
        flat_params, grads, m, v, step, hyp[0], hyp[3]
    )
    return new, m1, v1, step1, loss
