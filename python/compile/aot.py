"""AOT pipeline: lower every L2 entry point to HLO text + manifest.json.

Interchange format is HLO **text**, NOT `.serialize()`: the image's
xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction ids); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md). All functions are lowered with
`return_tuple=True` so the Rust side unwraps one tuple per call.

Usage (from python/):
    python -m compile.aot --preset tiny --out-dir ../artifacts
    python -m compile.aot --preset small --budget 16 --out-dir ../artifacts

Each build produces `artifacts/<preset>[-b<budget>]/` containing one
`<entry>.hlo.txt` per entry point and a `manifest.json` describing every
input/output tensor (name, dtype, dims), the flat parameter layout, and the
model/rollout hyper-parameters — the Rust runtime binds against the
manifest and never guesses shapes.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import PRESETS, ModelConfig, RolloutShapes


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text via StableHLO (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(dtype, *dims):
    return jax.ShapeDtypeStruct(tuple(dims), dtype)


F32, I32 = jnp.float32, jnp.int32


def _dtype_name(d):
    return {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[jnp.dtype(d)]


class EntryBuilder:
    """Collects (name, fn, arg specs, output names) and lowers them all."""

    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries = {}

    def add(self, name, fn, args, arg_names, out_names):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        outs = lowered.out_info
        out_list = jax.tree_util.tree_leaves(outs)
        self.entries[name] = {
            "file": fname,
            "inputs": [
                {"name": n, "dtype": _dtype_name(a.dtype), "dims": list(a.shape)}
                for n, a in zip(arg_names, args)
            ],
            "outputs": [
                {"name": n, "dtype": _dtype_name(o.dtype), "dims": list(o.shape)}
                for n, o in zip(out_names, out_list)
            ],
        }
        print(
            f"  {name:<22s} {len(text)/1024:8.1f} KiB  {time.time()-t0:5.1f}s",
            flush=True,
        )


def build(cfg: ModelConfig, shapes: RolloutShapes, out_dir: str,
          methods=("rkv", "snapkv", "h2o", "streaming"), skip_train=False):
    os.makedirs(out_dir, exist_ok=True)
    layout = model.ParamLayout(cfg)
    N = layout.total
    L, H, Dh, V = cfg.n_layers, cfg.n_heads, cfg.d_head, cfg.vocab
    P, T = cfg.prompt_len, cfg.max_seq
    R, Btr = shapes.decode_batch, shapes.train_batch
    Cd, Cs = cfg.max_seq, shapes.sparse_capacity
    print(f"building {cfg.name}: params={N} ({N*4/1e6:.1f} MB) -> {out_dir}")

    b = EntryBuilder(out_dir)

    b.add(
        "init_params",
        functools.partial(model.init_params, cfg),
        [_spec(I32)],
        ["seed"],
        ["params"],
    )

    cache_outs = ["kv", "stats_cum", "stats_win", "birth"]
    for variant, C in (("dense", Cd), ("sparse", Cs)):
        def prefill_fn(params, ids, lens, C=C):
            p = model.ParamLayout(cfg).unflatten(params)
            return model.prefill(cfg, p, ids, lens, capacity=C)

        b.add(
            f"prefill_{variant}",
            prefill_fn,
            [_spec(F32, N), _spec(I32, R, P), _spec(I32, R)],
            ["params", "ids", "lens"],
            cache_outs + ["logp_last"],
        )

        def decode_fn(params, kv, sc, sw, birth, lens, pos, token):
            p = model.ParamLayout(cfg).unflatten(params)
            return model.decode_step(cfg, p, kv, sc, sw, birth, lens, pos, token)

        b.add(
            f"decode_{variant}",
            decode_fn,
            [
                _spec(F32, N),
                _spec(F32, L, 2, R, H, C, Dh),
                _spec(F32, L, R, H, C),
                _spec(F32, L, R, H, C),
                _spec(I32, L, R, H, C),
                _spec(I32, R),
                _spec(I32, R),
                _spec(I32, R),
            ],
            ["params", "kv", "stats_cum", "stats_win", "birth", "lens", "pos", "token"],
            ["logp"] + cache_outs,
        )

        # Fused slot-masked prefill: slot recycling as ONE device call —
        # the live cache flows in, the masked slots' planes are rewritten
        # in-graph, no host round-trip. The Rust engine feature-gates on
        # this entry's presence and falls back to a scratch-batch splice
        # for older artifact sets.
        def prefill_slot_fn(params, kv, sc, sw, birth, ids, lens, slot_mask, C=C):
            p = model.ParamLayout(cfg).unflatten(params)
            return model.prefill_slot(
                cfg, p, kv, sc, sw, birth, ids, lens, slot_mask, capacity=C
            )

        b.add(
            f"prefill_slot_{variant}",
            prefill_slot_fn,
            [
                _spec(F32, N),
                _spec(F32, L, 2, R, H, C, Dh),
                _spec(F32, L, R, H, C),
                _spec(F32, L, R, H, C),
                _spec(I32, L, R, H, C),
                _spec(I32, R, P),
                _spec(I32, R),
                _spec(F32, R),
            ],
            ["params", "kv", "stats_cum", "stats_win", "birth", "ids", "lens",
             "slot_mask"],
            cache_outs + ["logp_last"],
        )

        # Fused partial-range (chunked) prefill: one chunk of a resumable
        # prompt per device call, driven by the token-budgeted step packer
        # (`prefill-chunk-tokens`). Same feature-gating story as the slot
        # entry: the Rust engine dispatches on this entry's presence and
        # degrades to defer-then-monolithic for older artifact sets.
        def prefill_chunk_fn(params, kv, sc, sw, birth, ids, lens, start,
                             limit, slot_mask, C=C):
            p = model.ParamLayout(cfg).unflatten(params)
            return model.prefill_chunk(
                cfg, p, kv, sc, sw, birth, ids, lens, start, limit,
                slot_mask, capacity=C
            )

        b.add(
            f"prefill_chunk_{variant}",
            prefill_chunk_fn,
            [
                _spec(F32, N),
                _spec(F32, L, 2, R, H, C, Dh),
                _spec(F32, L, R, H, C),
                _spec(F32, L, R, H, C),
                _spec(I32, L, R, H, C),
                _spec(I32, R, P),
                _spec(I32, R),
                _spec(I32, R),
                _spec(I32, R),
                _spec(F32, R),
            ],
            ["params", "kv", "stats_cum", "stats_win", "birth", "ids", "lens",
             "start", "limit", "slot_mask"],
            cache_outs + ["logp_last"],
        )

    for method in methods:
        b.add(
            f"compress_{method}",
            functools.partial(model.compress_step, method=method, shapes=shapes),
            [
                _spec(F32, L, 2, R, H, Cs, Dh),
                _spec(F32, L, R, H, Cs),
                _spec(F32, L, R, H, Cs),
                _spec(I32, L, R, H, Cs),
                _spec(F32, R),
            ],
            ["kv", "stats_cum", "stats_win", "birth", "do"],
            cache_outs,
        )

    def score_fn(params, ids, lens):
        p = model.ParamLayout(cfg).unflatten(params)
        return model.token_logprobs(cfg, p, ids, lens)

    b.add(
        "score",
        score_fn,
        [_spec(F32, N), _spec(I32, Btr, T), _spec(I32, Btr)],
        ["params", "ids", "lens"],
        ["logp", "entropy"],
    )

    if not skip_train:
        b.add(
            "train",
            functools.partial(model.train_step, cfg),
            [
                _spec(F32, N), _spec(F32, N), _spec(F32, N), _spec(I32),
                _spec(I32, Btr, T), _spec(F32, Btr, T), _spec(I32, Btr),
                _spec(F32, Btr), _spec(F32, Btr, T), _spec(F32, Btr),
                _spec(F32, Btr, T), _spec(F32, 4),
            ],
            ["params", "m", "v", "step", "ids", "loss_mask", "lens", "adv",
             "xi", "mrs", "logp_old", "hyp"],
            ["params", "m", "v", "step", "loss", "grad_norm", "clip_frac",
             "entropy", "kl"],
        )

        b.add(
            "lm",
            functools.partial(model.lm_step, cfg),
            [
                _spec(F32, N), _spec(F32, N), _spec(F32, N), _spec(I32),
                _spec(I32, Btr, T), _spec(F32, Btr, T), _spec(I32, Btr),
                _spec(F32, 4),
            ],
            ["params", "m", "v", "step", "ids", "mask", "lens", "hyp"],
            ["params", "m", "v", "step", "loss"],
        )

    manifest = {
        "config": {
            "name": cfg.name,
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "d_head": cfg.d_head,
            "max_seq": cfg.max_seq,
            "prompt_len": cfg.prompt_len,
            "n_params": N,
        },
        "shapes": {
            "decode_batch": R,
            "train_batch": Btr,
            "budget": shapes.budget,
            "buffer": shapes.buffer,
            "alpha": shapes.alpha,
            "lam": shapes.lam,
            "sinks": shapes.sinks,
            "sparse_capacity": Cs,
            "dense_capacity": Cd,
        },
        "params": layout.manifest(),
        "entries": b.entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  manifest.json           {len(b.entries)} entries")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--budget", type=int, default=32)
    ap.add_argument("--buffer", type=int, default=16)
    ap.add_argument("--alpha", type=int, default=4)
    ap.add_argument("--lam", type=float, default=0.1)
    ap.add_argument("--decode-batch", type=int, default=16)
    ap.add_argument("--train-batch", type=int, default=16)
    ap.add_argument("--methods", default="rkv,snapkv,h2o,streaming")
    ap.add_argument("--skip-train", action="store_true",
                    help="skip train/lm artifacts (eval-only builds)")
    ap.add_argument("--tag", default="",
                    help="directory suffix (default: -b<budget> if != 32)")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    shapes = RolloutShapes(
        decode_batch=args.decode_batch,
        train_batch=args.train_batch,
        budget=args.budget,
        buffer=args.buffer,
        alpha=args.alpha,
        lam=args.lam,
    )
    tag = args.tag or (f"-b{args.budget}" if args.budget != 32 else "")
    out_dir = os.path.join(args.out_dir, cfg.name + tag)
    t0 = time.time()
    build(cfg, shapes, out_dir, methods=args.methods.split(","),
          skip_train=args.skip_train)
    print(f"done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
