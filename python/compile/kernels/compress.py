"""Pallas KV-compression scoring kernel + jnp selection machinery (L1).

The compression operator M(.) of the paper (Eq. 2) is a *selection* of
which cache slots to retain. All four supported methods reduce to:

    score each occupied slot  ->  force-keep the alpha most recent slots
    ->  top-k(budget)  ->  compact the cache.

The only compute-heavy part is R-KV's redundancy statistic (pairwise key
cosine similarities, O(C^2 D) per head) — that is the Pallas kernel here.
SnapKV / H2O scores are statistics already accumulated by the fused decode
kernel (observation-window / cumulative attention mass), and StreamingLLM
is purely positional; their selection shares `select_topk` below, which
stays in jnp (top_k + gather lower to tight HLO already).

Methods (paper §2, Appendix A):
  * R-KV (Cai et al., 2025):   lam * norm(importance) - (1-lam) * norm(redundancy)
  * SnapKV (Li et al., 2024):  attention mass from the observation window
  * H2O (Zhang et al., 2023):  cumulative attention mass (heavy hitters)
  * StreamingLLM (Xiao 2023):  attention sinks + most recent window
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = ref.NEG_INF


# ---------------------------------------------------------------------------
# R-KV score kernel
# ---------------------------------------------------------------------------


def _rkv_kernel(k_ref, imp_ref, val_ref, s_ref, *, lam):
    """Per-group block: keys [C, D], imp [C], valid [C] -> score [C]."""
    keys = k_ref[...]
    valid = val_ref[...]
    C = keys.shape[0]

    norm = jnp.sqrt(jnp.sum(keys * keys, axis=-1, keepdims=True))
    khat = keys / jnp.maximum(norm, 1e-6)
    sim = jnp.dot(khat, khat.T)  # [C, C]
    row = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    offdiag = jnp.where(row != col, 1.0, 0.0).astype(keys.dtype)
    pair_valid = valid[:, None] * valid[None, :] * offdiag
    ssum = jnp.sum(sim * pair_valid, axis=-1)
    cnt = jnp.sum(pair_valid, axis=-1)
    red = jnp.where(cnt > 0, ssum / jnp.maximum(cnt, 1.0), 0.0) * valid

    def mmnorm(x):
        big = 1e30
        lo = jnp.min(jnp.where(valid > 0, x, big))
        hi = jnp.max(jnp.where(valid > 0, x, -big))
        rng = hi - lo
        normed = jnp.where(rng > 1e-12, (x - lo) / jnp.maximum(rng, 1e-12), 0.5)
        return jnp.clip(normed, 0.0, 1.0) * valid

    score = (lam * mmnorm(imp_ref[...]) - (1.0 - lam) * mmnorm(red)) * valid
    # Push invalid slots far below any valid score so top-k never picks them.
    s_ref[...] = score - (1.0 - valid)


def rkv_scores(keys, imp, valid, lam):
    """R-KV selection scores (Pallas, interpret mode).

    Args:
      keys:  [G, C, D] cached keys, G = layers*batch*heads flattened.
      imp:   [G, C]    importance statistic (cumulative attention mass).
      valid: [G, C]    slot occupancy (1.0 / 0.0).
      lam:   python float trade-off (paper: 0.1).

    Returns:
      score: [G, C]; invalid slots are pushed to <= -1 so top-k skips them.
    """
    G, C, D = keys.shape
    kernel = functools.partial(_rkv_kernel, lam=lam)
    return pl.pallas_call(
        kernel,
        grid=(G,),
        in_specs=[
            pl.BlockSpec((None, C, D), lambda g: (g, 0, 0)),
            pl.BlockSpec((None, C), lambda g: (g, 0)),
            pl.BlockSpec((None, C), lambda g: (g, 0)),
        ],
        out_specs=pl.BlockSpec((None, C), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((G, C), keys.dtype),
        interpret=True,
    )(keys, imp, valid)


# ---------------------------------------------------------------------------
# shared selection machinery (jnp — lowers to top_k + gather)
# ---------------------------------------------------------------------------


def select_topk(score, birth, valid, budget, alpha):
    """Pick `budget` slots per group: force-keep the `alpha` most recently
    written valid slots (observation tokens, paper Appendix A), fill the
    rest by descending score. Returns indices sorted by birth position so
    the compacted cache preserves generation order.

    Args:
      score: [G, C] method score (invalid slots must already be < valid ones).
      birth: [G, C] int32 absolute position at which each slot was written
             (-1 for empty slots).
      valid: [G, C] occupancy.
      budget, alpha: python ints.

    Returns:
      idx:  [G, budget] int32 slot indices to retain.
      keep: [G, C] 1.0 where the slot was retained.
    """
    C = score.shape[-1]
    # NOTE: no jax.lax.top_k here — it lowers to the `topk` HLO instruction
    # which the image's xla_extension 0.5.1 text parser rejects. Sort-based
    # selection lowers to the classic `sort` op instead.
    # Rank slots by recency: the alpha highest birth positions get +BIG.
    recency = jnp.where(valid > 0, birth, -(2**30))
    rec_sorted = jnp.sort(recency, axis=-1)  # ascending
    k = min(alpha, C)
    thresh = rec_sorted[..., C - k : C - k + 1]
    force = (recency >= thresh) & (valid > 0)
    sel_score = jnp.where(force, 1e6 + birth.astype(score.dtype), score)
    order_by_score = jnp.argsort(sel_score, axis=-1)  # ascending
    idx = order_by_score[..., C - budget :]
    # Stable order: sort retained indices by birth position (ascending).
    b_at = jnp.take_along_axis(birth, idx, axis=-1)
    order = jnp.argsort(b_at, axis=-1)
    idx = jnp.take_along_axis(idx, order, axis=-1).astype(jnp.int32)
    keep = jnp.zeros_like(score).at[
        jnp.arange(score.shape[0])[:, None], idx
    ].set(1.0)
    return idx, keep


def streaming_scores(birth, valid, sinks):
    """StreamingLLM scores: attention sinks (the `sinks` oldest positions)
    and recent tokens win; middle tokens lose. Recency handled by the
    force-keep in select_topk plus monotone birth score here."""
    is_sink = (birth >= 0) & (birth < sinks)
    base = birth.astype(jnp.float32) * 1e-3  # newer slightly better
    score = jnp.where(is_sink, 1e3, base)
    return jnp.where(valid > 0, score, NEG_INF)
