"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an exact (up to float tolerance) reference
implementation here. These are the CORE correctness signal: pytest sweeps
shapes with hypothesis-style random cases and asserts allclose between the
Pallas kernels (interpret=True) and these functions.

They are also used as the *backward* rule for the differentiable attention
wrapper (see attention.py): the Pallas forward is paired with the VJP of the
reference, which is mathematically the same function.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e9


def decode_attention_ref(q, k, v, mask):
    """Single-query attention over a fixed-capacity KV cache.

    Args:
      q:    [B, H, D]    query for the current token.
      k:    [B, H, C, D] cached keys (C = cache capacity).
      v:    [B, H, C, D] cached values.
      mask: [B, C]       additive validity mask (0 for valid, NEG_INF for
                         empty/evicted slots).

    Returns:
      out:   [B, H, D]   attention output.
      probs: [B, H, C]   attention probabilities per cache slot (consumed by
                         the compression scorers: H2O/SnapKV statistics).
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    s = jnp.einsum("bhd,bhcd->bhc", q, k) * scale + mask[:, None, :]
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    out = jnp.einsum("bhc,bhcd->bhd", p, v)
    return out, p


def prefill_attention_ref(q, k, v, qmask, kmask):
    """Causal self-attention over a full (padded) sequence, with the
    per-slot attention-mass statistic needed to seed compression stats.

    Args:
      q, k, v: [B, H, T, D]
      qmask:   [B, T] 1.0 for real query positions, 0.0 for padding.
      kmask:   [B, T] additive mask for key positions (0 valid / NEG_INF).

    Returns:
      out:    [B, H, T, D] attention output (garbage at padded queries —
              callers mask downstream).
      colsum: [B, H, T]    sum over *valid* query rows of the attention
              probability assigned to each key slot (cumulative attention
              mass, the H2O statistic seeding the decode-time stats).
    """
    T = q.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    s = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
    causal = jnp.where(
        jnp.arange(T)[:, None] >= jnp.arange(T)[None, :], 0.0, NEG_INF
    ).astype(q.dtype)
    s = s + causal[None, None, :, :] + kmask[:, None, None, :]
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    out = jnp.einsum("bhts,bhsd->bhtd", p, v)
    colsum = jnp.einsum("bhts,bt->bhs", p, qmask.astype(q.dtype))
    return out, colsum


def redundancy_scores_ref(keys, valid):
    """Mean cosine similarity of each cached key against the other valid
    cached keys — the R-KV redundancy statistic. Tokens that sit in dense
    similarity clusters (repeated/redundant reasoning) score high.

    Args:
      keys:  [G, C, D] cached keys (G = flattened layer*batch*head groups).
      valid: [G, C]    1.0 for occupied slots, 0.0 otherwise.

    Returns:
      red: [G, C] mean pairwise cosine similarity (0 where invalid or fewer
           than 2 valid slots).
    """
    norm = jnp.sqrt(jnp.sum(keys * keys, axis=-1, keepdims=True))
    khat = keys / jnp.maximum(norm, 1e-6)
    sim = jnp.einsum("gcd,ged->gce", khat, khat)
    C = keys.shape[-2]
    eye = jnp.eye(C, dtype=keys.dtype)
    pair_valid = valid[..., :, None] * valid[..., None, :] * (1.0 - eye)
    ssum = jnp.sum(sim * pair_valid, axis=-1)
    cnt = jnp.sum(pair_valid, axis=-1)
    red = jnp.where(cnt > 0, ssum / jnp.maximum(cnt, 1.0), 0.0)
    return red * valid


def minmax_normalize_ref(x, valid):
    """Min-max normalize x to [0, 1] over the valid slots of the last axis.

    Invalid slots map to 0. Degenerate (constant) ranges map to 0.5 so that
    neither importance nor redundancy dominates spuriously.
    """
    big = 1e30
    lo = jnp.min(jnp.where(valid > 0, x, big), axis=-1, keepdims=True)
    hi = jnp.max(jnp.where(valid > 0, x, -big), axis=-1, keepdims=True)
    rng = hi - lo
    normed = jnp.where(rng > 1e-12, (x - lo) / jnp.maximum(rng, 1e-12), 0.5)
    return jnp.clip(normed, 0.0, 1.0) * valid


def rkv_scores_ref(keys, imp, valid, lam):
    """R-KV selection score: lam * importance - (1 - lam) * redundancy,
    both min-max normalized over valid slots (Cai et al., 2025).

    Args:
      keys:  [G, C, D] cached keys.
      imp:   [G, C]    importance statistic (cumulative attention mass).
      valid: [G, C]    slot validity.
      lam:   scalar trade-off (paper: 0.1).

    Returns:
      score: [G, C] selection score; higher = keep.
    """
    red = redundancy_scores_ref(keys, valid)
    imp_n = minmax_normalize_ref(imp, valid)
    red_n = minmax_normalize_ref(red, valid)
    return (lam * imp_n - (1.0 - lam) * red_n) * valid - (1.0 - valid)
