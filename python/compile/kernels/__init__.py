"""L1: Pallas kernels for the rollout hot-spot (decode attention with fused
compression statistics, prefill attention, R-KV redundancy scoring) plus
their pure-jnp oracles (ref)."""

from . import attention, compress, ref  # noqa: F401
