"""Pallas attention kernels — the rollout hot-spot (L1).

Two kernels:

* ``decode_attention`` — single-query attention over a fixed-capacity KV
  cache. One grid cell per (batch, head); the whole per-head cache is a
  single VMEM-resident block (the Sparse-RL insight: with a token budget B
  the cache *fits on-chip*, so decode attention needs no HBM streaming —
  see DESIGN.md §Hardware-Adaptation). The kernel also emits the attention
  probabilities per cache slot, which the compression scorers (H2O
  cumulative mass, SnapKV observation window) accumulate — fused, so the
  cache is read exactly once per step.

* ``prefill_attention`` — causal self-attention over the (padded) prompt,
  emitting the column-sum attention-mass statistic that seeds the decode
  stats. Wrapped in ``jax.custom_vjp`` with the reference VJP so the same
  Pallas forward is usable inside the differentiated training graph.

All kernels run with ``interpret=True``: the image's CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowers the kernel body to
plain HLO so the AOT artifact runs at native XLA-CPU speed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = ref.NEG_INF


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


def _decode_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, p_ref, *, scale):
    """Per-(batch, head) block: q [D], k/v [C, D], m [C] -> o [D], p [C]."""
    q = q_ref[...]
    k = k_ref[...]
    s = jnp.dot(k, q) * scale + m_ref[...]
    s = s - jnp.max(s)
    e = jnp.exp(s)
    p = e / jnp.sum(e)
    o_ref[...] = jnp.dot(p, v_ref[...])
    p_ref[...] = p


def decode_attention(q, k, v, mask):
    """Single-query attention over the KV cache (Pallas, interpret mode).

    Args:
      q:    [B, H, D]    current-token query.
      k, v: [B, H, C, D] cached keys / values (C = cache capacity; for the
                         sparse path C = budget + buffer and the whole block
                         is VMEM-resident).
      mask: [B, C]       additive validity mask (0 valid / NEG_INF empty).

    Returns:
      out:   [B, H, D]
      probs: [B, H, C] attention probability mass per cache slot.
    """
    B, H, D = q.shape
    C = k.shape[2]
    scale = 1.0 / (D**0.5)
    kernel = functools.partial(_decode_kernel, scale=scale)
    out, probs = pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((None, None, D), lambda b, h: (b, h, 0)),
            pl.BlockSpec((None, None, C, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, C, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, C), lambda b, h: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, D), lambda b, h: (b, h, 0)),
            pl.BlockSpec((None, None, C), lambda b, h: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, C), q.dtype),
        ],
        interpret=True,
    )(q, k, v, mask)
    return out, probs


# ---------------------------------------------------------------------------
# prefill attention
# ---------------------------------------------------------------------------


def _prefill_kernel(q_ref, k_ref, v_ref, qm_ref, km_ref, o_ref, c_ref, *, scale):
    """Per-(batch, head) block: q/k/v [T, D], qm/km [T] -> o [T, D], c [T]."""
    q = q_ref[...]
    k = k_ref[...]
    T = q.shape[0]
    s = jnp.dot(q, k.T) * scale
    row = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    causal = jnp.where(row >= col, 0.0, NEG_INF).astype(s.dtype)
    s = s + causal + km_ref[...][None, :]
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v_ref[...])
    c_ref[...] = jnp.sum(p * qm_ref[...][:, None], axis=0)


def _prefill_pallas(q, k, v, qmask, kmask):
    B, H, T, D = q.shape
    scale = 1.0 / (D**0.5)
    kernel = functools.partial(_prefill_kernel, scale=scale)
    out, colsum = pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((None, None, T, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, T, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, T, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, T), lambda b, h: (b, 0)),
            pl.BlockSpec((None, T), lambda b, h: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, T, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, T), lambda b, h: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, T), q.dtype),
        ],
        interpret=True,
    )(q, k, v, qmask, kmask)
    return out, colsum


@jax.custom_vjp
def prefill_attention(q, k, v, qmask, kmask):
    """Causal attention with attention-mass statistics (Pallas forward).

    Args:
      q, k, v: [B, H, T, D]
      qmask:   [B, T] 1.0 at real query positions (weights the statistic).
      kmask:   [B, T] additive key-validity mask (0 / NEG_INF).

    Returns:
      out:    [B, H, T, D]
      colsum: [B, H, T] per-slot cumulative attention mass.

    Differentiable: the backward pass is the VJP of the pure-jnp reference,
    which computes the identical function, so gradients are exact.
    """
    return _prefill_pallas(q, k, v, qmask, kmask)


def _prefill_fwd(q, k, v, qmask, kmask):
    return _prefill_pallas(q, k, v, qmask, kmask), (q, k, v, qmask, kmask)


def _prefill_bwd(res, cts):
    _, vjp = jax.vjp(ref.prefill_attention_ref, *res)
    return vjp(cts)


prefill_attention.defvjp(_prefill_fwd, _prefill_bwd)
