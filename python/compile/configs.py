"""Model/scale presets shared by the AOT pipeline and pytest.

The paper trains Llama-3.2-1B / Qwen2.5-1.5B/3B/7B; we map those to four
from-scratch scale points (DESIGN.md §5) plus an `e2e` config for the
end-to-end driver. The Rust side never imports this — it binds artifacts
through `manifest.json`.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer hyper-parameters.

    Attributes mirror the fields serialized into the artifact manifest.
    """

    name: str
    vocab: int = 32
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 0  # 0 -> 8/3 * d_model rounded to a multiple of 16 (SwiGLU)
    max_seq: int = 208  # prompt (48) + response (160)
    prompt_len: int = 48

    def __post_init__(self):
        if self.d_ff == 0:
            ff = int(self.d_model * 8 / 3)
            ff = ((ff + 15) // 16) * 16
            object.__setattr__(self, "d_ff", ff)
        assert self.d_model % self.n_heads == 0

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


# Paper-model analogs (DESIGN.md §5 scale mapping).
PRESETS = {
    "nano": ModelConfig("nano", d_model=64, n_layers=2, n_heads=2),
    "tiny": ModelConfig("tiny", d_model=128, n_layers=4, n_heads=4),
    "small": ModelConfig("small", d_model=192, n_layers=6, n_heads=6),
    "base": ModelConfig("base", d_model=256, n_layers=8, n_heads=8),
    "e2e": ModelConfig("e2e", d_model=768, n_layers=12, n_heads=12),
}


@dataclasses.dataclass(frozen=True)
class RolloutShapes:
    """Static shapes an artifact set is specialized for."""

    decode_batch: int = 16  # rollout slots per decode dispatch
    train_batch: int = 16  # sequences per train_step
    budget: int = 32  # retained KV tokens after compression (paper: 512)
    buffer: int = 16  # fresh tokens between compressions (paper: 128)
    alpha: int = 4  # always-retained observation tokens (paper: 8)
    lam: float = 0.1  # R-KV importance/redundancy trade-off
    sinks: int = 2  # StreamingLLM attention sinks

    @property
    def sparse_capacity(self) -> int:
        return self.budget + self.buffer
