//! Ablation: which part of Sparse-RL's correction machinery matters?
//!
//! Sweeps the design choices DESIGN.md calls out, on the same base model,
//! seed, and budget:
//!   * full        — rejection (Eq. 6) + ξ reweighting (Eq. 7)   [paper]
//!   * reject-only — M^RS filter, ξ ≡ 1
//!   * xi-only     — ξ reweighting, no rejection
//!   * clamp       — token-level ξ clamping instead of rejection [paper's
//!                   Limitations/future-work proposal]
//!   * none        — naive sparse baseline
//!
//!     cargo run --release --example ablation_corrections -- \
//!         [--model nano] [--steps 15] [--method rkv]

use anyhow::Result;

use sparse_rl::config::{CorrectionMode, ExperimentConfig, RolloutMode};
use sparse_rl::experiments;
use sparse_rl::runtime::{Method, ModelEngine};
use sparse_rl::util::cli::CliArgs;

fn main() -> Result<()> {
    let args = CliArgs::from_env();
    let model = args.get("model", "nano".to_string());
    let steps = args.get("steps", 15usize);
    let method = Method::parse(&args.get("method", "rkv".to_string()))?;
    let seed = args.get("seed", 0u64);

    let dir = experiments::find_artifacts(&model)?;
    let engine = ModelEngine::load(&dir)?;
    let base = experiments::load_or_pretrain_base(
        &engine,
        experiments::default_pretrain_steps(&model),
        seed,
    )?;

    // (label, mode, rejection, reweight, correction_mode)
    let variants: Vec<(&str, RolloutMode, bool, bool, CorrectionMode)> = vec![
        ("full (paper)", RolloutMode::SparseRl(method), true, true, CorrectionMode::Reject),
        ("reject-only", RolloutMode::SparseRl(method), true, false, CorrectionMode::Reject),
        ("xi-only", RolloutMode::SparseRl(method), false, true, CorrectionMode::Reject),
        ("clamp (future work)", RolloutMode::SparseRl(method), true, true, CorrectionMode::Clamp),
        ("none (naive)", RolloutMode::NaiveSparse(method), false, false, CorrectionMode::Reject),
    ];

    println!(
        "{:<22} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "variant", "rew@end", "len@end", "KL@end", "rej-rate", "gnorm-max"
    );
    for (label, mode, rejection, reweight, cm) in variants {
        let mut cfg = ExperimentConfig::new(&dir);
        cfg.apply_cli(&args)?;
        cfg.seed = seed;
        cfg.mode = mode;
        cfg.train.steps = steps;
        cfg.train.rejection = rejection;
        cfg.train.reweight = reweight;
        cfg.train.correction_mode = cm;
        cfg.out_dir = format!("runs/ablation/{model}").into();
        let trainer = experiments::run_rl(&engine, cfg, base.clone(), 0)?;
        let m = &trainer.metrics;
        let k = (steps / 4).max(1);
        println!(
            "{:<22} {:>9.3} {:>9.1} {:>9.2e} {:>9.3} {:>9.2}",
            label,
            m.tail_mean("reward", k),
            m.tail_mean("response_len", k),
            m.tail_mean("mismatch_kl", k),
            m.tail_mean("rejection_rate", steps),
            m.series("grad_norm").into_iter().fold(0.0f64, f64::max),
        );
        experiments::save_run(&trainer, &format!("abl-{}", label.split(' ').next().unwrap()))?;
    }
    Ok(())
}
