//! Figure 1 harness: training stability — naive GRPO with compression vs
//! GRPO + Sparse-RL (reward curve + gradient-norm spikes).
//!
//!     cargo run --release --example fig1_stability -- \
//!         [--model tiny] [--steps 60] [--method rkv] [--show-anomaly]
//!
//! Prints both series side by side and a collapse diagnosis (tail reward
//! vs peak, grad-norm spike count). `--show-anomaly` hunts for a concrete
//! compression-induced anomalous sequence (paper Appendix F) and prints it
//! decoded.

use anyhow::Result;

use sparse_rl::config::{ExperimentConfig, RolloutMode};
use sparse_rl::coordinator::engine::RolloutEngine;
use sparse_rl::data::{benchmarks, task, tokenizer};
use sparse_rl::experiments;
use sparse_rl::runtime::{Method, ModelEngine};
use sparse_rl::util::cli::CliArgs;
use sparse_rl::util::rng::Rng;

fn main() -> Result<()> {
    let args = CliArgs::from_env();
    let model = args.get("model", "tiny".to_string());
    let steps = args.get("steps", 60usize);
    let method = Method::parse(&args.get("method", "rkv".to_string()))?;
    let seed = args.get("seed", 0u64);

    let dir = experiments::find_artifacts(&model)?;
    let engine = ModelEngine::load(&dir)?;
    let base = experiments::load_or_pretrain_base(
        &engine,
        experiments::default_pretrain_steps(&model),
        seed,
    )?;

    if args.flag("show-anomaly") {
        show_anomaly(&engine, &base.params, method, seed)?;
        return Ok(());
    }

    let mut runs = Vec::new();
    for mode in [RolloutMode::NaiveSparse(method), RolloutMode::SparseRl(method)] {
        let tag = mode.label().replace(':', "-");
        // reuse series from an earlier table1/fig run when available
        let reuse = [
            format!("runs/fig1/{model}/{tag}-metrics.csv"),
            format!("runs/table1/{model}/{tag}-metrics.csv"),
        ]
        .into_iter()
        .map(std::path::PathBuf::from)
        .find(|p| p.exists());
        if let Some(csv) = reuse {
            println!("reusing {}", csv.display());
            runs.push((mode.label(), sparse_rl::coordinator::Metrics::read_csv(&csv)?));
            continue;
        }
        println!("\n-- training {} for {steps} steps --", mode.label());
        let mut cfg = ExperimentConfig::new(&dir);
        cfg.apply_cli(&args)?;
        cfg.seed = seed;
        cfg.mode = mode;
        cfg.train.steps = steps;
        cfg.out_dir = format!("runs/fig1/{model}").into();
        let trainer = experiments::run_rl(&engine, cfg, base.clone(), 10)?;
        experiments::save_run(&trainer, &tag)?;
        runs.push((mode.label(), trainer.metrics));
    }

    println!("\n=== Figure 1: reward & grad-norm dynamics ({model}, {}) ===", method.name());
    for (label, metrics) in &runs {
        println!("\n[{label}]");
        experiments::print_series(metrics, "reward", 12);
        experiments::print_series(metrics, "grad_norm", 12);
        experiments::print_series(metrics, "anomaly_rate", 12);
        let peak = metrics
            .series("reward")
            .into_iter()
            .filter(|v| !v.is_nan())
            .fold(0.0f64, f64::max);
        let tail = metrics.tail_mean("reward", steps / 5 + 1);
        let spikes = metrics
            .series("grad_norm")
            .into_iter()
            .filter(|v| *v > 5.0)
            .count();
        println!(
            "  diagnosis: peak reward {peak:.3}, tail reward {tail:.3}, grad spikes(>5) {spikes}{}",
            if tail < 0.6 * peak && peak > 0.05 { "  << COLLAPSE" } else { "" }
        );
    }
    println!("\nCSV series in runs/fig1/{model}/");
    Ok(())
}

/// Hunt for a compression-induced anomalous trajectory (Appendix F).
fn show_anomaly(engine: &ModelEngine, params: &[f32], method: Method, seed: u64) -> Result<()> {
    let m = &engine.manifest;
    let sampling = sparse_rl::config::SamplingConfig {
        temperature: 1.0,
        top_p: 1.0,
        max_response: m.config.max_seq - m.config.prompt_len,
    };
    let ro = RolloutEngine::new(engine, RolloutMode::NaiveSparse(method), sampling);
    let mut rng = Rng::new(seed ^ 0xA40);
    for round in 0..50 {
        let tasks = benchmarks::training_split_ops(
            m.shapes.decode_batch,
            m.config.prompt_len,
            seed + round,
            3,
            5,
        );
        let chunk: Vec<_> = tasks.iter().enumerate().map(|(i, t)| (i, t)).collect();
        let seqs = ro.rollout_chunk(params, &chunk, &mut rng)?;
        for (seq, t) in seqs.iter().zip(tasks.iter()) {
            if task::looks_repetitive(&seq.response_ids, 5) && seq.accounting.compressions > 0 {
                println!("== anomalous sparse rollout (Appendix F analog) ==");
                println!("prompt:   {}", t.prompt_text);
                println!("expected: {}", t.expr.chain_of_thought());
                println!("got:      {}", tokenizer::decode_raw(&seq.response_ids));
                println!(
                    "({} compressions, finished={}, len={})",
                    seq.accounting.compressions,
                    seq.finished,
                    seq.response_ids.len()
                );
                return Ok(());
            }
        }
    }
    println!("no repetitive anomaly found in 50 rounds (policy may be too strong/weak)");
    Ok(())
}
