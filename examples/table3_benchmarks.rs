//! Table 3 harness: benchmark statistics (paper Appendix B).
//!
//!     cargo run --release --example table3_benchmarks
//!
//! Prints each synthetic benchmark's description, size (matched to the
//! paper's counts), difficulty range, evaluation protocol, and measured
//! prompt/target length statistics from the materialized tasks.

use anyhow::Result;

use sparse_rl::data::benchmarks::{suite, Protocol};
use sparse_rl::util::stats;

fn main() -> Result<()> {
    println!("=== Table 3: benchmark statistics ===\n");
    println!(
        "{:<10} {:>5} {:>6} {:>8} {:>11} {:>11}  {}",
        "Benchmark", "Size", "Ops", "Protocol", "prompt-len", "target-len", "Description"
    );
    for b in suite() {
        let tasks = b.tasks(48);
        let plens: Vec<f64> = tasks.iter().map(|t| t.prompt_ids.len() as f64).collect();
        let tlens: Vec<f64> = tasks.iter().map(|t| t.target_ids().len() as f64).collect();
        let proto = match b.protocol {
            Protocol::Pass1 => "Pass@1".to_string(),
            Protocol::AvgK(k) => format!("Avg@{k}"),
        };
        println!(
            "{:<10} {:>5} {:>6} {:>8} {:>5.1}±{:<4.1} {:>5.1}±{:<4.1}  {}",
            b.name,
            tasks.len(),
            format!("{}-{}", b.ops_lo, b.ops_hi),
            proto,
            stats::mean(&plens),
            stats::std(&plens),
            stats::mean(&tlens),
            stats::std(&tlens),
            b.description
        );
    }
    println!(
        "\npaper mapping: sizes match Table 3 exactly (GSM8K 1319 ... AMC23 40); \
         difficulty = expression depth replaces MATH level."
    );
    Ok(())
}
