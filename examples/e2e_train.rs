//! END-TO-END DRIVER (the required full-system validation).
//!
//! Proves all layers compose on a real small workload:
//!   1. pretrain a from-scratch transformer on synthetic worked examples
//!      (supervised CE via the AOT `lm` artifact), logging the loss curve,
//!   2. GRPO + Sparse-RL post-training with compressed (R-KV) rollouts —
//!      the paper's full pipeline: sparse sampler -> dense scorer ->
//!      rejection + reweighting -> Eq. 7 updates,
//!   3. evaluate on the 7-benchmark suite, dense and sparse-inference.
//!
//!     cargo run --release --example e2e_train -- \
//!         [--model tiny] [--pretrain-steps 1500] [--rl-steps 60] \
//!         [--mode sparse-rl:rkv] [--eval-limit 50]
//!
//! Results are recorded in EXPERIMENTS.md; curves land in
//! runs/e2e/<model>/.

use anyhow::Result;

use sparse_rl::config::{ExperimentConfig, RolloutMode};
use sparse_rl::coordinator::EvalOptions;
use sparse_rl::experiments;
use sparse_rl::runtime::ModelEngine;
use sparse_rl::util::cli::CliArgs;

fn main() -> Result<()> {
    let args = CliArgs::from_env();
    let model = args.get("model", "tiny".to_string());
    let pretrain_steps = args.get(
        "pretrain-steps",
        experiments::default_pretrain_steps(&model),
    );
    let rl_steps = args.get("rl-steps", 60usize);
    let mode = RolloutMode::parse(&args.get("mode", "sparse-rl:rkv".to_string()))?;
    let eval_limit = args.get("eval-limit", 50usize);
    let seed = args.get("seed", 0u64);

    let dir = experiments::find_artifacts(&model)?;
    let engine = ModelEngine::load(&dir)?;
    println!(
        "== e2e driver: {} ({} params) ==",
        model, engine.manifest.config.n_params
    );

    // ---- stage 1: supervised pretraining (loss curve logged) ----------
    let t0 = std::time::Instant::now();
    let base = experiments::load_or_pretrain_base(&engine, pretrain_steps, seed)?;
    println!("stage 1 done in {:.1}s", t0.elapsed().as_secs_f64());

    // base-model eval (the "Base" row of Table 1)
    println!("\nbase model eval (dense):");
    let (_, base_avg) =
        experiments::eval_checkpoint(&engine, &base.params, RolloutMode::Dense, eval_limit, seed,
                                     &EvalOptions::default())?;

    // ---- stage 2: RL post-training -------------------------------------
    let mut cfg = ExperimentConfig::new(&dir);
    cfg.apply_cli(&args)?;
    cfg.mode = mode;
    cfg.train.steps = rl_steps;
    cfg.out_dir = format!("runs/e2e/{model}").into();
    let t1 = std::time::Instant::now();
    let trainer = experiments::run_rl(&engine, cfg, base.clone(), 5)?;
    println!("stage 2 done in {:.1}s", t1.elapsed().as_secs_f64());
    let (csv, ckpt) = experiments::save_run(&trainer, &mode.label().replace(':', "-"))?;
    println!("metrics -> {}  checkpoint -> {}", csv.display(), ckpt.display());

    println!("\ntraining dynamics (bucketed means):");
    for series in ["reward", "response_len", "entropy", "mismatch_kl", "rejection_rate",
                   "grad_norm", "toks_saving"] {
        experiments::print_series(&trainer.metrics, series, 10);
    }

    // ---- stage 3: evaluation --------------------------------------------
    println!("\npost-RL eval (dense inference):");
    let (_, rl_avg) = experiments::eval_checkpoint(
        &engine,
        &trainer.state.params,
        RolloutMode::Dense,
        eval_limit,
        seed,
        &EvalOptions::default(),
    )?;
    println!("\npost-RL eval (sparse inference, same compression as training):");
    let sparse_eval_mode = match mode {
        RolloutMode::Dense => RolloutMode::SparseRl(sparse_rl::runtime::Method::RKv),
        m => m,
    };
    let (_, rl_sparse_avg) = experiments::eval_checkpoint(
        &engine,
        &trainer.state.params,
        sparse_eval_mode,
        eval_limit,
        seed,
        &EvalOptions::default(),
    )?;

    println!("\n== e2e summary ==");
    println!("  base avg:              {base_avg:.3}");
    println!("  after RL ({}) avg: {rl_avg:.3}", mode.label());
    println!("  sparse-inference avg:  {rl_sparse_avg:.3}");
    println!(
        "  mean toks saving during training: {:.1}%",
        100.0 * trainer.metrics.tail_mean("toks_saving", rl_steps)
    );
    println!(
        "  total wall: pretrain {:.0}s + rl {:.0}s",
        t0.elapsed().as_secs_f64() - t1.elapsed().as_secs_f64(),
        t1.elapsed().as_secs_f64()
    );
    Ok(())
}
