//! Figure 4 harness: KV-cache budget ablation (paper §5.5).
//!
//! Trains GRPO + Sparse-RL (R-KV) at several budgets and evaluates on the
//! MATH500 + Olympiad analogs, against the FullKV (dense) reference line.
//! Budgets are scaled: paper {128, 256, 512, 1024, Full}/4096-ctx maps to
//! {8, 16, 32, 48, Full}/208-ctx here.
//!
//! Budget is baked into the artifact shapes, so each point needs its own
//! artifact build (`make artifacts-budgets` or, keeping capacity
//! budget+buffer >= prompt_len:
//!   cd python && python -m compile.aot --preset nano --budget 16 --buffer 32 \
//!       --tag=-b16 --out-dir ../artifacts)
//!
//!     cargo run --release --example fig4_budget_ablation -- \
//!         [--model tiny] [--budgets 8,16,32,48] [--rl-steps 40] [--eval-limit 40]

use anyhow::Result;

use sparse_rl::config::{ExperimentConfig, RolloutMode};
use sparse_rl::coordinator::{evaluate, EvalOptions};
use sparse_rl::experiments;
use sparse_rl::runtime::{Method, ModelEngine};
use sparse_rl::util::cli::CliArgs;

fn main() -> Result<()> {
    let args = CliArgs::from_env();
    let model = args.get("model", "tiny".to_string());
    let budgets: Vec<usize> = args
        .get("budgets", "8,16,32,40".to_string())
        .split(',')
        .map(|s| s.parse().expect("budget"))
        .collect();
    let rl_steps = args.get("rl-steps", 40usize);
    let limit = args.get("eval-limit", 40usize);
    let seed = args.get("seed", 0u64);

    let suite = experiments::suite();
    let benches: Vec<_> = suite
        .iter()
        .filter(|b| b.name == "math500" || b.name == "olympiad")
        .collect();

    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();

    // budget points
    for &budget in &budgets {
        let tag = if budget == 32 { String::new() } else { format!("-b{budget}") };
        let dir = std::path::PathBuf::from(format!("artifacts/{model}{tag}"));
        if !dir.join("manifest.json").exists() {
            println!(
                "skipping budget {budget}: artifacts missing (build with \
                 `cd python && python -m compile.aot --preset {model} --budget {budget} \
                 --buffer {} --tag=-b{budget} --out-dir ../artifacts`; capacity \
                 budget+buffer must stay >= prompt_len)",
                48usize.saturating_sub(budget).max(8)
            );
            continue;
        }
        let engine = ModelEngine::load(&dir)?;
        let base = experiments::load_or_pretrain_base(
            &engine,
            experiments::default_pretrain_steps(&model),
            seed,
        )?;
        let mut cfg = ExperimentConfig::new(&dir);
        cfg.apply_cli(&args)?;
        cfg.seed = seed;
        cfg.mode = RolloutMode::SparseRl(Method::RKv);
        cfg.train.steps = rl_steps;
        cfg.out_dir = format!("runs/fig4/{model}").into();
        println!("\n-- budget {budget}: training {rl_steps} steps --");
        let trainer = experiments::run_rl(&engine, cfg, base, 10)?;
        experiments::save_run(&trainer, &format!("b{budget}"))?;
        let mut accs = Vec::new();
        for b in &benches {
            let r = evaluate(
                &engine,
                &trainer.state.params,
                RolloutMode::Dense,
                b,
                limit,
                seed,
                &EvalOptions::default(),
            )?;
            accs.push(r.accuracy);
        }
        rows.push((format!("budget {budget}"), accs));
    }

    // FullKV (dense) reference line
    {
        let dir = experiments::find_artifacts(&model)?;
        let engine = ModelEngine::load(&dir)?;
        let base = experiments::load_or_pretrain_base(
            &engine,
            experiments::default_pretrain_steps(&model),
            seed,
        )?;
        let mut cfg = ExperimentConfig::new(&dir);
        cfg.apply_cli(&args)?;
        cfg.seed = seed;
        cfg.mode = RolloutMode::Dense;
        cfg.train.steps = rl_steps;
        cfg.out_dir = format!("runs/fig4/{model}").into();
        println!("\n-- FullKV (dense) reference --");
        let trainer = experiments::run_rl(&engine, cfg, base, 10)?;
        let mut accs = Vec::new();
        for b in &benches {
            let r = evaluate(
                &engine,
                &trainer.state.params,
                RolloutMode::Dense,
                b,
                limit,
                seed,
                &EvalOptions::default(),
            )?;
            accs.push(r.accuracy);
        }
        rows.push(("FullKV (dense)".to_string(), accs));
    }

    println!("\n=== Figure 4: budget ablation ({model}, R-KV, {rl_steps} steps) ===");
    print!("{:<16}", "setting");
    for b in &benches {
        print!(" {:>10}", b.name);
    }
    println!();
    for (label, accs) in &rows {
        print!("{label:<16}");
        for a in accs {
            print!(" {a:>10.3}");
        }
        println!();
    }
    println!(
        "\nshape check (paper): degraded at the smallest budget, rapid recovery \
         by mid budgets, ≈FullKV at the training budget."
    );
    Ok(())
}
