//! Quickstart: load artifacts, initialize a model, roll out a few tasks
//! under dense and sparse (R-KV) decoding, and print what the system sees.
//!
//!     cargo run --release --example quickstart -- [--model nano] [--checkpoint ckpt.srl]
//!
//! With a pretrained checkpoint (`sparse-rl pretrain --model nano`) the
//! responses become real chains of thought; from random init they are
//! noise — either way this demonstrates the full request path: Rust
//! coordinator -> PJRT -> AOT-compiled JAX/Pallas artifacts, with KV
//! compression and accounting live.

use anyhow::Result;

use sparse_rl::config::{RolloutMode, SamplingConfig};
use sparse_rl::coordinator::engine::RolloutEngine;
use sparse_rl::data::{benchmarks, tokenizer, Task};
use sparse_rl::experiments;
use sparse_rl::runtime::{Method, ModelEngine, TrainState};
use sparse_rl::util::cli::CliArgs;
use sparse_rl::util::rng::Rng;

fn main() -> Result<()> {
    let args = CliArgs::from_env();
    let model = args.get("model", "nano".to_string());
    let dir = experiments::find_artifacts(&model)?;
    println!("== sparse-rl quickstart ==\nartifacts: {}", dir.display());

    let engine = ModelEngine::load(&dir)?;
    let m = &engine.manifest;
    println!(
        "model {}: {} params, {} layers x {} heads, ctx {}, sparse budget {}+{}",
        m.config.name,
        m.config.n_params,
        m.config.n_layers,
        m.config.n_heads,
        m.config.max_seq,
        m.shapes.budget,
        m.shapes.buffer,
    );

    let state = match args.opt("checkpoint") {
        Some(p) => {
            let (_, s) = sparse_rl::runtime::params::load(p.as_ref(), m.config.n_params)?;
            println!("loaded checkpoint {p}");
            s
        }
        None => TrainState::new(engine.init_params(0)?),
    };

    let mut rng = Rng::new(42);
    let tasks: Vec<Task> = benchmarks::training_split_ops(3, m.config.prompt_len, 42, 2, 3);
    let sampling = SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 96 };

    for mode in [RolloutMode::Dense, RolloutMode::SparseRl(Method::RKv)] {
        println!("\n-- rollout mode: {} --", mode.label());
        let ro = RolloutEngine::new(&engine, mode, sampling);
        let chunk: Vec<(usize, &Task)> = tasks.iter().enumerate().map(|(i, t)| (i, t)).collect();
        let seqs = ro.rollout_chunk(&state.params, &chunk, &mut rng)?;
        for (seq, task) in seqs.iter().zip(tasks.iter()) {
            println!(
                "  {}  (answer {})\n    -> {:?}\n    reward {}  len {}  compressions {}  KV saved {:.0}%",
                task.prompt_text,
                task.answer,
                tokenizer::decode(&seq.response_ids),
                task.reward(&seq.response_ids),
                seq.response_ids.len(),
                seq.accounting.compressions,
                100.0 * seq.accounting.toks_saving(),
            );
        }
    }

    println!("\nper-artifact latency:");
    for (name, calls, ns) in engine.latency_report() {
        println!(
            "  {:<18} {:>5} calls  {:>12}",
            name,
            calls,
            sparse_rl::util::bench::fmt_ns(ns)
        );
    }
    Ok(())
}
