//! Table 1 harness: main results over the 7 benchmarks.
//!
//! Rows per model scale: Base, GRPO-Dense, GRPO naive:<m>, +Sparse-RL:<m>
//! for m in {R-KV, SnapKV}, with the Avg column and Toks.saving — the same
//! row layout as the paper's Table 1.
//!
//!     cargo run --release --example table1_main -- \
//!         [--models nano,tiny] [--rl-steps 40] [--eval-limit 30] [--seed 0]
//!
//! Full paper scale (4 models x 400 steps x full benchmarks) is the same
//! command with --models nano,tiny,small,base --rl-steps 400
//! --eval-limit 0; defaults are scaled down to run on this testbed
//! (EXPERIMENTS.md records which setting produced the committed numbers).

use anyhow::Result;

use sparse_rl::config::{ExperimentConfig, RolloutMode};
use sparse_rl::coordinator::{EvalOptions, EvalResult};
use sparse_rl::experiments;
use sparse_rl::runtime::{Method, ModelEngine, TrainState};
use sparse_rl::util::cli::CliArgs;

struct Row {
    label: String,
    accs: Vec<f64>,
    avg: f64,
    toks_saving: Option<f64>,
}

fn eval_row(
    engine: &ModelEngine,
    label: &str,
    params: &[f32],
    limit: usize,
    seed: u64,
    toks_saving: Option<f64>,
) -> Result<Row> {
    let (results, avg): (Vec<EvalResult>, f64) =
        experiments::eval_checkpoint(engine, params, RolloutMode::Dense, limit, seed,
                                     &EvalOptions::default())?;
    Ok(Row {
        label: label.to_string(),
        accs: results.iter().map(|r| r.accuracy).collect(),
        avg,
        toks_saving,
    })
}

fn train_mode(
    engine: &ModelEngine,
    base: &TrainState,
    mode: RolloutMode,
    rl_steps: usize,
    seed: u64,
) -> Result<(TrainState, f64)> {
    let mut cfg = ExperimentConfig::new(&engine.manifest.dir);
    cfg.seed = seed;
    cfg.mode = mode;
    cfg.train.steps = rl_steps;
    cfg.out_dir = format!("runs/table1/{}", engine.manifest.config.name).into();
    let trainer = experiments::run_rl(engine, cfg, base.clone(), 0)?;
    let saving = trainer.metrics.tail_mean("toks_saving", rl_steps.max(1));
    experiments::save_run(&trainer, &mode.label().replace(':', "-"))?;
    Ok((trainer.state, saving))
}

fn main() -> Result<()> {
    let args = CliArgs::from_env();
    let models: Vec<String> = args
        .get("models", "nano,tiny".to_string())
        .split(',')
        .map(str::to_string)
        .collect();
    let rl_steps = args.get("rl-steps", 40usize);
    let limit = args.get("eval-limit", 30usize);
    let seed = args.get("seed", 0u64);
    let methods = [Method::RKv, Method::SnapKv];

    let suite = experiments::suite();
    let names: Vec<&str> = suite.iter().map(|b| b.name).collect();

    for model in &models {
        let dir = experiments::find_artifacts(model)?;
        let engine = ModelEngine::load(&dir)?;
        let base = experiments::load_or_pretrain_base(
            &engine,
            experiments::default_pretrain_steps(model),
            seed,
        )?;

        let mut rows: Vec<Row> = Vec::new();
        rows.push(eval_row(&engine, "Base", &base.params, limit, seed, None)?);

        let (dense_state, _) =
            train_mode(&engine, &base, RolloutMode::Dense, rl_steps, seed)?;
        rows.push(eval_row(&engine, "GRPO Dense", &dense_state.params, limit, seed, None)?);

        for method in methods {
            let (naive, _) =
                train_mode(&engine, &base, RolloutMode::NaiveSparse(method), rl_steps, seed)?;
            rows.push(eval_row(
                &engine,
                &format!("GRPO naive w/ {}", method.name()),
                &naive.params,
                limit,
                seed,
                None,
            )?);
            let (ours, saving) =
                train_mode(&engine, &base, RolloutMode::SparseRl(method), rl_steps, seed)?;
            rows.push(eval_row(
                &engine,
                &format!("+Sparse-RL w/ {}", method.name()),
                &ours.params,
                limit,
                seed,
                Some(saving),
            )?);
        }

        // ---- print the table --------------------------------------------
        println!("\n=== Table 1 ({model}) — rl_steps={rl_steps} eval_limit={limit} ===");
        print!("{:<22}", "Rollout");
        for n in &names {
            print!(" {n:>8}");
        }
        println!(" {:>8} {:>10}", "Avg.", "Toks.sav");
        for row in &rows {
            print!("{:<22}", row.label);
            for a in &row.accs {
                print!(" {:>8.3}", a);
            }
            match row.toks_saving {
                Some(s) => println!(" {:>8.3} {:>9.1}%", row.avg, 100.0 * s),
                None => println!(" {:>8.3} {:>10}", row.avg, "-"),
            }
        }
    }
    Ok(())
}
