//! Figures 5 & 6 harness: rejection-rate and clip-ratio dynamics during
//! GRPO + Sparse-RL training (paper Appendix C).
//!
//!     cargo run --release --example fig56_dynamics -- \
//!         [--model tiny] [--steps 60] [--method rkv]
//!
//! Paper reference points: mean rejection ratio ≈ 0.07 (fluctuating
//! 0.05-0.11), clip ratio ≈ 5e-4. Reuses the fig2 CSV when present.

use std::path::PathBuf;

use anyhow::Result;

use sparse_rl::config::{ExperimentConfig, RolloutMode};
use sparse_rl::coordinator::Metrics;
use sparse_rl::experiments;
use sparse_rl::runtime::{Method, ModelEngine};
use sparse_rl::util::cli::CliArgs;

fn main() -> Result<()> {
    let args = CliArgs::from_env();
    let model = args.get("model", "tiny".to_string());
    let steps = args.get("steps", 60usize);
    let method = Method::parse(&args.get("method", "rkv".to_string()))?;
    let seed = args.get("seed", 0u64);

    let tag = format!("sparse-rl-{}", method.name());
    let reuse = ["figs", "table1"]
        .into_iter()
        .map(|root| PathBuf::from(format!("runs/{root}/{model}/{tag}-metrics.csv")))
        .find(|p| p.exists());
    let metrics = if let Some(csv) = reuse {
        println!("reusing {}", csv.display());
        Metrics::read_csv(&csv)?
    } else {
        let dir = experiments::find_artifacts(&model)?;
        let engine = ModelEngine::load(&dir)?;
        let base = experiments::load_or_pretrain_base(
            &engine,
            experiments::default_pretrain_steps(&model),
            seed,
        )?;
        let mut cfg = ExperimentConfig::new(&dir);
        cfg.apply_cli(&args)?;
        cfg.seed = seed;
        cfg.mode = RolloutMode::SparseRl(method);
        cfg.train.steps = steps;
        cfg.out_dir = format!("runs/figs/{model}").into();
        let trainer = experiments::run_rl(&engine, cfg, base, 10)?;
        experiments::save_run(&trainer, &tag)?;
        trainer.metrics
    };

    println!("\n=== Figure 5: rejection-rate dynamics ({model}, {}) ===", method.name());
    experiments::print_series(&metrics, "rejection_rate", 15);
    let mean_rej = metrics.tail_mean("rejection_rate", usize::MAX);
    println!("  mean rejection rate: {mean_rej:.4}   (paper: ≈0.07)");

    println!("\n=== Figure 6: clip-ratio dynamics ===");
    experiments::print_series(&metrics, "clip_frac", 15);
    let mean_clip = metrics.tail_mean("clip_frac", usize::MAX);
    println!("  mean clip ratio: {mean_clip:.2e}   (paper: ≈5e-4)");

    println!(
        "\nshape check: rejection stays a small minority of trajectories \
         (most sparse rollouts are consistent); clipping stays negligible \
         (reweighting keeps updates inside the trust region)."
    );
    Ok(())
}
