//! Table 2 harness: superiority in sparse inference (paper §5.4).
//!
//! Both checkpoints — GRPO-Dense-trained and GRPO+Sparse-RL-trained — are
//! evaluated under the SAME KV compression used during Sparse-RL training
//! (R-KV at the training budget). The paper's claim: Sparse-RL training
//! internalizes the compression logic ("sparsity-aware training"), so it
//! wins when deployment is memory-constrained.
//!
//!     cargo run --release --example table2_sparse_inference -- \
//!         [--model tiny] [--rl-steps 40] [--eval-limit 30] [--method rkv]
//!
//! Reuses runs/table1/<model>/{dense,sparse-rl-<m>}.srl checkpoints when
//! present (run table1_main first to avoid re-training).

use std::path::PathBuf;

use anyhow::Result;

use sparse_rl::config::{ExperimentConfig, RolloutMode};
use sparse_rl::coordinator::EvalOptions;
use sparse_rl::experiments;
use sparse_rl::runtime::{params, Method, ModelEngine, TrainState};
use sparse_rl::util::cli::CliArgs;

fn get_checkpoint(
    engine: &ModelEngine,
    args: &CliArgs,
    mode: RolloutMode,
    model: &str,
    rl_steps: usize,
    seed: u64,
) -> Result<TrainState> {
    let tag = mode.label().replace(':', "-");
    let path = PathBuf::from(format!("runs/table1/{model}/{tag}.srl"));
    if path.exists() {
        println!("reusing checkpoint {}", path.display());
        let (_, s) = params::load(&path, engine.manifest.config.n_params)?;
        return Ok(s);
    }
    let base = experiments::load_or_pretrain_base(
        engine,
        experiments::default_pretrain_steps(model),
        seed,
    )?;
    let mut cfg = ExperimentConfig::new(&engine.manifest.dir);
    cfg.apply_cli(args)?;
    cfg.seed = seed;
    cfg.mode = mode;
    cfg.train.steps = rl_steps;
    cfg.out_dir = format!("runs/table1/{model}").into();
    let trainer = experiments::run_rl(engine, cfg, base, 10)?;
    experiments::save_run(&trainer, &tag)?;
    Ok(trainer.state)
}

fn main() -> Result<()> {
    let args = CliArgs::from_env();
    let model = args.get("model", "tiny".to_string());
    let rl_steps = args.get("rl-steps", 40usize);
    let limit = args.get("eval-limit", 30usize);
    let method = Method::parse(&args.get("method", "rkv".to_string()))?;
    let seed = args.get("seed", 0u64);

    let dir = experiments::find_artifacts(&model)?;
    let engine = ModelEngine::load(&dir)?;

    let dense_ckpt =
        get_checkpoint(&engine, &args, RolloutMode::Dense, &model, rl_steps, seed)?;
    let sparse_ckpt = get_checkpoint(
        &engine,
        &args,
        RolloutMode::SparseRl(method),
        &model,
        rl_steps,
        seed,
    )?;

    // deploy BOTH under compressed inference (the paper's Table 2 setting)
    let deploy_mode = RolloutMode::SparseRl(method);
    println!("\nGRPO (Dense)-trained model under sparse inference ({}):", method.name());
    let (dense_rows, dense_avg) =
        experiments::eval_checkpoint(&engine, &dense_ckpt.params, deploy_mode, limit, seed,
                                     &EvalOptions::default())?;
    println!("\nSparse-RL ({})-trained model under sparse inference:", method.name());
    let (ours_rows, ours_avg) =
        experiments::eval_checkpoint(&engine, &sparse_ckpt.params, deploy_mode, limit, seed,
                                     &EvalOptions::default())?;

    println!(
        "\n=== Table 2 ({model}) — sparse inference w/ {} @ budget {} ===",
        method.name(),
        engine.manifest.shapes.budget
    );
    print!("{:<26}", "Trained via");
    for r in &dense_rows {
        print!(" {:>8}", r.benchmark);
    }
    println!(" {:>8}", "Avg.");
    print!("{:<26}", "GRPO (Dense)");
    for r in &dense_rows {
        print!(" {:>8.3}", r.accuracy);
    }
    println!(" {dense_avg:>8.3}");
    print!("{:<26}", format!("+Sparse-RL ({})", method.name()));
    for r in &ours_rows {
        print!(" {:>8.3}", r.accuracy);
    }
    println!(" {ours_avg:>8.3}");
    println!(
        "\nshape check (paper: Sparse-RL wins under sparse deployment): {}",
        if ours_avg >= dense_avg { "HOLDS" } else { "does not hold at this scale" }
    );
    Ok(())
}
