//! Figure 2 harness: training curves — GRPO-Dense vs GRPO + Sparse-RL
//! (average reward, response length, policy entropy), paper §5.3.
//!
//!     cargo run --release --example fig2_curves -- \
//!         [--model tiny] [--steps 60] [--method rkv]
//!
//! Writes the full series to runs/figs/<model>/{dense,sparse-rl-<m>}-metrics.csv
//! (which fig3_mismatch_kl and fig56_dynamics reuse) and prints bucketed
//! terminal plots.

use anyhow::Result;

use sparse_rl::config::{ExperimentConfig, RolloutMode};
use sparse_rl::experiments;
use sparse_rl::runtime::{Method, ModelEngine};
use sparse_rl::util::cli::CliArgs;

fn main() -> Result<()> {
    let args = CliArgs::from_env();
    let model = args.get("model", "tiny".to_string());
    let steps = args.get("steps", 60usize);
    let method = Method::parse(&args.get("method", "rkv".to_string()))?;
    let seed = args.get("seed", 0u64);

    let dir = experiments::find_artifacts(&model)?;
    let engine = ModelEngine::load(&dir)?;
    let base = experiments::load_or_pretrain_base(
        &engine,
        experiments::default_pretrain_steps(&model),
        seed,
    )?;

    let mut runs = Vec::new();
    for mode in [RolloutMode::Dense, RolloutMode::SparseRl(method)] {
        let tag = mode.label().replace(':', "-");
        let reuse = [
            format!("runs/figs/{model}/{tag}-metrics.csv"),
            format!("runs/table1/{model}/{tag}-metrics.csv"),
        ]
        .into_iter()
        .map(std::path::PathBuf::from)
        .find(|p| p.exists());
        if let Some(csv) = reuse {
            println!("reusing {}", csv.display());
            runs.push((mode.label(), sparse_rl::coordinator::Metrics::read_csv(&csv)?));
            continue;
        }
        println!("\n-- training {} for {steps} steps --", mode.label());
        let mut cfg = ExperimentConfig::new(&dir);
        cfg.apply_cli(&args)?;
        cfg.seed = seed;
        cfg.mode = mode;
        cfg.train.steps = steps;
        cfg.out_dir = format!("runs/figs/{model}").into();
        let trainer = experiments::run_rl(&engine, cfg, base.clone(), 10)?;
        let (csv, _) = experiments::save_run(&trainer, &mode.label().replace(':', "-"))?;
        println!("series -> {}", csv.display());
        runs.push((mode.label(), trainer.metrics));
    }

    println!("\n=== Figure 2: training curves ({model}, {}) ===", method.name());
    for series in ["reward", "response_len", "entropy"] {
        println!("\n[{series}]");
        for (label, metrics) in &runs {
            print!("  {label:<18}");
            experiments::print_series(metrics, series, 12);
        }
    }
    println!(
        "\npaper-shape checks: sparse reward slightly below dense but stable; \
         sparse length spikes early then converges; sparse entropy decays slower."
    );
    Ok(())
}
