//! Figure 3 harness: mismatch KL between rollout (sampler) and training
//! (dense) policies, GRPO-Dense vs GRPO + Sparse-RL (paper §5.3).
//!
//!     cargo run --release --example fig3_mismatch_kl -- \
//!         [--model tiny] [--steps 60] [--method rkv]
//!
//! Reuses runs/figs/<model>/*.csv from fig2_curves when present (run that
//! first); otherwise trains both modes itself. The paper's shape: sparse
//! starts ~10x higher (1e-3 vs 1e-4) and converges as the learner adapts
//! to the compression logic.

use std::path::PathBuf;

use anyhow::Result;

use sparse_rl::config::{ExperimentConfig, RolloutMode};
use sparse_rl::coordinator::Metrics;
use sparse_rl::experiments;
use sparse_rl::runtime::{Method, ModelEngine};
use sparse_rl::util::cli::CliArgs;

fn load_or_train(
    engine: &ModelEngine,
    args: &CliArgs,
    mode: RolloutMode,
    model: &str,
    steps: usize,
    seed: u64,
) -> Result<Metrics> {
    let tag = mode.label().replace(':', "-");
    for root in ["figs", "table1"] {
        let csv = PathBuf::from(format!("runs/{root}/{model}/{tag}-metrics.csv"));
        if csv.exists() {
            println!("reusing {}", csv.display());
            return Metrics::read_csv(&csv);
        }
    }
    let dir = experiments::find_artifacts(model)?;
    let base = experiments::load_or_pretrain_base(
        engine,
        experiments::default_pretrain_steps(model),
        seed,
    )?;
    let mut cfg = ExperimentConfig::new(&dir);
    cfg.apply_cli(args)?;
    cfg.seed = seed;
    cfg.mode = mode;
    cfg.train.steps = steps;
    cfg.out_dir = format!("runs/figs/{model}").into();
    let trainer = experiments::run_rl(engine, cfg, base, 10)?;
    experiments::save_run(&trainer, &mode.label().replace(':', "-"))?;
    Ok(trainer.metrics)
}

fn main() -> Result<()> {
    let args = CliArgs::from_env();
    let model = args.get("model", "tiny".to_string());
    let steps = args.get("steps", 60usize);
    let method = Method::parse(&args.get("method", "rkv".to_string()))?;
    let seed = args.get("seed", 0u64);
    let dir = experiments::find_artifacts(&model)?;
    let engine = ModelEngine::load(&dir)?;

    let dense = load_or_train(&engine, &args, RolloutMode::Dense, &model, steps, seed)?;
    let sparse =
        load_or_train(&engine, &args, RolloutMode::SparseRl(method), &model, steps, seed)?;

    println!("\n=== Figure 3: mismatch KL(π_sampler ‖ π_old) ({model}) ===");
    println!("  dense baseline (engine-numerics mismatch only):");
    experiments::print_series(&dense, "mismatch_kl", 12);
    println!("  sparse-rl:{} (compression-induced mismatch):", method.name());
    experiments::print_series(&sparse, "mismatch_kl", 12);

    let d_mean = dense.tail_mean("mismatch_kl", steps);
    let s_early: f64 = sparse
        .series("mismatch_kl")
        .iter()
        .take((steps / 4).max(1))
        .filter(|v| !v.is_nan())
        .sum::<f64>()
        / (steps / 4).max(1) as f64;
    let s_late = sparse.tail_mean("mismatch_kl", (steps / 4).max(1));
    println!("\nshape check (paper: sparse ≫ dense early, then decays):");
    println!("  dense mean       {d_mean:.3e}");
    println!("  sparse early     {s_early:.3e}");
    println!("  sparse late      {s_late:.3e}");
    println!(
        "  ratio sparse/dense early: {:.1}x, late: {:.1}x",
        s_early / d_mean.abs().max(1e-12),
        s_late / d_mean.abs().max(1e-12)
    );
    Ok(())
}
