#!/usr/bin/env python3
"""Bench-trajectory regression guard for BENCH_rollout.json.

Compares a freshly produced bench file against the committed trajectory
(recorded by the CI commit-back step on main pushes) and fails when any
DETERMINISTIC modeled makespan regressed by more than the threshold.

Rules:
  * Only dicts carrying a "makespan_ticks" key are compared, and only
    when their "deterministic" flag is absent or true (multi-worker rows
    race on the mutex run-to-run and are recorded for context only).
  * Scenarios present in the baseline but no longer emitted are noted,
    not failed (scenarios evolve; the recorder refreshes the baseline on
    the next main push).
  * Scenarios only in the FRESH file (a newly added bench part, e.g.
    part 1i's `chunked_prefill` monolithic/chunked rows on the PR that
    introduced them) are listed as new and pass —
    comparison iterates baseline keys only, so growing the bench never
    trips the guard; the recorder picks the new rows up on the next
    main push.
  * An unpopulated baseline (the "pending" placeholder committed before
    the first record step ran) skips the guard entirely.

Usage: bench_guard.py <committed-baseline.json> <fresh.json> [threshold]
Threshold is a fraction; default 0.10 (= fail on >10% regression).
"""

import json
import sys


def walk(node, path=()):
    """Yield (path, makespan) for every comparable deterministic row."""
    if not isinstance(node, dict):
        return
    if "makespan_ticks" in node and node.get("deterministic", True) is not False:
        yield path, float(node["makespan_ticks"])
    for key, value in node.items():
        yield from walk(value, path + (key,))


def main():
    if len(sys.argv) < 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        baseline = json.load(f)
    with open(sys.argv[2]) as f:
        fresh = json.load(f)
    threshold = float(sys.argv[3]) if len(sys.argv) > 3 else 0.10

    base_rows = dict(walk(baseline))
    if not base_rows:
        print(
            "bench guard: baseline has no recorded makespans yet "
            "(pending the first main-push record step); skipping"
        )
        return 0
    fresh_rows = dict(walk(fresh))

    failures = []
    compared = 0
    for path, base in sorted(base_rows.items()):
        name = "/".join(path)
        got = fresh_rows.get(path)
        if got is None:
            print(f"bench guard: note: scenario {name} no longer emitted; skipping")
            continue
        compared += 1
        if base > 0 and got > base * (1.0 + threshold):
            failures.append(
                f"  {name}: {got:.0f} ticks vs baseline {base:.0f} "
                f"(+{100.0 * (got / base - 1.0):.1f}%)"
            )
        else:
            delta = 100.0 * (got / base - 1.0) if base > 0 else 0.0
            print(f"bench guard: {name}: {got:.0f} vs {base:.0f} ({delta:+.1f}%) ok")

    for path in sorted(set(fresh_rows) - set(base_rows)):
        name = "/".join(path)
        print(
            f"bench guard: new scenario {name}: {fresh_rows[path]:.0f} ticks "
            "(not in baseline yet; recorded on the next main push)"
        )

    if failures:
        print(
            f"bench guard: FAIL — modeled makespan regressed >"
            f"{100.0 * threshold:.0f}% on {len(failures)} scenario(s):"
        )
        print("\n".join(failures))
        return 1
    print(f"bench guard: {compared} deterministic makespans within +{100.0 * threshold:.0f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
