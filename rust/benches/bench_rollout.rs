//! Rollout-path benches: engine comparison + component latency.
//!
//! Part 1 (always runs, no artifacts needed): static chunked vs continuous
//! slot-recycling engines head-to-head on the deterministic mock backend
//! under a skewed response-length workload — decode steps, decode-step
//! slot occupancy, idle fraction, refills. Both engines are verified to
//! emit token-identical sequences before the numbers are printed.
//!
//! Part 1c: pipelined vs continuous on the mock latency cost model
//! (`CostModel::representative`, virtual-clock ticks): dense + sparse,
//! worst-case + paged admission, worker counts 1/2/4. Asserts the
//! pipelined engine's modeled makespan is STRICTLY below the continuous
//! engine's — at one worker the win is pure prefill/decode overlap (the
//! dedicated prefill lane), at 2/4 it compounds with multi-lane decode.
//!
//! Part 1d: fifo vs shortest-first admission order on a deterministic
//! skewed-length workload (pipelined, paged, sparse): one giant-prompt
//! task planted behind a short one head-of-line-blocks the whole fifo
//! queue at the memory wall, while shortest-first packs every cheap task
//! wide first and runs the giant last. Asserts shortest-first's modeled
//! makespan is STRICTLY below fifo's with token-identical outputs.
//!
//! Part 1i: monolithic vs chunked prefill (`prefill-chunk-tokens`) on a
//! long-prompt continuous workload: token-budgeted device steps must
//! strictly lower both the modeled makespan and the per-step tick bound
//! (`max_step_ticks`) while staying token-identical.
//!
//! Part 1j: SLO vs FIFO serving admission on a deterministic flash-crowd
//! trace (warmup + an infeasible burst + a feasible late wave): the SLO
//! controller sheds the burst up front with estimates and keeps the
//! modeled p99 TTFT of everything it serves strictly below the
//! admit-everything FIFO baseline, token-identical to the closed batch.
//!
//! Part 2 (needs `make artifacts`): every artifact on the rollout/training
//! path — decode step latency (dense vs sparse — the memory-wall compute
//! story), compression overhead per method, prefill, dense scoring, and
//! the RL train step. Backs the §Perf numbers in EXPERIMENTS.md.
//!
//!     cargo bench --bench bench_rollout [-- --model nano]

use std::collections::BTreeMap;

use sparse_rl::config::{
    AdmissionOrder, AdmissionPolicy, EngineKind, FaultPolicy, PrefillMode, PrefixSharing,
    RolloutMode, SamplingConfig, ServeAdmission, ServeConfig,
};
use sparse_rl::coordinator::{
    rollout_fleet, CostModel, FaultKind, FaultOp, FaultPlan, GenSeq, KvMemoryManager,
    MockModelBackend, Replica, RolloutBackend, RolloutCtx, RolloutPolicy, RolloutStats, Scheduler,
    ServeOutcome, ServeRequest, ServeServer,
};
use sparse_rl::data::task::Task;
use sparse_rl::experiments;
use sparse_rl::runtime::{Hyp, Method, ModelEngine, ParamsLit, TrainState, Variant};
use sparse_rl::util::bench::Bencher;
use sparse_rl::util::cli::CliArgs;
use sparse_rl::util::json::Json;
use sparse_rl::util::rng::Rng;

fn mk_sched(slots: usize, reserve: usize) -> Scheduler {
    Scheduler::worst_case(slots, reserve)
}

fn run_static_mock(
    policy: &RolloutPolicy,
    backend: &mut MockModelBackend,
    tasks: &[Task],
    seed: u64,
    reserve: usize,
    kv_cap: usize,
) -> (Vec<GenSeq>, RolloutStats) {
    let mut kv = KvMemoryManager::new(kv_cap);
    let mut sched = mk_sched(backend.slots(), reserve);
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    policy
        .rollout_static_queue(backend, &flat, seed, RolloutCtx::new(&mut sched, &mut kv))
        .expect("rollout")
}

fn run_continuous_mock(
    policy: &RolloutPolicy,
    backend: &mut MockModelBackend,
    tasks: &[Task],
    seed: u64,
    reserve: usize,
    kv_cap: usize,
) -> (Vec<GenSeq>, RolloutStats) {
    let mut kv = KvMemoryManager::new(kv_cap);
    let mut sched = mk_sched(backend.slots(), reserve);
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    policy
        .rollout_continuous(backend, &flat, seed, RolloutCtx::new(&mut sched, &mut kv))
        .expect("rollout")
}

fn run_continuous_paged_mock(
    policy: &RolloutPolicy,
    backend: &mut MockModelBackend,
    tasks: &[Task],
    seed: u64,
    reserve: usize,
    kv_cap: usize,
    page_tokens: usize,
) -> (Vec<GenSeq>, RolloutStats, KvMemoryManager) {
    let mut kv = KvMemoryManager::with_pages(kv_cap, page_tokens);
    let mut sched =
        mk_sched(backend.slots(), reserve).with_admission(AdmissionPolicy::Paged);
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    let (seqs, stats) = policy
        .rollout_continuous(backend, &flat, seed, RolloutCtx::new(&mut sched, &mut kv))
        .expect("rollout");
    (seqs, stats, kv)
}

/// Static vs continuous on the mock model: the long-tail-bubble numbers.
fn engine_comparison() {
    let (slots, prompt_len, max_seq, budget, buffer) = (8usize, 24usize, 160usize, 28usize, 8usize);
    let n_tasks = 64;
    let seed = 7u64;
    let mut rng = Rng::new(1);
    let tasks: Vec<Task> = (0..n_tasks)
        .map(|_| {
            let ops = 1 + rng.below(2);
            Task::gen(&mut rng, ops, prompt_len)
        })
        .collect();
    let sampling = SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 64 };

    println!(
        "== engine comparison: static vs continuous (mock model, R={slots}, {n_tasks} tasks, \
         skewed lengths) =="
    );
    println!(
        "{:<16} {:<11} {:>12} {:>10} {:>7} {:>8} {:>9}",
        "mode", "engine", "decode-steps", "occupancy", "idle%", "refills", "prefills"
    );

    for mode in [RolloutMode::Dense, RolloutMode::SparseRl(Method::RKv)] {
        let policy = RolloutPolicy::new(mode, sampling);
        let capacity = if mode.is_sparse() { budget + buffer } else { max_seq };
        let reserve = capacity;
        let kv_cap = reserve * slots * 4; // slot-limited: isolate the bubble
        let backend = || {
            let mut b = if mode.is_sparse() {
                MockModelBackend::sparse(slots, prompt_len, max_seq, 32, budget, buffer)
            } else {
                MockModelBackend::dense(slots, prompt_len, max_seq, 32)
            };
            b.eos_pull = 0.12; // long-tailed response lengths
            b
        };

        let (stat_seqs, ss) =
            run_static_mock(&policy, &mut backend(), &tasks, seed, reserve, kv_cap);
        let (cont_seqs, cs) =
            run_continuous_mock(&policy, &mut backend(), &tasks, seed, reserve, kv_cap);

        // engines must agree token-for-token before the numbers mean anything
        let agree = stat_seqs
            .iter()
            .zip(cont_seqs.iter())
            .all(|(a, b)| a.response_ids == b.response_ids && a.sampler_logp == b.sampler_logp);
        let mut lens: Vec<usize> = stat_seqs.iter().map(|s| s.response_ids.len()).collect();
        lens.sort_unstable();

        for (engine, st) in [("static", &ss), ("continuous", &cs)] {
            println!(
                "{:<16} {:<11} {:>12} {:>10.3} {:>6.1}% {:>8} {:>9}",
                mode.label(),
                engine,
                st.decode_steps,
                st.occupancy(),
                100.0 * st.idle_frac(),
                st.refills,
                st.prefills + st.slot_prefills,
            );
        }
        let saved = 1.0 - cs.decode_steps as f64 / ss.decode_steps.max(1) as f64;
        println!(
            "  -> lengths p0/p50/p100 = {}/{}/{}: continuous saves {:.1}% decode steps, \
             token-identical outputs: {}",
            lens.first().unwrap(),
            lens[lens.len() / 2],
            lens.last().unwrap(),
            100.0 * saved,
            if agree { "yes" } else { "NO (BUG)" },
        );
        assert!(agree, "engines diverged on the bench workload");
        if lens.first() != lens.last() {
            assert!(
                cs.decode_steps < ss.decode_steps,
                "continuous must need strictly fewer decode steps under skew"
            );
        }
    }
    println!();
}

/// Paged vs worst-case admission head-to-head on the continuous engine
/// (mock model, skewed lengths): the tentpole claim is that admitting by
/// *actual* residency strictly raises admitted width and lowers decode
/// steps under the same wall, with identical tokens. Returns the JSON rows
/// for BENCH_rollout.json (the CI perf trajectory).
fn paged_comparison() -> Json {
    let (slots, prompt_len, max_seq, budget, buffer) = (8usize, 16usize, 160usize, 40usize, 16usize);
    let (n_tasks, seed, page_tokens) = (64usize, 7u64, 4usize);
    let mut rng = Rng::new(1);
    let tasks: Vec<Task> = (0..n_tasks)
        .map(|_| {
            let ops = 1 + rng.below(2);
            Task::gen(&mut rng, ops, prompt_len)
        })
        .collect();
    let sampling = SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 64 };

    println!(
        "== admission comparison: worst-case vs paged (continuous engine, mock model, \
         R={slots}, {n_tasks} tasks, page={page_tokens} tok) =="
    );
    println!(
        "{:<16} {:<11} {:>12} {:>10} {:>10} {:>9} {:>8}",
        "mode", "admission", "decode-steps", "width-peak", "occupancy", "preempts", "pages"
    );

    let mut out = BTreeMap::new();
    for mode in [RolloutMode::Dense, RolloutMode::SparseRl(Method::RKv)] {
        let policy = RolloutPolicy::new(mode, sampling);
        let capacity = if mode.is_sparse() { budget + buffer } else { max_seq };
        let reserve = capacity;
        // memory-limited wall: worst-case admission fits 3 sequences
        let kv_cap = reserve * 3;
        let backend = || {
            let mut b = if mode.is_sparse() {
                MockModelBackend::sparse(slots, prompt_len, max_seq, 32, budget, buffer)
            } else {
                MockModelBackend::dense(slots, prompt_len, max_seq, 32)
            };
            b.eos_pull = 0.15; // long-tailed response lengths
            b
        };

        let (wc_seqs, wc) =
            run_continuous_mock(&policy, &mut backend(), &tasks, seed, reserve, kv_cap);
        let (pg_seqs, pg, kv) = run_continuous_paged_mock(
            &policy,
            &mut backend(),
            &tasks,
            seed,
            reserve,
            kv_cap,
            page_tokens,
        );

        // identical tokens under either admission policy (per-task RNG)
        let agree = wc_seqs
            .iter()
            .zip(pg_seqs.iter())
            .all(|(a, b)| a.response_ids == b.response_ids && a.sampler_logp == b.sampler_logp);
        assert!(agree, "admission policy changed tokens (BUG)");
        kv.check_invariants().expect("wall invariants");
        assert_eq!(kv.reserved(), 0, "paged run leaked KV");

        let mut obj = BTreeMap::new();
        for (admission, st) in [("worst_case", &wc), ("paged", &pg)] {
            println!(
                "{:<16} {:<11} {:>12} {:>10} {:>10.3} {:>9} {:>8}",
                mode.label(),
                admission,
                st.decode_steps,
                st.peak_live_slots,
                st.occupancy(),
                st.preemptions,
                st.max_used_pages,
            );
            let mut row = BTreeMap::new();
            row.insert("decode_steps".into(), Json::Num(st.decode_steps as f64));
            row.insert("peak_live_slots".into(), Json::Num(st.peak_live_slots as f64));
            row.insert("occupancy".into(), Json::Num(st.occupancy()));
            row.insert("preemptions".into(), Json::Num(st.preemptions as f64));
            row.insert("max_used_pages".into(), Json::Num(st.max_used_pages as f64));
            row.insert("max_reserved_kv".into(), Json::Num(st.max_reserved_kv as f64));
            obj.insert(admission.to_string(), Json::Obj(row));
        }
        let saved = 1.0 - pg.decode_steps as f64 / wc.decode_steps.max(1) as f64;
        println!(
            "  -> paged admits {}x wider at peak, saves {:.1}% decode steps \
             ({} preemptions), token-identical: yes",
            pg.peak_live_slots as f64 / wc.peak_live_slots.max(1) as f64,
            100.0 * saved,
            pg.preemptions,
        );
        assert!(
            pg.peak_live_slots > wc.peak_live_slots,
            "paged admission must admit strictly wider ({} !> {})",
            pg.peak_live_slots,
            wc.peak_live_slots
        );
        assert!(
            pg.decode_steps < wc.decode_steps,
            "paged admission must need strictly fewer decode steps ({} !< {})",
            pg.decode_steps,
            wc.decode_steps
        );
        obj.insert("kv_cap_tokens".into(), Json::Num(kv_cap as f64));
        obj.insert("reserve_per_seq".into(), Json::Num(reserve as f64));
        out.insert(mode.label(), Json::Obj(obj));
    }
    out.insert("page_tokens".into(), Json::Num(page_tokens as f64));
    out.insert("tasks".into(), Json::Num(n_tasks as f64));
    println!();
    Json::Obj(out)
}

#[allow(clippy::too_many_arguments)]
fn run_pipelined_mock(
    policy: &RolloutPolicy,
    proto: &MockModelBackend,
    tasks: &[Task],
    seed: u64,
    reserve: usize,
    kv_cap: usize,
    page_tokens: usize,
    admission: AdmissionPolicy,
    workers: usize,
) -> (Vec<GenSeq>, RolloutStats) {
    let mut kv = KvMemoryManager::with_pages(kv_cap, page_tokens);
    let mut sched = mk_sched(proto.slots(), reserve).with_admission(admission);
    let mut backends: Vec<MockModelBackend> = (0..workers).map(|_| proto.clone()).collect();
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    let (seqs, stats) = if policy.prefill.is_async() {
        let mut exec = proto.clone();
        policy
            .rollout_pipelined(
                &mut backends,
                Some(&mut exec),
                &flat,
                seed,
                RolloutCtx::new(&mut sched, &mut kv),
            )
            .expect("rollout")
    } else {
        policy
            .rollout_pipelined(&mut backends, None, &flat, seed, RolloutCtx::new(&mut sched, &mut kv))
            .expect("rollout")
    };
    assert_eq!(kv.reserved(), 0, "pipelined run leaked KV");
    kv.check_invariants().expect("wall invariants");
    (seqs, stats)
}

/// Pipelined vs continuous on the modeled latency clock: the tentpole
/// claim. Slot prefills stall the continuous engine's whole batch; the
/// pipelined engine hides them on a dedicated lane (and splits decode
/// across worker lanes), so its modeled makespan must be strictly lower —
/// dense + sparse, worst-case + paged, at 1/2/4 workers, with
/// token-identical outputs throughout. Runs `prefill = async`: the
/// dedicated-prefill-lane model this scenario has always used is now what
/// the executor thread physically implements, and the recorded
/// deterministic w=1 trajectory values are unchanged by the sync-mode
/// accounting fix (sync charges the worker's own lane — see
/// `prefill_mode_comparison` for that head-to-head). Returns JSON rows
/// for BENCH_rollout.json.
fn pipelined_comparison() -> Json {
    let (slots, prompt_len, max_seq, budget, buffer) = (8usize, 24usize, 160usize, 28usize, 8usize);
    let (n_tasks, seed, page_tokens) = (64usize, 7u64, 4usize);
    let costs = CostModel::representative();
    let mut rng = Rng::new(1);
    let tasks: Vec<Task> = (0..n_tasks)
        .map(|_| {
            let ops = 1 + rng.below(2);
            Task::gen(&mut rng, ops, prompt_len)
        })
        .collect();
    let sampling = SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 64 };

    println!(
        "== pipeline comparison: continuous vs pipelined (mock latency model, R={slots}, \
         {n_tasks} tasks, prefill={}t slot-prefill={}t decode={}t) ==",
        costs.prefill_ticks, costs.slot_prefill_ticks, costs.decode_ticks
    );
    println!(
        "{:<16} {:<11} {:<14} {:>12} {:>10} {:>10} {:>9}",
        "mode", "admission", "engine", "decode-steps", "makespan", "blocked", "speedup"
    );

    let mut out = BTreeMap::new();
    for mode in [RolloutMode::Dense, RolloutMode::SparseRl(Method::RKv)] {
        let policy = RolloutPolicy::new(mode, sampling).with_prefill(PrefillMode::Async);
        let capacity = if mode.is_sparse() { budget + buffer } else { max_seq };
        let reserve = capacity;
        // slot-limited wall: isolate the prefill-overlap + multi-lane
        // story from admission-width effects (paged_comparison covers
        // the memory-limited regime)
        let kv_cap = reserve * slots * 4;
        let proto = {
            let mut b = if mode.is_sparse() {
                MockModelBackend::sparse(slots, prompt_len, max_seq, 32, budget, buffer)
            } else {
                MockModelBackend::dense(slots, prompt_len, max_seq, 32)
            };
            b.eos_pull = 0.12; // long-tailed response lengths
            b.with_costs(costs)
        };

        for admission in [AdmissionPolicy::WorstCase, AdmissionPolicy::Paged] {
            let page = if admission == AdmissionPolicy::Paged { page_tokens } else { 1 };
            // continuous baseline on the same cost model + wall
            let (cont_seqs, cs) = {
                let mut kv = KvMemoryManager::with_pages(kv_cap, page);
                let mut sched = mk_sched(slots, reserve).with_admission(admission);
                let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
                policy
                    .rollout_continuous(
                        &mut proto.clone(),
                        &flat,
                        seed,
                        RolloutCtx::new(&mut sched, &mut kv),
                    )
                    .expect("rollout")
            };
            let label = format!("{}/{}", mode.label(), admission.label());
            let mut obj = BTreeMap::new();
            let mut row = BTreeMap::new();
            row.insert("decode_steps".into(), Json::Num(cs.decode_steps as f64));
            row.insert("makespan_ticks".into(), Json::Num(cs.modeled_makespan_ticks as f64));
            row.insert(
                "prefill_blocked_ticks".into(),
                Json::Num(cs.prefill_blocked_ticks as f64),
            );
            row.insert("decode_busy_ticks".into(), Json::Num(cs.decode_busy_ticks as f64));
            obj.insert("continuous".to_string(), Json::Obj(row));
            println!(
                "{:<16} {:<11} {:<14} {:>12} {:>10} {:>10} {:>9}",
                mode.label(),
                admission.label(),
                "continuous",
                cs.decode_steps,
                cs.modeled_makespan_ticks,
                cs.prefill_blocked_ticks,
                "1.00x"
            );

            for workers in [1usize, 2, 4] {
                let (pipe_seqs, ps) = run_pipelined_mock(
                    &policy, &proto, &tasks, seed, reserve, kv_cap, page, admission, workers,
                );
                let agree = cont_seqs.iter().zip(pipe_seqs.iter()).all(|(a, b)| {
                    a.response_ids == b.response_ids && a.sampler_logp == b.sampler_logp
                });
                assert!(agree, "pipelined diverged from continuous (BUG)");
                let speedup =
                    cs.modeled_makespan_ticks as f64 / ps.modeled_makespan_ticks.max(1) as f64;
                println!(
                    "{:<16} {:<11} {:<14} {:>12} {:>10} {:>10} {:>8.2}x",
                    mode.label(),
                    admission.label(),
                    format!("pipelined w={workers}"),
                    ps.decode_steps,
                    ps.modeled_makespan_ticks,
                    ps.prefill_blocked_ticks,
                    speedup
                );
                assert!(
                    ps.modeled_makespan_ticks < cs.modeled_makespan_ticks,
                    "{label} w={workers}: pipelined makespan {} !< continuous {}",
                    ps.modeled_makespan_ticks,
                    cs.modeled_makespan_ticks
                );
                let mut row = BTreeMap::new();
                row.insert("decode_steps".into(), Json::Num(ps.decode_steps as f64));
                row.insert(
                    "makespan_ticks".into(),
                    Json::Num(ps.modeled_makespan_ticks as f64),
                );
                row.insert(
                    "sched_stall_ticks".into(),
                    Json::Num(ps.sched_stall_ticks as f64),
                );
                row.insert("preemptions".into(), Json::Num(ps.preemptions as f64));
                row.insert("speedup".into(), Json::Num(speedup));
                // task-to-lane assignment is whoever wins the mutex, so
                // multi-worker numbers vary run-to-run (the strict-win
                // margin dwarfs that variance, but trajectory comparisons
                // should anchor on the deterministic w=1 row)
                row.insert("deterministic".into(), Json::Bool(workers == 1));
                obj.insert(format!("pipelined_w{workers}"), Json::Obj(row));
            }
            out.insert(label, Json::Obj(obj));
        }
    }
    out.insert("prefill_ticks".into(), Json::Num(costs.prefill_ticks as f64));
    out.insert(
        "slot_prefill_ticks".into(),
        Json::Num(costs.slot_prefill_ticks as f64),
    );
    out.insert("decode_ticks".into(), Json::Num(costs.decode_ticks as f64));
    out.insert("tasks".into(), Json::Num(n_tasks as f64));
    println!();
    Json::Obj(out)
}

/// Build a task whose prompt is exactly `prompt_tokens` long (mock-model
/// benches only: the deterministic mock hashes prompt CONTENT, rewards are
/// never read, so padding/truncating the prompt is safe and gives exact
/// control over predicted residency).
fn sized_task(rng: &mut Rng, prompt_tokens: usize) -> Task {
    let mut t = Task::gen(rng, 1, 48);
    while t.prompt_ids.len() < prompt_tokens {
        let fill = 3 + (t.prompt_ids.len() % 20) as i32; // in-vocab filler
        t.prompt_ids.push(fill);
    }
    t.prompt_ids.truncate(prompt_tokens.max(1));
    t
}

/// Fifo vs shortest-first admission order under pipelined + paged + sparse
/// on a deterministic skewed-length workload: the makespan-aware-admission
/// claim. One giant-prompt task (predicted residency = the full per-seq
/// bound; its prompt alone nearly fills the wall) sits at queue position 1
/// behind a single short task. Fifo head-of-line-blocks on it: the first
/// short runs the wall ALONE, then the giant runs alone, and only then do
/// the remaining shorts pack the batch. Shortest-first pops every short
/// first (they pair up across both slots) and leaves the giant for the
/// drained wall at the end — strictly less width-1 decoding, strictly
/// lower modeled makespan, identical tokens (per-task RNG).
///
/// Lengths are made deterministic by suppressing EOS (`eos_pull` very
/// negative): every response runs to its cap, so response length =
/// min(max_response, max_seq - prompt) — the giant's huge prompt forces a
/// SHORT response and the cheap prompts run LONG, the skewed-length
/// profile Sparrow-style sparse rollouts schedule around. The run is
/// single-worker, so both traces are fully deterministic.
fn admission_order_comparison() -> Json {
    let (slots, prompt_len, max_seq, budget, buffer) = (2usize, 48usize, 56usize, 44usize, 8usize);
    let (page_tokens, seed) = (4usize, 7u64);
    let costs = CostModel::representative();
    let mode = RolloutMode::SparseRl(Method::RKv);
    let sampling = SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 16 };
    // async prefill: the dedicated-lane timing model this scenario has
    // always recorded (sync would charge the worker lane and shift the
    // committed trajectory values)
    let policy = RolloutPolicy::new(mode, sampling).with_prefill(PrefillMode::Async);
    let reserve = budget + buffer; // 52-token bound = 13 pages
    let kv_cap = 56; // 14 pages: the giant (13 pages) ~owns the wall
    let mut rng = Rng::new(1);
    // queue order [short, GIANT, short x5]: the fifo poison placement
    let tasks: Vec<Task> = (0..7)
        .map(|i| sized_task(&mut rng, if i == 1 { prompt_len } else { 4 }))
        .collect();
    let proto = {
        let mut b = MockModelBackend::sparse(slots, prompt_len, max_seq, 32, budget, buffer);
        b.eos_pull = -30.0; // EOS suppressed: cap-bound deterministic lengths
        b.with_costs(costs)
    };

    println!(
        "== admission-order comparison: fifo vs shortest-first (pipelined w=1, paged, sparse, \
         R={slots}, giant prompt {prompt_len} behind a short head) =="
    );
    println!(
        "{:<15} {:>12} {:>10} {:>10} {:>9} {:>9}",
        "order", "decode-steps", "makespan", "blocked", "stalled", "preempts"
    );

    let mut out = BTreeMap::new();
    let mut seqs_by_order = Vec::new();
    let mut makespans = Vec::new();
    for order in [AdmissionOrder::Fifo, AdmissionOrder::ShortestFirst] {
        let mut kv = KvMemoryManager::with_pages(kv_cap, page_tokens);
        let mut sched = mk_sched(slots, reserve)
            .with_admission(AdmissionPolicy::Paged)
            .with_order(order);
        let mut backends = vec![proto.clone()];
        let mut exec = proto.clone();
        let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
        let (seqs, st) = policy
            .rollout_pipelined(
                &mut backends,
                Some(&mut exec),
                &flat,
                seed,
                RolloutCtx::new(&mut sched, &mut kv),
            )
            .expect("rollout");
        assert_eq!(kv.reserved(), 0, "{}: run leaked KV", order.label());
        kv.check_invariants().expect("wall invariants");
        println!(
            "{:<15} {:>12} {:>10} {:>10} {:>9} {:>9}",
            order.label(),
            st.decode_steps,
            st.modeled_makespan_ticks,
            st.prefill_blocked_ticks,
            st.sched_stall_ticks,
            st.preemptions,
        );
        let mut row = BTreeMap::new();
        row.insert("decode_steps".into(), Json::Num(st.decode_steps as f64));
        row.insert("makespan_ticks".into(), Json::Num(st.modeled_makespan_ticks as f64));
        row.insert(
            "prefill_blocked_ticks".into(),
            Json::Num(st.prefill_blocked_ticks as f64),
        );
        row.insert("sched_stall_ticks".into(), Json::Num(st.sched_stall_ticks as f64));
        row.insert("preemptions".into(), Json::Num(st.preemptions as f64));
        out.insert(order.label().replace('-', "_"), Json::Obj(row));
        makespans.push(st.modeled_makespan_ticks);
        seqs_by_order.push(seqs);
    }

    // ordering is a pure scheduling choice: identical tokens per task
    let agree = seqs_by_order[0]
        .iter()
        .zip(seqs_by_order[1].iter())
        .all(|(a, b)| a.response_ids == b.response_ids && a.sampler_logp == b.sampler_logp);
    assert!(agree, "admission order changed tokens (BUG)");
    // the workload really is length-skewed: the giant's capped response
    // is half the shorts' (prompt eats the max_seq budget)
    let mut lens: Vec<usize> = seqs_by_order[0].iter().map(|s| s.response_ids.len()).collect();
    assert!(
        lens.iter().min() < lens.iter().max(),
        "response lengths unexpectedly uniform: {lens:?}"
    );
    let (fifo, sjf) = (makespans[0], makespans[1]);
    println!(
        "  -> lengths min/max = {}/{}: shortest-first saves {:.1}% modeled makespan, \
         token-identical: yes\n",
        lens.iter().min().unwrap(),
        lens.iter().max().unwrap(),
        100.0 * (1.0 - sjf as f64 / fifo.max(1) as f64),
    );
    assert!(
        sjf < fifo,
        "shortest-first modeled makespan {sjf} !< fifo {fifo} (head-of-line blocking \
         should serialize the fifo run)"
    );
    lens.sort_unstable();
    out.insert(
        "response_len_min".into(),
        Json::Num(*lens.first().unwrap() as f64),
    );
    out.insert(
        "response_len_max".into(),
        Json::Num(*lens.last().unwrap() as f64),
    );
    out.insert("tasks".into(), Json::Num(tasks.len() as f64));
    out.insert("kv_cap_tokens".into(), Json::Num(kv_cap as f64));
    out.insert("page_tokens".into(), Json::Num(page_tokens as f64));
    Json::Obj(out)
}

/// Sync vs async slot prefill on the pipelined engine (part 1e): the
/// PR-5 tentpole claim. Under `prefill = sync` the joining worker makes
/// the prefill call itself, so every slot prefill blocks a decode lane
/// for `slot_prefill_ticks`; under `prefill = async` the dedicated
/// executor thread prepares it on the ONE shared prefill lane while the
/// workers keep decoding. Same tasks, same wall, same cost model —
/// token-identical outputs, and the async modeled makespan must be
/// STRICTLY below sync at every worker count (the acceptance bar pins
/// w=2 and w=4; w=1 is the deterministic trajectory anchor, where the
/// win is pure prefill/decode overlap).
///
/// Cost profile: DECODE-BOUND (`decode_ticks` 80 vs 40-tick prefills —
/// a full R-wide batch step against single-row prompt work), which is
/// the regime a lone executor serves: total prefill traffic stays well
/// under the decode span even at w=4 (~50% lane utilization), so every
/// slot prefill hides behind decode and sync's per-join stall is pure
/// loss. The flip side is real and intentional: in a PREFILL-bound
/// profile the single executor lane saturates at high worker counts and
/// sync's w-way parallel prefills win — scaling the executor count is
/// the recorded ROADMAP follow-up, and this scenario documents the
/// boundary rather than hiding it. Margins here are several times the
/// multi-worker scheduling jitter, so the strict asserts hold despite
/// the w>1 rows being nondeterministic.
fn prefill_mode_comparison() -> Json {
    let (slots, prompt_len, max_seq) = (8usize, 24usize, 160usize);
    let (n_tasks, seed) = (160usize, 7u64);
    // decode-bound profile (see above); prefill costs match the
    // representative model
    let costs = CostModel {
        prefill_ticks: 40,
        slot_prefill_ticks: 40,
        decode_ticks: 80,
        compress_ticks: 5,
        attach_ticks: 4,
        chunk_token_ticks: 1,
    };
    let mode = RolloutMode::Dense; // no compression traffic: isolate prefill
    let sampling = SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 64 };
    let reserve = max_seq;
    // slot-limited wall: isolate the prefill-blocking story
    let kv_cap = reserve * slots * 4;
    let mut rng = Rng::new(1);
    let tasks: Vec<Task> = (0..n_tasks)
        .map(|_| Task::gen(&mut rng, 1, prompt_len))
        .collect();
    let proto = {
        let mut b = MockModelBackend::dense(slots, prompt_len, max_seq, 32);
        // gentle EOS pull: long, skewed responses — deep decode spans for
        // the executor lane to hide prefills behind (and refills that
        // trickle instead of arriving in synchronized bursts)
        b.eos_pull = 0.06;
        b.with_costs(costs)
    };

    println!(
        "== prefill-mode comparison: sync vs async slot prefill (pipelined, dense, R={slots}, \
         {n_tasks} tasks, slot-prefill={}t decode={}t) ==",
        costs.slot_prefill_ticks, costs.decode_ticks
    );
    println!(
        "{:<10} {:<8} {:>12} {:>10} {:>10} {:>9} {:>9}",
        "workers", "prefill", "decode-steps", "makespan", "blocked", "in-flight", "speedup"
    );

    let mut out = BTreeMap::new();
    for workers in [1usize, 2, 4] {
        let mut obj = BTreeMap::new();
        let mut seqs_by_mode = Vec::new();
        let mut makespans = Vec::new();
        for prefill in [PrefillMode::Sync, PrefillMode::Async] {
            let policy = RolloutPolicy::new(mode, sampling).with_prefill(prefill);
            let (seqs, st) = run_pipelined_mock(
                &policy,
                &proto,
                &tasks,
                seed,
                reserve,
                kv_cap,
                1,
                AdmissionPolicy::WorstCase,
                workers,
            );
            let mut row = BTreeMap::new();
            row.insert("decode_steps".into(), Json::Num(st.decode_steps as f64));
            row.insert("makespan_ticks".into(), Json::Num(st.modeled_makespan_ticks as f64));
            row.insert(
                "prefill_blocked_ticks".into(),
                Json::Num(st.prefill_blocked_ticks as f64),
            );
            row.insert(
                "async_prefills".into(),
                Json::Num(st.async_prefills_submitted as f64),
            );
            // multi-worker task-to-lane assignment races on the mutex, so
            // only the w=1 rows anchor the recorded trajectory
            row.insert("deterministic".into(), Json::Bool(workers == 1));
            obj.insert(prefill.label().to_string(), Json::Obj(row));
            makespans.push(st.modeled_makespan_ticks);
            println!(
                "{:<10} {:<8} {:>12} {:>10} {:>10} {:>9} {:>9}",
                format!("w={workers}"),
                prefill.label(),
                st.decode_steps,
                st.modeled_makespan_ticks,
                st.prefill_blocked_ticks,
                st.async_prefill_inflight_peak,
                if prefill.is_async() {
                    format!(
                        "{:.2}x",
                        makespans[0] as f64 / st.modeled_makespan_ticks.max(1) as f64
                    )
                } else {
                    "1.00x".into()
                },
            );
            seqs_by_mode.push(seqs);
        }
        // prefill mode is a pure scheduling choice: identical tokens
        let agree = seqs_by_mode[0]
            .iter()
            .zip(seqs_by_mode[1].iter())
            .all(|(a, b)| a.response_ids == b.response_ids && a.sampler_logp == b.sampler_logp);
        assert!(agree, "w={workers}: prefill mode changed tokens (BUG)");
        let (sync, asy) = (makespans[0], makespans[1]);
        assert!(
            asy < sync,
            "w={workers}: async modeled makespan {asy} !< sync {sync} (the executor lane \
             must hide slot prefills behind decode)"
        );
        obj.insert(
            "speedup".into(),
            Json::Num(sync as f64 / asy.max(1) as f64),
        );
        out.insert(format!("w{workers}"), Json::Obj(obj));
    }
    out.insert("tasks".into(), Json::Num(n_tasks as f64));
    out.insert(
        "slot_prefill_ticks".into(),
        Json::Num(costs.slot_prefill_ticks as f64),
    );
    out.insert("decode_ticks".into(), Json::Num(costs.decode_ticks as f64));
    println!();
    Json::Obj(out)
}

/// Prefix sharing on a GRPO-style grouped workload (part 1f): the PR-6
/// tentpole claim. G sequences of a group carry identical prompts; under
/// `prefix-sharing = group` + paged admission the page-aligned prompt
/// prefix is charged ONCE through the refcounted pool (siblings pay one
/// private page), and refills of a cached prompt attach a prepared
/// prefill (`attach_ticks`) instead of re-running the full slot prefill.
/// Continuous engine, single lane — fully deterministic.
///
/// Geometry: 24-token prompts on 4-token pages admit at 7 pages unshared
/// (24 prefix + 1 private), so a 24-page wall fits 3 sequences. Shared,
/// each sibling after the first costs 1 page, so two whole groups (8
/// sequences — the slot cap) sit on 20 pages. Responses are cap-bound
/// and uniform (EOS suppressed), so the comparison isolates admission
/// width and prefill traffic: strictly wider peak width AND strictly
/// fewer prefill-blocked ticks, token-identical outputs.
fn prefix_sharing_comparison() -> Json {
    let (slots, prompt_len, max_seq, budget, buffer) = (8usize, 24usize, 32usize, 28usize, 8usize);
    let (page_tokens, seed) = (4usize, 7u64);
    let costs = CostModel::representative();
    let mode = RolloutMode::SparseRl(Method::RKv);
    let sampling = SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 4 };
    let policy = RolloutPolicy::new(mode, sampling);
    let reserve = budget + buffer; // 36-token bound; paged admits 25 tok = 7 pages
    let kv_cap = 96; // 24 pages: unshared width 3, shared width 8 (slot-capped)
    let mut rng = Rng::new(1);
    // 6 GRPO groups x 4 siblings, identical prompts within a group
    let leads: Vec<Task> = (0..6).map(|_| sized_task(&mut rng, prompt_len)).collect();
    let tasks: Vec<Task> = (0..24).map(|i| leads[i / 4].clone()).collect();
    let backend = || {
        let mut b = MockModelBackend::sparse(slots, prompt_len, max_seq, 32, budget, buffer);
        b.eos_pull = -30.0; // EOS suppressed: cap-bound deterministic lengths
        b.with_costs(costs)
    };

    println!(
        "== prefix-sharing comparison: off vs group (continuous, paged, sparse, R={slots}, \
         6 groups x 4 siblings, page={page_tokens} tok, slot-prefill={}t attach={}t) ==",
        costs.slot_prefill_ticks, costs.attach_ticks
    );
    println!(
        "{:<8} {:>12} {:>10} {:>10} {:>10} {:>9} {:>9}",
        "sharing", "decode-steps", "makespan", "blocked", "width-peak", "attaches", "shared"
    );

    let mut out = BTreeMap::new();
    let mut seqs_by_sharing = Vec::new();
    let mut stats_by_sharing = Vec::new();
    for sharing in [PrefixSharing::Off, PrefixSharing::Group] {
        let mut kv = KvMemoryManager::with_pages(kv_cap, page_tokens);
        let mut sched = mk_sched(slots, reserve)
            .with_admission(AdmissionPolicy::Paged)
            .with_sharing(sharing);
        let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
        let (seqs, st) = policy
            .with_sharing(sharing)
            .rollout_continuous(&mut backend(), &flat, seed, RolloutCtx::new(&mut sched, &mut kv))
            .expect("rollout");
        assert_eq!(kv.reserved(), 0, "{}: run leaked KV", sharing.label());
        assert_eq!(kv.live_prefixes(), 0, "{}: prefix entries leaked", sharing.label());
        kv.check_invariants().expect("wall invariants");
        println!(
            "{:<8} {:>12} {:>10} {:>10} {:>10} {:>9} {:>9}",
            sharing.label(),
            st.decode_steps,
            st.modeled_makespan_ticks,
            st.prefill_blocked_ticks,
            st.peak_live_slots,
            st.shared_prefill_attaches,
            sched.stats.shared_admissions,
        );
        let mut row = BTreeMap::new();
        row.insert("decode_steps".into(), Json::Num(st.decode_steps as f64));
        row.insert("makespan_ticks".into(), Json::Num(st.modeled_makespan_ticks as f64));
        row.insert(
            "prefill_blocked_ticks".into(),
            Json::Num(st.prefill_blocked_ticks as f64),
        );
        row.insert("peak_live_slots".into(), Json::Num(st.peak_live_slots as f64));
        row.insert(
            "shared_prefill_attaches".into(),
            Json::Num(st.shared_prefill_attaches as f64),
        );
        row.insert(
            "shared_admissions".into(),
            Json::Num(sched.stats.shared_admissions as f64),
        );
        // single-lane continuous on the virtual clock: fully deterministic
        row.insert("deterministic".into(), Json::Bool(true));
        out.insert(sharing.label().to_string(), Json::Obj(row));
        seqs_by_sharing.push(seqs);
        stats_by_sharing.push(st);
    }

    // sharing is a pure accounting/caching choice: identical tokens
    let agree = seqs_by_sharing[0]
        .iter()
        .zip(seqs_by_sharing[1].iter())
        .all(|(a, b)| a.response_ids == b.response_ids && a.sampler_logp == b.sampler_logp);
    assert!(agree, "prefix sharing changed tokens (BUG)");
    let (off, shared) = (&stats_by_sharing[0], &stats_by_sharing[1]);
    assert_eq!(off.shared_prefill_attaches, 0, "sharing=off attached a prefill");
    assert!(
        shared.shared_prefill_attaches > 0,
        "grouped workload never attached a shared prefill"
    );
    assert!(
        shared.peak_live_slots > off.peak_live_slots,
        "sharing must admit strictly wider ({} !> {})",
        shared.peak_live_slots,
        off.peak_live_slots
    );
    assert!(
        shared.prefill_blocked_ticks < off.prefill_blocked_ticks,
        "sharing must spend strictly fewer prefill ticks ({} !< {})",
        shared.prefill_blocked_ticks,
        off.prefill_blocked_ticks
    );
    println!(
        "  -> sharing admits {:.2}x wider at peak, cuts prefill-blocked ticks {:.1}% \
         ({} attaches), token-identical: yes\n",
        shared.peak_live_slots as f64 / off.peak_live_slots.max(1) as f64,
        100.0 * (1.0 - shared.prefill_blocked_ticks as f64
            / off.prefill_blocked_ticks.max(1) as f64),
        shared.shared_prefill_attaches,
    );
    out.insert("tasks".into(), Json::Num(tasks.len() as f64));
    out.insert("group_size".into(), Json::Num(4.0));
    out.insert("kv_cap_tokens".into(), Json::Num(kv_cap as f64));
    out.insert("page_tokens".into(), Json::Num(page_tokens as f64));
    out.insert("attach_ticks".into(), Json::Num(costs.attach_ticks as f64));
    Json::Obj(out)
}

/// Replica-tier fleet on a straggler-skewed workload (part 1g): the
/// PR-7 tentpole claim. Sixteen tasks — two giant-prompt stragglers
/// buried among cheap short-prompt tasks — run on fleets of 1/2/4 full
/// engine replicas (each a private scheduler + KV wall + continuous
/// lane). The load-modeled router balances by predicted residency ×
/// admission cost, so each giant lands on a different replica and the
/// fleet makespan (slowest replica, `merge_parallel`) must drop
/// STRICTLY below the single-replica serial makespan at N=2 and N=4,
/// with token-identical outputs per task (per-task RNG makes tokens
/// placement-invariant).
///
/// Stealing is OFF for the recorded rows: each replica then drains its
/// routed queue in exactly one engine pass, so the whole fleet trace is
/// deterministic (EOS suppressed → cap-bound lengths; continuous,
/// single lane per replica). A steal-ON N=4 row is recorded for context
/// only — batch composition there depends on thread timing, so it is
/// marked non-deterministic and the guard skips it.
fn fleet_comparison() -> Json {
    let (slots, prompt_len, max_seq, budget, buffer) = (2usize, 48usize, 56usize, 44usize, 8usize);
    let seed = 7u64;
    let costs = CostModel::representative();
    let mode = RolloutMode::SparseRl(Method::RKv);
    let sampling = SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 16 };
    let policy = RolloutPolicy::new(mode, sampling);
    let reserve = budget + buffer;
    // slot-limited wall per replica: isolate the routing/makespan story
    let kv_cap = reserve * slots * 4;
    let mut rng = Rng::new(1);
    // 16 tasks; positions 0 and 8 are the giant-prompt stragglers (their
    // prompt eats the max_seq budget, so they decode SHORT but occupy a
    // large modeled load — the router must not stack them)
    let tasks: Vec<Task> = (0..16)
        .map(|i| sized_task(&mut rng, if i % 8 == 0 { prompt_len } else { 4 }))
        .collect();
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    let proto = {
        let mut b = MockModelBackend::sparse(slots, prompt_len, max_seq, 32, budget, buffer);
        b.eos_pull = -30.0; // EOS suppressed: cap-bound deterministic lengths
        b.with_costs(costs)
    };
    let mk_fleet = |n: usize| -> Vec<Replica<MockModelBackend>> {
        (0..n)
            .map(|_| {
                Replica::new(
                    mk_sched(slots, reserve),
                    KvMemoryManager::new(kv_cap),
                    vec![proto.clone()],
                )
            })
            .collect()
    };

    println!(
        "== fleet comparison: 1 vs 2 vs 4 replicas (continuous, sparse, R={slots}/replica, \
         {} tasks, 2 giant-prompt stragglers, steal=off) ==",
        tasks.len()
    );
    println!(
        "{:<14} {:>12} {:>10} {:>7} {:>8} {:>9}",
        "fleet", "decode-steps", "makespan", "lanes", "steals", "speedup"
    );

    let mut out = BTreeMap::new();
    let mut base: Option<(Vec<GenSeq>, u64)> = None;
    for n in [1usize, 2, 4] {
        let mut replicas = mk_fleet(n);
        let (seqs, st, report) =
            rollout_fleet(&policy, EngineKind::Continuous, &mut replicas, &flat, seed, false)
                .expect("fleet rollout");
        for (r, rep) in replicas.iter().enumerate() {
            assert_eq!(rep.kv.reserved(), 0, "N={n}: replica {r} leaked KV");
            rep.kv.check_invariants().expect("wall invariants");
        }
        assert_eq!(report.replica_steals, 0, "N={n}: steal=off run stole");
        if n > 1 {
            for r in 0..n {
                assert!(
                    report.routed.iter().any(|&x| x == r),
                    "N={n}: router left replica {r} idle"
                );
            }
        }
        let speedup = match &base {
            Some((base_seqs, base_makespan)) => {
                // replica placement is a pure scheduling choice:
                // identical tokens per task at any fleet size
                let agree = base_seqs.iter().zip(seqs.iter()).all(|(a, b)| {
                    a.response_ids == b.response_ids && a.sampler_logp == b.sampler_logp
                });
                assert!(agree, "N={n}: fleet size changed tokens (BUG)");
                assert!(
                    st.modeled_makespan_ticks < *base_makespan,
                    "N={n}: fleet makespan {} !< single-replica {}",
                    st.modeled_makespan_ticks,
                    base_makespan
                );
                *base_makespan as f64 / st.modeled_makespan_ticks.max(1) as f64
            }
            None => 1.0,
        };
        println!(
            "{:<14} {:>12} {:>10} {:>7} {:>8} {:>8.2}x",
            format!("replicas={n}"),
            st.decode_steps,
            st.modeled_makespan_ticks,
            st.workers,
            report.replica_steals,
            speedup
        );
        let mut row = BTreeMap::new();
        row.insert("decode_steps".into(), Json::Num(st.decode_steps as f64));
        row.insert("makespan_ticks".into(), Json::Num(st.modeled_makespan_ticks as f64));
        row.insert("fleet_lanes".into(), Json::Num(st.workers as f64));
        row.insert("speedup".into(), Json::Num(speedup));
        // steal=off: one engine pass per replica, fully deterministic
        row.insert("deterministic".into(), Json::Bool(true));
        out.insert(format!("replicas_{n}"), Json::Obj(row));
        if base.is_none() {
            base = Some((seqs, st.modeled_makespan_ticks));
        }
    }

    // context row: stealing ON at N=4 — tokens still identical (the
    // invariant), but batch composition races on the fleet mutex, so
    // tick stats are not trajectory-comparable
    {
        let mut replicas = mk_fleet(4);
        let (seqs, st, report) =
            rollout_fleet(&policy, EngineKind::Continuous, &mut replicas, &flat, seed, true)
                .expect("fleet rollout");
        let (base_seqs, _) = base.as_ref().unwrap();
        let agree = base_seqs
            .iter()
            .zip(seqs.iter())
            .all(|(a, b)| a.response_ids == b.response_ids && a.sampler_logp == b.sampler_logp);
        assert!(agree, "steal=on: fleet stealing changed tokens (BUG)");
        println!(
            "{:<14} {:>12} {:>10} {:>7} {:>8} {:>9}",
            "n=4 steal=on",
            st.decode_steps,
            st.modeled_makespan_ticks,
            st.workers,
            report.replica_steals,
            "-"
        );
        let mut row = BTreeMap::new();
        row.insert("decode_steps".into(), Json::Num(st.decode_steps as f64));
        row.insert("makespan_ticks".into(), Json::Num(st.modeled_makespan_ticks as f64));
        row.insert("replica_steals".into(), Json::Num(report.replica_steals as f64));
        row.insert("deterministic".into(), Json::Bool(false));
        out.insert("replicas_4_steal_on".into(), Json::Obj(row));
    }

    println!("  -> token-identical across every fleet size: yes\n");
    out.insert("tasks".into(), Json::Num(tasks.len() as f64));
    out.insert("giant_prompt_tokens".into(), Json::Num(prompt_len as f64));
    out.insert("slots_per_replica".into(), Json::Num(slots as f64));
    Json::Obj(out)
}

/// Fault-tolerance overhead (part 1h): the robustness-PR claim, on the
/// virtual clock. Four passes over the same deterministic continuous
/// workload:
///
/// * `baseline` — seed behavior (retries 0, abort), no faults;
/// * `armed_fault_free` — `fault-retries = 3` + `fault-policy =
///   quarantine` with NO faults injected: arming the knobs must be
///   free — bit-identical tokens, decode steps, AND modeled makespan
///   (the zero-overhead-when-healthy guarantee the config docs state);
/// * `retry_burst_absorbed` — a scripted 3-deep decode error burst
///   inside the budget: tokens stay identical, `retries` counts exactly
///   the injected errors, and the makespan grows by exactly the
///   virtual-clock backoff the retry loop charges;
/// * `quarantine_one_task` — a prompt-keyed fault no budget can absorb:
///   one task quarantined, every survivor token-identical, pool
///   conserved — the recorded makespan is the price of a lost task.
///
/// Single-lane continuous on the virtual clock: every row is fully
/// deterministic, so the bench guard can hold the trajectory to it.
fn fault_tolerance_comparison() -> Json {
    let (slots, prompt_len, max_seq) = (8usize, 24usize, 160usize);
    let (n_tasks, seed) = (64usize, 7u64);
    let costs = CostModel::representative();
    let sampling = SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 64 };
    let reserve = max_seq;
    // slot-limited wall: isolate the fault accounting from admission effects
    let kv_cap = reserve * slots * 4;
    let mut rng = Rng::new(1);
    let tasks: Vec<Task> = (0..n_tasks)
        .map(|_| {
            let ops = 1 + rng.below(2);
            Task::gen(&mut rng, ops, prompt_len)
        })
        .collect();
    // a refill task (index > slots): its slot prefill carries the prompt
    // a prompt-keyed fault is pinned to
    let doomed = 12usize;
    assert!(
        tasks
            .iter()
            .enumerate()
            .all(|(i, t)| i == doomed || t.prompt_ids != tasks[doomed].prompt_ids),
        "doomed task's prompt must be unique for a one-task quarantine"
    );
    let backend = |plan: Option<FaultPlan>| {
        let mut b = MockModelBackend::dense(slots, prompt_len, max_seq, 32);
        b.eos_pull = 0.12; // long-tailed response lengths
        let b = b.with_costs(costs);
        match plan {
            Some(p) => b.with_faults(p),
            None => b,
        }
    };
    let run = |policy: &RolloutPolicy, plan: Option<FaultPlan>| {
        let mut kv = KvMemoryManager::new(kv_cap);
        let mut sched = mk_sched(slots, reserve);
        let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
        let (seqs, st) = policy
            .rollout_continuous(&mut backend(plan), &flat, seed, RolloutCtx::new(&mut sched, &mut kv))
            .expect("rollout");
        assert_eq!(kv.reserved(), 0, "fault bench run leaked KV");
        kv.check_invariants().expect("wall invariants");
        (seqs, st)
    };

    println!(
        "== fault-tolerance overhead: retries + quarantine (continuous, dense, R={slots}, \
         {n_tasks} tasks, retries=3) =="
    );
    println!(
        "{:<22} {:>12} {:>10} {:>8} {:>7} {:>9}",
        "scenario", "decode-steps", "makespan", "retries", "failed", "overhead"
    );

    let baseline = RolloutPolicy::new(RolloutMode::Dense, sampling);
    let armed = baseline.with_fault_retries(3).with_fault_policy(FaultPolicy::Quarantine);
    let burst = FaultPlan::new()
        .scripted(FaultOp::Decode, 40, FaultKind::Err)
        .scripted(FaultOp::Decode, 41, FaultKind::Err)
        .scripted(FaultOp::Decode, 42, FaultKind::Err);
    let poison =
        FaultPlan::new().scripted_prompt(tasks[doomed].prompt_ids.clone(), FaultKind::Err);
    let scenarios: [(&str, &RolloutPolicy, Option<FaultPlan>); 4] = [
        ("baseline", &baseline, None),
        ("armed_fault_free", &armed, None),
        ("retry_burst_absorbed", &armed, Some(burst)),
        ("quarantine_one_task", &armed, Some(poison)),
    ];

    let mut out = BTreeMap::new();
    let mut base: Option<(Vec<GenSeq>, u64)> = None;
    for (name, policy, plan) in scenarios {
        let (seqs, st) = run(policy, plan);
        if let Some((base_seqs, _)) = &base {
            // tokens are fault-knob- and retry-invariant; a quarantined
            // task is the one allowed divergence (it has no tokens)
            let agree = base_seqs
                .iter()
                .zip(seqs.iter())
                .all(|(a, b)| {
                    b.failed
                        || (a.response_ids == b.response_ids && a.sampler_logp == b.sampler_logp)
                });
            assert!(agree, "{name}: fault handling changed surviving tokens (BUG)");
        }
        let overhead = match &base {
            Some((_, base_makespan)) => {
                st.modeled_makespan_ticks as f64 / (*base_makespan).max(1) as f64 - 1.0
            }
            None => 0.0,
        };
        println!(
            "{:<22} {:>12} {:>10} {:>8} {:>7} {:>8.2}%",
            name,
            st.decode_steps,
            st.modeled_makespan_ticks,
            st.retries,
            st.failed_tasks,
            100.0 * overhead,
        );
        match name {
            "armed_fault_free" => {
                let (_, base_makespan) = base.as_ref().unwrap();
                assert_eq!(
                    st.modeled_makespan_ticks, *base_makespan,
                    "arming fault knobs must be free on a healthy run"
                );
                assert_eq!(st.retries, 0);
                assert_eq!(st.failed_tasks, 0);
            }
            "retry_burst_absorbed" => {
                let (_, base_makespan) = base.as_ref().unwrap();
                assert_eq!(st.retries, 3, "one retry per injected error");
                assert_eq!(st.failed_tasks, 0, "the burst is inside the budget");
                assert!(
                    st.modeled_makespan_ticks > *base_makespan,
                    "retry backoff must show up on the virtual clock"
                );
            }
            "quarantine_one_task" => {
                assert_eq!(st.failed_tasks, 1, "exactly the poisoned task fails");
                assert!(seqs[doomed].failed, "the poisoned task must carry the flag");
            }
            _ => {}
        }
        let mut row = BTreeMap::new();
        row.insert("decode_steps".into(), Json::Num(st.decode_steps as f64));
        row.insert("makespan_ticks".into(), Json::Num(st.modeled_makespan_ticks as f64));
        row.insert("retries".into(), Json::Num(st.retries as f64));
        row.insert("failed_tasks".into(), Json::Num(st.failed_tasks as f64));
        // single-lane continuous, scripted plan: fully deterministic
        row.insert("deterministic".into(), Json::Bool(true));
        out.insert(name.to_string(), Json::Obj(row));
        if base.is_none() {
            base = Some((seqs, st.modeled_makespan_ticks));
        }
    }

    println!("  -> healthy-run overhead of arming retries+quarantine: 0 ticks (bit-exact)\n");
    out.insert("tasks".into(), Json::Num(n_tasks as f64));
    out.insert("fault_retries".into(), Json::Num(3.0));
    out.insert("injected_errors".into(), Json::Num(3.0));
    Json::Obj(out)
}

/// Chunked prefill (part 1i): the token-budgeted step packer claim, on
/// the virtual clock. A long-prompt continuous workload (every prompt 32
/// tokens — wider than anything the decode batch absorbs for free) runs
/// twice: monolithic (`prefill-chunk-tokens = 0`, every refill charges
/// the full `slot_prefill_ticks` into one device step) and chunked
/// (budget = 28 tokens/step, refills ride the decode batch in
/// `chunk_token_ticks`-per-token slices capped by the step's leftover
/// budget). Chunking must strictly lower BOTH the modeled makespan (a
/// chunk has no per-call fixed cost, so 32 chunk-tokens < one 40-tick
/// monolithic prefill) AND the per-step tick bound `max_step_ticks` (no
/// refill step ever exceeds decode + leftover-budget work — the
/// head-of-line-blocking fix), with token-identical outputs. Single-lane
/// continuous on the virtual clock: both rows fully deterministic.
fn chunked_prefill_comparison() -> Json {
    let (slots, prompt_len, max_seq) = (8usize, 32usize, 96usize);
    let (n_tasks, seed, chunk_budget) = (64usize, 7u64, 28usize);
    let costs = CostModel::representative();
    let mode = RolloutMode::Dense; // no compression traffic: isolate prefill packing
    let sampling = SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 48 };
    let reserve = max_seq;
    // slot-limited wall: isolate the step-packing story
    let kv_cap = reserve * slots * 4;
    let mut rng = Rng::new(1);
    // uniform LONG prompts: every refill is a worst-case monolithic stall
    let tasks: Vec<Task> = (0..n_tasks).map(|_| sized_task(&mut rng, prompt_len)).collect();
    let backend = || {
        let mut b = MockModelBackend::dense(slots, prompt_len, max_seq, 32);
        b.eos_pull = 0.12; // long-tailed response lengths
        b.with_costs(costs)
    };

    println!(
        "== chunked-prefill comparison: monolithic vs token-budgeted steps (continuous, dense, \
         R={slots}, {n_tasks} tasks, prompt={prompt_len} tok, budget={chunk_budget} tok/step, \
         slot-prefill={}t chunk-token={}t) ==",
        costs.slot_prefill_ticks, costs.chunk_token_ticks
    );
    println!(
        "{:<12} {:>12} {:>10} {:>10} {:>13} {:>8} {:>8}",
        "prefill", "decode-steps", "makespan", "blocked", "max-step-tick", "chunks", "refills"
    );

    let base = RolloutPolicy::new(mode, sampling);
    let mut out = BTreeMap::new();
    let mut seqs_by_row = Vec::new();
    let mut stats_by_row = Vec::new();
    for (label, chunk) in [("monolithic", 0usize), ("chunked", chunk_budget)] {
        let policy = base.with_prefill_chunk_tokens(chunk);
        let (seqs, st) =
            run_continuous_mock(&policy, &mut backend(), &tasks, seed, reserve, kv_cap);
        println!(
            "{:<12} {:>12} {:>10} {:>10} {:>13} {:>8} {:>8}",
            label,
            st.decode_steps,
            st.modeled_makespan_ticks,
            st.prefill_blocked_ticks,
            st.max_step_ticks,
            st.prefill_chunks,
            st.refills,
        );
        let mut row = BTreeMap::new();
        row.insert("decode_steps".into(), Json::Num(st.decode_steps as f64));
        row.insert("makespan_ticks".into(), Json::Num(st.modeled_makespan_ticks as f64));
        row.insert(
            "prefill_blocked_ticks".into(),
            Json::Num(st.prefill_blocked_ticks as f64),
        );
        row.insert("max_step_ticks".into(), Json::Num(st.max_step_ticks as f64));
        row.insert("prefill_chunks".into(), Json::Num(st.prefill_chunks as f64));
        row.insert("refills".into(), Json::Num(st.refills as f64));
        // single-lane continuous on the virtual clock: fully deterministic
        row.insert("deterministic".into(), Json::Bool(true));
        out.insert(label.to_string(), Json::Obj(row));
        seqs_by_row.push(seqs);
        stats_by_row.push(st);
    }

    // chunking is a pure scheduling choice: identical tokens per task
    let agree = seqs_by_row[0]
        .iter()
        .zip(seqs_by_row[1].iter())
        .all(|(a, b)| a.response_ids == b.response_ids && a.sampler_logp == b.sampler_logp);
    assert!(agree, "chunked prefill changed tokens (BUG)");
    let (mono, ch) = (&stats_by_row[0], &stats_by_row[1]);
    assert!(mono.refills > 0, "workload never recycled a slot");
    assert_eq!(mono.prefill_chunks, 0, "monolithic run recorded chunks");
    assert_eq!(ch.slot_prefills, 0, "chunked run issued monolithic slot prefills");
    assert!(
        ch.prefill_chunks >= ch.refills,
        "{} refills but only {} chunks",
        ch.refills,
        ch.prefill_chunks
    );
    assert!(
        ch.modeled_makespan_ticks < mono.modeled_makespan_ticks,
        "chunked modeled makespan {} !< monolithic {} (per-token chunk work must \
         undercut the fixed slot-prefill charge)",
        ch.modeled_makespan_ticks,
        mono.modeled_makespan_ticks
    );
    assert!(
        ch.max_step_ticks < mono.max_step_ticks,
        "chunked max step {} !< monolithic {} (the packer must remove the \
         head-of-line prefill stall)",
        ch.max_step_ticks,
        mono.max_step_ticks
    );
    // the packer's hard per-step bound: decode + at most the leftover
    // token budget of one chunk (floored at one token for progress)
    assert!(
        ch.max_step_ticks
            <= costs.decode_ticks + chunk_budget as u64 * costs.chunk_token_ticks,
        "chunked max step {} exceeds the packed budget bound",
        ch.max_step_ticks
    );
    println!(
        "  -> chunking saves {:.1}% modeled makespan and caps steps at {} ticks (vs {}), \
         token-identical: yes\n",
        100.0 * (1.0 - ch.modeled_makespan_ticks as f64
            / mono.modeled_makespan_ticks.max(1) as f64),
        ch.max_step_ticks,
        mono.max_step_ticks,
    );
    out.insert("tasks".into(), Json::Num(n_tasks as f64));
    out.insert("prompt_tokens".into(), Json::Num(prompt_len as f64));
    out.insert("chunk_budget_tokens".into(), Json::Num(chunk_budget as f64));
    out.insert(
        "chunk_token_ticks".into(),
        Json::Num(costs.chunk_token_ticks as f64),
    );
    out.insert(
        "slot_prefill_ticks".into(),
        Json::Num(costs.slot_prefill_ticks as f64),
    );
    Json::Obj(out)
}

/// SLO vs FIFO serving admission (part 1j): the serving-front-end claim,
/// on the virtual clock. A deterministic flash-crowd trace — one warmup
/// request, then a 24-request burst whose deadlines sit one tick short of
/// their own modeled cost (infeasible at any dispatch tick), then a
/// feasible 3-request wave long after the burst would have drained — runs
/// through `ServeServer` twice. Under `serve-admission = slo` the
/// admission oracle (`predicted_cost_ticks`, the router's
/// residency × admission-cost product) refuses the whole burst up front
/// with reject-with-estimate outcomes, so the completed requests all
/// start essentially on arrival; under `fifo` the burst is admitted, and
/// its queueing delay lands in the TTFT tail. Asserts the SLO arm's
/// modeled p99 TTFT (and max) is STRICTLY below FIFO's, that every
/// completed request on both arms streams tokens identical to one closed
/// batch of the whole trace, and that shedding is exact: precisely the
/// burst, each refusal carrying the modeled cost it was refused on.
/// Single-lane continuous on the virtual clock: both rows deterministic
/// (fresh-only on first recording, so `bench_guard.py` lists them as new).
fn serving_comparison() -> Json {
    let (slots, prompt_len) = (2usize, 24usize);
    let (burst, wave, seed) = (24usize, 3usize, 9u64);
    let costs = CostModel::representative();
    let sampling = SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 24 };
    let max_seq = prompt_len + sampling.max_response;
    let reserve = max_seq;
    let kv_cap = reserve * slots * 2;
    let n = 1 + burst + wave;
    let mut rng = Rng::new(3);
    // uniform prompts: one modeled admission cost for the whole trace
    let tasks: Vec<Task> = (0..n).map(|_| sized_task(&mut rng, prompt_len)).collect();
    let backend = || {
        let mut b = MockModelBackend::dense(slots, prompt_len, max_seq, 32);
        b.eos_pull = 0.12; // long-tailed response lengths
        b.with_costs(costs)
    };
    let pred = mk_sched(slots, reserve)
        .predicted_cost_ticks(prompt_len, sampling.max_response);

    let mut trace: Vec<ServeRequest> = vec![ServeRequest::new(tasks[0].clone(), 0)];
    for t in &tasks[1..=burst] {
        // deadline one tick short of the modeled cost: infeasible even if
        // dispatched the instant it arrives
        trace.push(ServeRequest::new(t.clone(), 1).with_deadline(pred));
    }
    for t in &tasks[1 + burst..] {
        trace.push(ServeRequest::new(t.clone(), 10_000).with_deadline(10_000 + 2 * pred));
    }

    let policy = RolloutPolicy::new(RolloutMode::Dense, sampling);
    // the closed-batch oracle: serving must stream exactly these tokens
    let (closed, _) = run_continuous_mock(&policy, &mut backend(), &tasks, seed, reserve, kv_cap);

    println!(
        "== serving comparison: slo vs fifo admission (continuous, R={slots}, warmup + \
         {burst}-request infeasible burst + {wave}-request late wave, predicted cost {pred}t) ==",
    );
    println!(
        "{:<6} {:>9} {:>6} {:>6} {:>9} {:>9} {:>9} {:>10}",
        "adm", "completed", "shed", "rounds", "ttft-p50", "ttft-p99", "e2e-p99", "makespan"
    );

    let mut out = BTreeMap::new();
    let mut reports = Vec::new();
    for admission in [ServeAdmission::Slo, ServeAdmission::Fifo] {
        let mut server = ServeServer::new(
            policy,
            EngineKind::Continuous,
            ServeConfig { admission, queue_depth: 0, slo_ticks: 0 },
            vec![backend()],
            mk_sched(slots, reserve),
            KvMemoryManager::new(kv_cap),
        );
        let report = server.run(&trace, seed).expect("serve");
        for (i, o) in report.outcomes.iter().enumerate() {
            if let ServeOutcome::Completed { response, .. } = o {
                assert_eq!(
                    response, &closed[i].response_ids,
                    "serving changed request {i}'s tokens (BUG)"
                );
            }
        }
        println!(
            "{:<6} {:>9} {:>6} {:>6} {:>9} {:>9} {:>9} {:>10}",
            admission.label(),
            report.completed(),
            report.shed(),
            report.rounds,
            report.ttft.p50(),
            report.ttft.p99(),
            report.e2e.p99(),
            report.makespan_ticks,
        );
        let mut row = BTreeMap::new();
        row.insert("completed".into(), Json::Num(report.completed() as f64));
        row.insert("shed".into(), Json::Num(report.shed() as f64));
        row.insert("rounds".into(), Json::Num(report.rounds as f64));
        row.insert("ttft_p50_ticks".into(), Json::Num(report.ttft.p50() as f64));
        row.insert("ttft_p99_ticks".into(), Json::Num(report.ttft.p99() as f64));
        row.insert("e2e_p99_ticks".into(), Json::Num(report.e2e.p99() as f64));
        row.insert("makespan_ticks".into(), Json::Num(report.makespan_ticks as f64));
        // single-lane continuous serve on the virtual clock: deterministic
        row.insert("deterministic".into(), Json::Bool(true));
        out.insert(admission.label().to_string(), Json::Obj(row));
        reports.push(report);
    }

    let (slo, fifo) = (&reports[0], &reports[1]);
    assert_eq!(slo.shed(), burst, "slo must shed exactly the infeasible burst");
    assert_eq!(slo.completed(), 1 + wave);
    for i in 1..=burst {
        match &slo.outcomes[i] {
            ServeOutcome::Shed { predicted_cost_ticks, predicted_done_tick, .. } => {
                assert_eq!(*predicted_cost_ticks, pred, "request {i}");
                assert!(*predicted_done_tick > trace[i].deadline_tick, "request {i}");
            }
            other => panic!("request {i}: expected Shed, got {other:?}"),
        }
    }
    assert_eq!(fifo.shed(), 0, "fifo is the no-controller baseline");
    assert_eq!(fifo.completed(), n);
    assert!(
        slo.ttft.p99() < fifo.ttft.p99(),
        "slo p99 ttft {} !< fifo p99 ttft {} (the admission controller must \
         keep the burst's queueing delay out of the served tail)",
        slo.ttft.p99(),
        fifo.ttft.p99()
    );
    assert!(slo.ttft.max() < fifo.ttft.max());
    println!(
        "  -> slo sheds {burst} with estimates and cuts served p99 ttft {} -> {} ticks \
         ({:.1}%), token-identical: yes\n",
        fifo.ttft.p99(),
        slo.ttft.p99(),
        100.0 * (1.0 - slo.ttft.p99() as f64 / fifo.ttft.p99().max(1) as f64),
    );
    out.insert("requests".into(), Json::Num(n as f64));
    out.insert("burst".into(), Json::Num(burst as f64));
    out.insert("predicted_cost_ticks".into(), Json::Num(pred as f64));
    Json::Obj(out)
}

fn main() {
    let args = CliArgs::parse(std::env::args().skip(1).filter(|a| a != "--bench"));

    // Part 1: engine comparison on the mock backend (always runs).
    engine_comparison();

    // Part 1b: paged vs worst-case admission (always runs); Part 1c:
    // pipelined vs continuous on the modeled latency clock; Part 1d:
    // fifo vs shortest-first admission order on the skewed-length
    // head-of-line workload; Part 1e: sync vs async slot prefill; Part
    // 1f: prefix sharing off vs group on a GRPO-grouped workload; Part
    // 1g: replica fleet 1/2/4 on the straggler-skewed workload; Part
    // 1h: fault-tolerance overhead (retry backoff + quarantine); Part
    // 1i: chunked vs monolithic prefill on the long-prompt workload;
    // Part 1j: slo vs fifo serving admission on the flash-crowd trace.
    // All feed BENCH_rollout.json so CI records the perf trajectory (and
    // the bench guard compares deterministic makespans against it).
    let paged = paged_comparison();
    let pipelined = pipelined_comparison();
    let order = admission_order_comparison();
    let prefill = prefill_mode_comparison();
    let sharing = prefix_sharing_comparison();
    let fleet = fleet_comparison();
    let faults = fault_tolerance_comparison();
    let chunked = chunked_prefill_comparison();
    let serving = serving_comparison();
    {
        let mut doc = BTreeMap::new();
        doc.insert("bench".to_string(), Json::Str("rollout".into()));
        doc.insert("paged_vs_worst_case".to_string(), paged);
        doc.insert("pipelined_vs_continuous".to_string(), pipelined);
        doc.insert("admission_order".to_string(), order);
        doc.insert("prefill_mode".to_string(), prefill);
        doc.insert("prefix_sharing".to_string(), sharing);
        doc.insert("fleet".to_string(), fleet);
        doc.insert("fault_tolerance".to_string(), faults);
        doc.insert("chunked_prefill".to_string(), chunked);
        doc.insert("serving".to_string(), serving);
        let path = "BENCH_rollout.json";
        match std::fs::write(path, sparse_rl::util::json::to_string(&Json::Obj(doc))) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }

    // Part 2: artifact component latencies.
    let model = args.get("model", "nano".to_string());
    let dir = match experiments::find_artifacts(&model) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("skipping artifact benches: {e}");
            return;
        }
    };
    let engine = ModelEngine::load(&dir).expect("engine");
    let m = engine.manifest.clone();
    let params = TrainState::new(engine.init_params(0).expect("init")).params;
    let plit = ParamsLit::new(&params);
    let r = m.shapes.decode_batch;
    let p = m.config.prompt_len;

    let mut b = Bencher::default();
    b.header(&format!(
        "rollout components ({model}: {} params, R={r}, Cd={}, Cs={})",
        m.config.n_params, m.shapes.dense_capacity, m.shapes.sparse_capacity
    ));

    // prompt batch
    let mut ids = vec![0i32; r * p];
    let mut lens = vec![(p / 2) as i32; r];
    for s in 0..r {
        ids[s * p] = 1;
        for i in 1..p / 2 {
            ids[s * p + i] = 3 + ((s + i) % 20) as i32;
        }
        lens[s] = (p / 2) as i32;
    }

    for variant in [Variant::Dense, Variant::Sparse] {
        b.bench(&format!("prefill_{}", variant.name()), || {
            engine.prefill(variant, &plit, &ids, &lens).expect("prefill");
        });
    }

    // per-slot prefill (slot recycling cost: full prefill + host splice)
    {
        let (mut cache, _) =
            engine.prefill(Variant::Sparse, &plit, &ids, &lens).expect("prefill");
        let prompt: Vec<i32> = ids[..(p / 2)].to_vec();
        b.bench("prefill_slot (recycle)", || {
            engine.prefill_slot(&plit, &mut cache, r / 2, &prompt).expect("prefill_slot");
        });
    }

    for variant in [Variant::Dense, Variant::Sparse] {
        let (mut cache, _) = engine.prefill(variant, &plit, &ids, &lens).expect("prefill");
        let cur: Vec<i32> = lens.clone();
        let pos: Vec<i32> = lens.clone();
        let tok = vec![5i32; r];
        b.bench(&format!("decode_{}", variant.name()), || {
            engine.decode(&plit, &mut cache, &cur, &pos, &tok).expect("decode");
        });
    }

    {
        let do_all = vec![1.0f32; r];
        for method in Method::all() {
            let (mut cache, _) =
                engine.prefill(Variant::Sparse, &plit, &ids, &lens).expect("prefill");
            b.bench(&format!("compress_{}", method.name()), || {
                engine.compress(method, &mut cache, &do_all).expect("compress");
            });
        }
    }

    {
        let (bt, t) = (m.shapes.train_batch, m.config.max_seq);
        let sids = vec![5i32; bt * t];
        let slens = vec![t as i32; bt];
        b.bench("score (dense TF)", || {
            engine.score(&params, &sids, &slens).expect("score");
        });

        let mut state = TrainState::new(params.clone());
        let mask = vec![1.0f32; bt * t];
        let adv = vec![0.5f32; bt];
        let xi = vec![1.0f32; bt * t];
        let mrs = vec![1.0f32; bt];
        let (logp_old, _) = engine.score(&params, &sids, &slens).expect("score");
        b.bench("train_step (Eq.7 + Adam)", || {
            engine
                .train(&mut state, &sids, &mask, &slens, &adv, &xi, &mrs, &logp_old, Hyp::default())
                .expect("train");
        });

        b.bench("lm_step", || {
            engine.lm(&mut state, &sids, &mask, &slens, Hyp::default()).expect("lm");
        });
    }

    // derived report: per-token decode cost and the dense/sparse ratio
    let results = b.results();
    let get = |name: &str| {
        results
            .iter()
            .find(|r| r.name.starts_with(name))
            .map(|r| r.mean_ns())
            .unwrap_or(f64::NAN)
    };
    let dense = get("decode_dense");
    let sparse = get("decode_sparse");
    println!("\nderived:");
    println!(
        "  decode per-token (batch {r}): dense {:.1} µs, sparse {:.1} µs, dense/sparse = {:.2}x",
        dense / 1e3 / r as f64,
        sparse / 1e3 / r as f64,
        dense / sparse
    );
    println!(
        "  KV bytes/seq: dense {} KiB vs sparse {} KiB ({}x reduction)",
        m.kv_bytes_per_seq(m.shapes.dense_capacity) / 1024,
        m.kv_bytes_per_seq(m.shapes.sparse_capacity) / 1024,
        m.shapes.dense_capacity as f64 / m.shapes.sparse_capacity as f64
    );
}
