//! Component latency bench: every artifact on the rollout/training path.
//!
//! Backs the §Perf numbers in EXPERIMENTS.md: decode step latency (dense
//! vs sparse — the memory-wall compute story), compression overhead per
//! method, prefill, dense scoring, and the RL train step.
//!
//!     cargo bench --bench bench_rollout [-- --model nano]

use sparse_rl::experiments;
use sparse_rl::runtime::{Hyp, Method, ModelEngine, ParamsLit, TrainState, Variant};
use sparse_rl::util::bench::Bencher;
use sparse_rl::util::cli::CliArgs;

fn main() {
    let args = CliArgs::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let model = args.get("model", "nano".to_string());
    let dir = match experiments::find_artifacts(&model) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("skipping bench: {e}");
            return;
        }
    };
    let engine = ModelEngine::load(&dir).expect("engine");
    let m = engine.manifest.clone();
    let params = TrainState::new(engine.init_params(0).expect("init")).params;
    let plit = ParamsLit::new(&params);
    let r = m.shapes.decode_batch;
    let p = m.config.prompt_len;

    let mut b = Bencher::default();
    b.header(&format!(
        "rollout components ({model}: {} params, R={r}, Cd={}, Cs={})",
        m.config.n_params, m.shapes.dense_capacity, m.shapes.sparse_capacity
    ));

    // prompt batch
    let mut ids = vec![0i32; r * p];
    let mut lens = vec![(p / 2) as i32; r];
    for s in 0..r {
        ids[s * p] = 1;
        for i in 1..p / 2 {
            ids[s * p + i] = 3 + ((s + i) % 20) as i32;
        }
        lens[s] = (p / 2) as i32;
    }

    for variant in [Variant::Dense, Variant::Sparse] {
        b.bench(&format!("prefill_{}", variant.name()), || {
            engine.prefill(variant, &plit, &ids, &lens).expect("prefill");
        });
    }

    for variant in [Variant::Dense, Variant::Sparse] {
        let (mut cache, _) = engine.prefill(variant, &plit, &ids, &lens).expect("prefill");
        let cur: Vec<i32> = lens.clone();
        let pos: Vec<i32> = lens.clone();
        let tok = vec![5i32; r];
        b.bench(&format!("decode_{}", variant.name()), || {
            engine.decode(&plit, &mut cache, &cur, &pos, &tok).expect("decode");
        });
    }

    {
        let do_all = vec![1.0f32; r];
        for method in Method::all() {
            let (mut cache, _) =
                engine.prefill(Variant::Sparse, &plit, &ids, &lens).expect("prefill");
            b.bench(&format!("compress_{}", method.name()), || {
                engine.compress(method, &mut cache, &do_all).expect("compress");
            });
        }
    }

    {
        let (bt, t) = (m.shapes.train_batch, m.config.max_seq);
        let sids = vec![5i32; bt * t];
        let slens = vec![t as i32; bt];
        b.bench("score (dense TF)", || {
            engine.score(&params, &sids, &slens).expect("score");
        });

        let mut state = TrainState::new(params.clone());
        let mask = vec![1.0f32; bt * t];
        let adv = vec![0.5f32; bt];
        let xi = vec![1.0f32; bt * t];
        let mrs = vec![1.0f32; bt];
        let (logp_old, _) = engine.score(&params, &sids, &slens).expect("score");
        b.bench("train_step (Eq.7 + Adam)", || {
            engine
                .train(&mut state, &sids, &mask, &slens, &adv, &xi, &mrs, &logp_old, Hyp::default())
                .expect("train");
        });

        b.bench("lm_step", || {
            engine.lm(&mut state, &sids, &mask, &slens, Hyp::default()).expect("lm");
        });
    }

    // derived report: per-token decode cost and the dense/sparse ratio
    let results = b.results();
    let get = |name: &str| {
        results
            .iter()
            .find(|r| r.name.starts_with(name))
            .map(|r| r.mean_ns())
            .unwrap_or(f64::NAN)
    };
    let dense = get("decode_dense");
    let sparse = get("decode_sparse");
    println!("\nderived:");
    println!(
        "  decode per-token (batch {r}): dense {:.1} µs, sparse {:.1} µs, dense/sparse = {:.2}x",
        dense / 1e3 / r as f64,
        sparse / 1e3 / r as f64,
        dense / sparse
    );
    println!(
        "  KV bytes/seq: dense {} KiB vs sparse {} KiB ({}x reduction)",
        m.kv_bytes_per_seq(m.shapes.dense_capacity) / 1024,
        m.kv_bytes_per_seq(m.shapes.sparse_capacity) / 1024,
        m.shapes.dense_capacity as f64 / m.shapes.sparse_capacity as f64
    );
}
