//! End-to-end rollout throughput bench behind Table 1's Toks.saving and
//! the paper's memory-wall batch-size argument (§1).
//!
//! Rolls a fixed workload (P prompts x G samples) through the memory-wall
//! scheduler in dense vs sparse modes and reports: admitted batch width,
//! chunk count, wall-clock, generated tokens/sec, and KV token savings.
//!
//!     cargo bench --bench bench_table1 [-- --model nano --kv-wall 2048]

use std::time::Instant;

use sparse_rl::config::{ExperimentConfig, RolloutMode};
use sparse_rl::coordinator::{KvMemoryManager, Scheduler};
use sparse_rl::data::benchmarks;
use sparse_rl::experiments;
use sparse_rl::runtime::{Method, ModelEngine, TrainState};
use sparse_rl::util::cli::CliArgs;

fn main() {
    let args = CliArgs::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let model = args.get("model", "nano".to_string());
    let kv_wall = args.get("kv-wall", 2048usize);
    let n_seqs = args.get("n-seqs", 32usize);
    let max_response = args.get("max-response", 64usize);

    let dir = match experiments::find_artifacts(&model) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("skipping bench: {e}");
            return;
        }
    };
    let engine = ModelEngine::load(&dir).expect("engine");
    let state = TrainState::new(engine.init_params(0).expect("init"));

    println!(
        "\n== memory-wall rollout throughput ({model}, wall {kv_wall} KV tokens, {n_seqs} seqs) =="
    );
    println!(
        "{:<18} {:>6} {:>7} {:>9} {:>10} {:>10} {:>9}",
        "mode", "width", "chunks", "wall(s)", "tok/s", "KV-peak", "toks-sav"
    );

    for mode in [
        RolloutMode::Dense,
        RolloutMode::SparseRl(Method::RKv),
        RolloutMode::SparseRl(Method::SnapKv),
    ] {
        let mut cfg = ExperimentConfig::new(&dir);
        cfg.mode = mode;
        cfg.sampling.max_response = max_response;
        cfg.memory.global_kv_tokens = kv_wall;
        cfg.train.prompts_per_step = n_seqs / cfg.train.group_size;

        // drive the exact trainer rollout path (scheduler + wall + engine)
        let tasks = benchmarks::training_split_ops(256, engine.manifest.config.prompt_len, 7, 3, 5);
        let mut trainer =
            sparse_rl::coordinator::Trainer::new(&engine, cfg, state.clone(), tasks);
        let task_indices: Vec<usize> = (0..n_seqs / 8).collect();

        let t0 = Instant::now();
        let (seqs, rstats) = trainer.rollout_batch(&task_indices).expect("rollout");
        let chunks = rstats.chunks;
        let wall = t0.elapsed().as_secs_f64();

        let gen_tokens: usize = seqs.iter().map(|s| s.response_ids.len()).sum();
        let mut acct = sparse_rl::compression::KvAccounting::new();
        for s in &seqs {
            acct.merge(&s.accounting);
        }
        let sched = Scheduler::new(&engine.manifest, mode.is_sparse());
        let width = sched
            .slots
            .min(KvMemoryManager::new(kv_wall).admissible(sched.reserve_per_seq));
        println!(
            "{:<18} {:>6} {:>7} {:>9.2} {:>10.0} {:>10} {:>8.1}%",
            mode.label(),
            width,
            chunks,
            wall,
            gen_tokens as f64 / wall,
            acct.peak_actual,
            100.0 * acct.toks_saving()
        );
    }
    println!(
        "\nshape check (paper §1): the dense path is admission-limited by the wall \
         (width ~ wall/max_seq), sparse is slot-limited; fewer chunks -> higher tok/s."
    );
}
