//! Pure-Rust coordinator micro-benchmarks: the L3 pieces that must never
//! be the bottleneck (paper's contribution is the coordinator, so we hold
//! it to <10% of step time — §Perf).
//!
//!     cargo bench --bench bench_components

use sparse_rl::coordinator::kv_manager::KvMemoryManager;
use sparse_rl::coordinator::scheduler::Scheduler;
use sparse_rl::coordinator::{group, rejection};
use sparse_rl::data::{benchmarks, task::Task};
use sparse_rl::util::bench::Bencher;
use sparse_rl::util::json::Json;
use sparse_rl::util::rng::Rng;

fn main() {
    let mut b = Bencher::default();
    b.header("L3 coordinator components");

    {
        let mut rng = Rng::new(1);
        let logp: Vec<f32> = (0..32).map(|_| -rng.next_f32() * 6.0).collect();
        b.bench("sample_logits (V=32, T=1, top-p=1)", || {
            std::hint::black_box(rng.sample_logits(&logp, 1.0, 1.0));
        });
        b.bench("sample_logits (T=0.7, top-p=0.95)", || {
            std::hint::black_box(rng.sample_logits(&logp, 0.7, 0.95));
        });
    }

    {
        let mut rng = Rng::new(2);
        let t = Task::gen(&mut rng, 4, 48);
        let resp = t.target_ids();
        b.bench("reward verification (CoT parse + match)", || {
            std::hint::black_box(t.reward(&resp));
        });
        b.bench("task generation (4 ops, bounded)", || {
            std::hint::black_box(Task::gen(&mut rng, 4, 48));
        });
    }

    {
        let rewards: Vec<f64> = (0..64).map(|i| (i % 3 == 0) as u8 as f64).collect();
        b.bench("group advantages (64 seqs, G=8)", || {
            std::hint::black_box(group::batched_group_advantages(&rewards, 8).unwrap());
        });
    }

    {
        let logp_old: Vec<f32> = (0..160).map(|i| -1.0 - (i % 7) as f32 * 0.1).collect();
        let logp_sp: Vec<f32> = logp_old.iter().map(|x| x - 0.01).collect();
        b.bench("xi ratios + rejection verdict (160 tok)", || {
            let xi = rejection::xi_ratios(&logp_old, &logp_sp);
            std::hint::black_box(rejection::verdict(&xi, 1e-4));
        });
    }

    {
        b.bench("scheduler: plan 1024 seqs against the wall", || {
            let mut kv = KvMemoryManager::new(4096);
            let mut s = Scheduler::worst_case(16, 208);
            let mut pending: Vec<usize> = (0..1024).collect();
            let mut base = 0u64;
            while let Some(c) = s.next_chunk(&mut pending, &mut kv, base, &[]) {
                s.finish_chunk(&c, &mut kv, base);
                base += c.items.len() as u64;
            }
        });
    }

    {
        let text = std::fs::read_to_string("artifacts/nano/manifest.json")
            .or_else(|_| std::fs::read_to_string("../artifacts/nano/manifest.json"));
        if let Ok(text) = text {
            b.bench("manifest.json parse", || {
                std::hint::black_box(Json::parse(&text).unwrap());
            });
        }
    }

    {
        b.bench("benchmark suite materialize (gsm8k, 1319 tasks)", || {
            std::hint::black_box(benchmarks::suite()[0].tasks(48));
        });
    }
}
