//! Minimal offline stand-in for the `xla-rs` bindings.
//!
//! The build environment has no XLA/PJRT shared libraries, so this crate
//! keeps the workspace compiling and its pure-Rust test suite running:
//!
//! * `Literal` data operations (`vec1`, `scalar`, `reshape`, `to_vec`) are
//!   fully functional host-side implementations — everything that only
//!   moves bytes works for real.
//! * Runtime operations (HLO parsing, compilation, execution) return a
//!   clear `Error` so artifact-driven paths fail fast with an actionable
//!   message instead of linking errors. Integration tests gate on artifact
//!   presence and skip before ever reaching these.
//!
//! To run real AOT artifacts, point the workspace's `xla` path dependency
//! at an actual xla-rs checkout; the API surface here matches the subset
//! the coordinator uses.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err(op: &str) -> Error {
    Error(format!(
        "{op}: XLA runtime not available (offline stub; point the `xla` \
         path dependency at a real xla-rs checkout to execute artifacts)"
    ))
}

#[derive(Debug, Clone, PartialEq)]
enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Storage {
    fn len(&self) -> usize {
        match self {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
        }
    }
}

/// Element types a `Literal` can hold.
pub trait NativeType: Copy {
    fn into_storage(v: Vec<Self>) -> Storage;
    fn from_storage(s: &Storage) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn into_storage(v: Vec<Self>) -> Storage {
        Storage::F32(v)
    }
    fn from_storage(s: &Storage) -> Option<Vec<Self>> {
        match s {
            Storage::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn into_storage(v: Vec<Self>) -> Storage {
        Storage::I32(v)
    }
    fn from_storage(s: &Storage) -> Option<Vec<Self>> {
        match s {
            Storage::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host-side tensor literal: flat storage + dims. Fully functional.
#[derive(Debug, Clone)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            storage: T::into_storage(data.to_vec()),
            dims: vec![data.len() as i64],
        }
    }

    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { storage: T::into_storage(vec![v]), dims: vec![] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.storage.len() {
            return Err(Error(format!(
                "reshape: {} elements cannot view as {:?}",
                self.storage.len(),
                dims
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_storage(&self.storage)
            .ok_or_else(|| Error("to_vec: literal holds a different dtype".into()))
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(stub_err("Literal::to_tuple"))
    }
}

/// PJRT client handle (stub: construction succeeds so manifest-only flows
/// work; anything touching the device errors).
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(stub_err("PjRtClient::buffer_from_host_literal"))
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("PjRtBuffer::to_literal_sync"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn client(&self) -> PjRtClient {
        PjRtClient
    }

    pub fn execute_b(&self, _bufs: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("PjRtLoadedExecutable::execute_b"))
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(stub_err("HloModuleProto::from_text_file"))
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_data_ops_work() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn runtime_ops_error_cleanly() {
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let c = PjRtClient::cpu().unwrap();
        assert!(c.buffer_from_host_literal(None, &Literal::scalar(1i32)).is_err());
    }
}
