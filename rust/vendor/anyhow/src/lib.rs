//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The registry is unavailable in the build environment, so this vendored
//! crate provides the small slice of anyhow's API the workspace uses:
//! `Error` (message-only, with context chaining), `Result`, the `Context`
//! extension trait for `Result`/`Option`, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Like real anyhow, `Error` deliberately does NOT
//! implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` impl coherent.

use std::fmt::{self, Debug, Display};

/// A message-carrying error. Context is prepended, anyhow-style:
/// `outer context: inner cause`.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap with an outer context message.
    pub fn context<C: Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, mirroring anyhow's.
pub trait Context<T> {
    fn context<C: Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Display> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an `Error` from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return with an `Error` built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file/1f9a").context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_chains() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert!(f(2).is_ok());
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        let e = anyhow!("v = {}", 7);
        assert_eq!(e.to_string(), "v = 7");
    }
}
