//! Integration: the full RL training loop over real artifacts (nano).
//!
//! These exercise the complete coordinator — scheduler + memory wall +
//! rollout + scoring + corrections + Eq. 7 updates — end to end, asserting
//! structural invariants rather than learning outcomes (learning curves
//! are the examples'/EXPERIMENTS.md's job).

use std::path::{Path, PathBuf};

use sparse_rl::config::{ExperimentConfig, RolloutMode};
use sparse_rl::coordinator::Trainer;
use sparse_rl::data::benchmarks;
use sparse_rl::runtime::{Method, ModelEngine, TrainState};

fn artifacts() -> Option<PathBuf> {
    for cand in ["artifacts/nano", "../artifacts/nano"] {
        let p = Path::new(cand);
        if p.join("manifest.json").exists() {
            return Some(p.to_path_buf());
        }
    }
    eprintln!("SKIP: artifacts/nano not built");
    None
}

fn mk_trainer(engine: &ModelEngine, mode: RolloutMode) -> Trainer<'_> {
    let mut cfg = ExperimentConfig::new(&engine.manifest.dir);
    cfg.mode = mode;
    cfg.seed = 17;
    cfg.train.prompts_per_step = 2; // 16 rollouts/step -> fast
    cfg.sampling.max_response = 48;
    let tasks = benchmarks::training_split_ops(64, engine.manifest.config.prompt_len, 17, 1, 2);
    let state = TrainState::new(engine.init_params(17).expect("init"));
    Trainer::new(engine, cfg, state, tasks)
}

#[test]
fn rl_step_dense_full_loop() {
    let Some(dir) = artifacts() else { return };
    let engine = ModelEngine::load(&dir).unwrap();
    let mut t = mk_trainer(&engine, RolloutMode::Dense);
    let before = t.state.params.clone();
    let r = t.rl_step().expect("rl step");
    // structural invariants
    assert!(r.response_len_mean > 0.0);
    assert!(r.entropy_mean > 0.0, "entropy {}", r.entropy_mean);
    assert_eq!(r.rejection_rate, 0.0, "dense mode must not reject");
    assert_eq!(r.toks_saving, 0.0, "dense mode saves nothing");
    assert!(t.state.step >= 1, "no updates applied");
    assert!(r.gen_tokens > 0);
    // dense mismatch KL is engine-numerics only: tiny
    assert!(
        r.mismatch_kl.abs() < 1e-2,
        "dense mismatch KL too large: {}",
        r.mismatch_kl
    );
    // params moved unless the whole batch was degenerate (possible but the
    // seed is fixed and produces some signal; tolerate both, require sane)
    let _ = before;
    // wall released
    assert_eq!(t.kv.reserved(), 0, "KV reservations leaked");
    // metrics recorded
    assert_eq!(t.metrics.len(), 1);
}

#[test]
fn rl_step_sparse_rl_applies_corrections() {
    let Some(dir) = artifacts() else { return };
    let engine = ModelEngine::load(&dir).unwrap();
    let mut t = mk_trainer(&engine, RolloutMode::SparseRl(Method::RKv));
    let r = t.rl_step().expect("rl step");
    // sparse rollouts must actually save KV once generations outlive the
    // capacity; with max_response 48 + prompt ≲ 16 vs capacity 48, most
    // random-init generations do
    assert!(r.toks_saving >= 0.0);
    assert!(r.mismatch_kl.abs() < 1.0, "wild mismatch KL {}", r.mismatch_kl);
    assert_eq!(t.kv.reserved(), 0);
    // sparse capacity reservations are smaller -> fewer chunks than seqs
    assert!(r.rollout_chunks <= 16);
}

#[test]
fn naive_sparse_skips_corrections() {
    let Some(dir) = artifacts() else { return };
    let engine = ModelEngine::load(&dir).unwrap();
    let mut t = mk_trainer(&engine, RolloutMode::NaiveSparse(Method::H2O));
    let r = t.rl_step().expect("rl step");
    assert_eq!(r.rejection_rate, 0.0, "naive mode must not reject");
}

#[test]
fn memory_wall_limits_dense_chunk_width() {
    let Some(dir) = artifacts() else { return };
    let engine = ModelEngine::load(&dir).unwrap();
    let mut t = mk_trainer(&engine, RolloutMode::Dense);
    // tighten the wall: only 2 dense sequences fit at once
    t.cfg.memory.global_kv_tokens = engine.manifest.config.max_seq * 2 + 10;
    t.kv = sparse_rl::coordinator::KvMemoryManager::new(t.cfg.memory.global_kv_tokens);
    let (seqs, rstats) = t.rollout_batch(&[0, 1]).expect("rollouts");
    let chunks = rstats.chunks;
    assert_eq!(seqs.len(), 16);
    assert!(
        chunks >= 8,
        "wall of 2 seqs should force >= 8 chunks for 16 seqs, got {chunks}"
    );
    assert_eq!(t.kv.reserved(), 0);
}

#[test]
fn continuous_engine_matches_static_on_real_artifacts() {
    // The real-model counterpart of tests/engine_equivalence.rs: the same
    // step on both engines must emit identical tokens and sampler logps
    // per task (batch-row independence + per-task RNG + exact slot
    // prefill splicing).
    let Some(dir) = artifacts() else { return };
    let engine = ModelEngine::load(&dir).unwrap();
    for mode in [RolloutMode::Dense, RolloutMode::SparseRl(Method::RKv)] {
        let mut ts = mk_trainer(&engine, mode);
        let mut tc = mk_trainer(&engine, mode);
        tc.cfg.engine = sparse_rl::config::EngineKind::Continuous;
        let (stat_seqs, stat_stats) = ts.rollout_batch(&[0, 1, 2]).expect("static");
        let (cont_seqs, cont_stats) = tc.rollout_batch(&[0, 1, 2]).expect("continuous");
        assert_eq!(stat_seqs.len(), cont_seqs.len());
        for (a, b) in stat_seqs.iter().zip(cont_seqs.iter()) {
            assert_eq!(a.task_idx, b.task_idx);
            assert_eq!(
                a.response_ids, b.response_ids,
                "engines diverged on task {} ({})",
                a.task_idx,
                mode.label()
            );
            assert_eq!(a.sampler_logp, b.sampler_logp, "logp diverged on task {}", a.task_idx);
            assert_eq!(a.finished, b.finished);
        }
        assert!(
            cont_stats.decode_steps <= stat_stats.decode_steps,
            "continuous used more decode steps ({} > {})",
            cont_stats.decode_steps,
            stat_stats.decode_steps
        );
        assert_eq!(ts.kv.reserved(), 0);
        assert_eq!(tc.kv.reserved(), 0);
    }
}

#[test]
fn pipelined_async_prefill_matches_static_on_real_artifacts() {
    // The real-model counterpart of the equivalence grid's prefill axis:
    // the pipelined engine with the REAL async prefill-executor thread
    // (prepare on the executor's EngineBackend, splice-apply on the
    // worker's) must emit identical tokens to the static engine.
    let Some(dir) = artifacts() else { return };
    let engine = ModelEngine::load(&dir).unwrap();
    for mode in [RolloutMode::Dense, RolloutMode::SparseRl(Method::RKv)] {
        let mut ts = mk_trainer(&engine, mode);
        let mut tp = mk_trainer(&engine, mode);
        tp.cfg.engine = sparse_rl::config::EngineKind::Pipelined;
        tp.cfg.rollout_workers = 2;
        tp.cfg.prefill = sparse_rl::config::PrefillMode::Async;
        let (stat_seqs, _) = ts.rollout_batch(&[0, 1, 2]).expect("static");
        let (pipe_seqs, pstats) = tp.rollout_batch(&[0, 1, 2]).expect("pipelined async");
        assert_eq!(stat_seqs.len(), pipe_seqs.len());
        for (a, b) in stat_seqs.iter().zip(pipe_seqs.iter()) {
            assert_eq!(
                a.response_ids, b.response_ids,
                "async pipelined diverged on task {} ({})",
                a.task_idx,
                mode.label()
            );
            assert_eq!(a.sampler_logp, b.sampler_logp, "logp diverged on task {}", a.task_idx);
        }
        assert_eq!(
            pstats.async_prefills_submitted, pstats.async_prefills_completed,
            "executor lost a submission ({})",
            mode.label()
        );
        assert_eq!(ts.kv.reserved(), 0);
        assert_eq!(tp.kv.reserved(), 0);
    }
}

#[test]
fn rl_step_runs_on_continuous_engine() {
    let Some(dir) = artifacts() else { return };
    let engine = ModelEngine::load(&dir).unwrap();
    let mut t = mk_trainer(&engine, RolloutMode::SparseRl(Method::RKv));
    t.cfg.engine = sparse_rl::config::EngineKind::Continuous;
    let r = t.rl_step().expect("rl step (continuous)");
    assert!(r.gen_tokens > 0);
    assert!(r.slot_occupancy > 0.0 && r.slot_occupancy <= 1.0);
    assert_eq!(r.rollout_chunks, 1, "continuous drains the queue in one pass");
    assert_eq!(t.kv.reserved(), 0, "KV reservations leaked");
}

#[test]
fn group_layout_is_prompt_major() {
    let Some(dir) = artifacts() else { return };
    let engine = ModelEngine::load(&dir).unwrap();
    let mut t = mk_trainer(&engine, RolloutMode::Dense);
    let (seqs, _) = t.rollout_batch(&[3, 7]).expect("rollouts");
    let g = t.cfg.train.group_size;
    // first g sequences share prompt of task 3, next g of task 7
    let p0 = &seqs[0].prompt_ids;
    for s in &seqs[..g] {
        assert_eq!(&s.prompt_ids, p0, "group 0 mixed prompts");
    }
    let p1 = &seqs[g].prompt_ids;
    assert_ne!(p0, p1, "distinct tasks should have distinct prompts");
    for s in &seqs[g..2 * g] {
        assert_eq!(&s.prompt_ids, p1, "group 1 mixed prompts");
    }
}

#[test]
fn pretrain_then_rl_smoke() {
    let Some(dir) = artifacts() else { return };
    let engine = ModelEngine::load(&dir).unwrap();
    let mut t = mk_trainer(&engine, RolloutMode::SparseRl(Method::SnapKv));
    let corpus = benchmarks::pretrain_corpus(128, engine.manifest.config.prompt_len, 5);
    let losses = t.pretrain(&corpus, 6, 0).expect("pretrain");
    assert_eq!(losses.len(), 6);
    assert!(losses.iter().all(|l| l.is_finite() && *l > 0.0));
    let r = t.rl_step().expect("rl step after pretrain");
    assert!(r.entropy_mean > 0.0);
}
