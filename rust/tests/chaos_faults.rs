//! Chaos harness: seeded backend fault injection against every engine
//! shell and the replica fleet.
//!
//! Runs entirely on the deterministic mock backend with a scripted
//! `FaultPlan` (`coordinator::mock`), so every fault fires at an exact,
//! reproducible call — no timing, no flakes. The contract under test is
//! the fault-tolerance tentpole:
//!
//! 1. **Retry absorption** — scripted `Err` bursts no longer than the
//!    `fault-retries` budget are invisible: tokens, logp bits, and
//!    accounting are identical to the fault-free run, and the
//!    `RolloutStats::retries` counter matches the plan's injected-error
//!    count exactly (backends fail BEFORE side effects, so a retried
//!    call is the identical call).
//! 2. **Quarantine conservation** — past the budget under
//!    `fault-policy = quarantine`, exactly the poisoned work is marked
//!    failed (one task on the per-task prefill path, the live wave on
//!    batch paths, the chunk on the static path), every other task is
//!    token-identical to the fault-free run, and the pool balances:
//!    admissions == releases, a quarantine IS a release, the wall
//!    drains to zero.
//! 3. **Abort is loud** — the default policy surfaces the injected
//!    error verbatim; injected panics cross thread joins as readable
//!    payloads ("injected fault: ... panicked"), never as deadlocks.
//! 4. **Replica failover** — a dead replica's work requeues to
//!    survivors and reruns token-identically (per-task RNG), requeue /
//!    death counters match the plan exactly, survivor pools conserve,
//!    and an all-dead fleet errors cleanly instead of hanging.

use sparse_rl::config::{EngineKind, FaultPolicy, PrefillMode, RolloutMode, SamplingConfig};
use sparse_rl::coordinator::{
    rollout_fleet, CostModel, FaultKind, FaultOp, FaultPlan, GenSeq, KvMemoryManager,
    MockModelBackend, Replica, RolloutCtx, RolloutPolicy, RolloutStats, Scheduler,
};
use sparse_rl::data::task::Task;
use sparse_rl::util::propcheck::{self, PropConfig};
use sparse_rl::util::rng::Rng;

const PROMPT_LEN: usize = 24;
const MAX_SEQ: usize = 40;
const SEED: u64 = 0xC4A0_5EED;

fn dense_backend(slots: usize) -> MockModelBackend {
    let mut b = MockModelBackend::dense(slots, PROMPT_LEN, MAX_SEQ, 32);
    b.eos_pull = 0.08;
    b
}

fn mk_sched(slots: usize) -> Scheduler {
    Scheduler::worst_case(slots, MAX_SEQ)
}

fn mk_kv(slots: usize) -> KvMemoryManager {
    KvMemoryManager::new(slots * MAX_SEQ)
}

fn mk_policy() -> RolloutPolicy {
    RolloutPolicy::new(
        RolloutMode::Dense,
        SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 12 },
    )
}

/// Tasks with pairwise-distinct prompts (the first token is pinned to
/// the task index) so a prompt-keyed fault targets exactly one task.
fn gen_tasks(n: usize, seed: u64) -> Vec<Task> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let mut t = Task::gen(&mut rng, 1, PROMPT_LEN);
            t.prompt_ids[0] = i as i32;
            t
        })
        .collect()
}

fn run_static(
    policy: &RolloutPolicy,
    backend: &mut MockModelBackend,
    tasks: &[Task],
    sched: &mut Scheduler,
    kv: &mut KvMemoryManager,
) -> Result<(Vec<GenSeq>, RolloutStats), String> {
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    policy
        .rollout_static_queue(backend, &flat, SEED, RolloutCtx::new(sched, kv))
        .map_err(|e| format!("{e:#}"))
}

fn run_continuous(
    policy: &RolloutPolicy,
    backend: &mut MockModelBackend,
    tasks: &[Task],
    sched: &mut Scheduler,
    kv: &mut KvMemoryManager,
) -> Result<(Vec<GenSeq>, RolloutStats), String> {
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    policy
        .rollout_continuous(backend, &flat, SEED, RolloutCtx::new(sched, kv))
        .map_err(|e| format!("{e:#}"))
}

fn run_pipelined(
    policy: &RolloutPolicy,
    proto: &MockModelBackend,
    tasks: &[Task],
    sched: &mut Scheduler,
    kv: &mut KvMemoryManager,
    workers: usize,
) -> Result<(Vec<GenSeq>, RolloutStats), String> {
    let mut backends: Vec<MockModelBackend> = (0..workers).map(|_| proto.clone()).collect();
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    if policy.prefill.is_async() {
        let mut exec = proto.clone();
        policy
            .rollout_pipelined(&mut backends, Some(&mut exec), &flat, SEED, RolloutCtx::new(sched, kv))
            .map_err(|e| format!("{e:#}"))
    } else {
        policy
            .rollout_pipelined(&mut backends, None, &flat, SEED, RolloutCtx::new(sched, kv))
            .map_err(|e| format!("{e:#}"))
    }
}

/// Same comparator the equivalence harness uses: tokens, logp bits,
/// finished flag, and the full KV accounting must agree.
fn seqs_equal(a: &GenSeq, b: &GenSeq) -> Result<(), String> {
    if a.task_idx != b.task_idx {
        return Err(format!("task_idx {} != {}", a.task_idx, b.task_idx));
    }
    if a.response_ids != b.response_ids {
        return Err(format!(
            "task {}: response_ids diverge\n  a: {:?}\n  b: {:?}",
            a.task_idx, a.response_ids, b.response_ids
        ));
    }
    if a.sampler_logp != b.sampler_logp {
        return Err(format!("task {}: sampler_logp not bit-identical", a.task_idx));
    }
    if a.finished != b.finished {
        return Err(format!("task {}: finished {} != {}", a.task_idx, a.finished, b.finished));
    }
    let (x, y) = (&a.accounting, &b.accounting);
    if x.integral_actual != y.integral_actual
        || x.integral_dense != y.integral_dense
        || x.peak_actual != y.peak_actual
        || x.peak_dense != y.peak_dense
        || x.steps != y.steps
        || x.compressions != y.compressions
        || x.evicted != y.evicted
    {
        return Err(format!("task {}: accounting diverges: {x:?} vs {y:?}", a.task_idx));
    }
    Ok(())
}

/// Fault-free continuous reference for a task set (the equivalence
/// suite already proves all engines/fleets agree with this).
fn reference_seqs(tasks: &[Task], slots: usize) -> Vec<GenSeq> {
    let policy = mk_policy();
    let (mut sched, mut kv) = (mk_sched(slots), mk_kv(slots));
    let (seqs, _) =
        run_continuous(&policy, &mut dense_backend(slots), tasks, &mut sched, &mut kv)
            .expect("fault-free reference run must succeed");
    seqs
}

// ---------------------------------------------------------------------
// 1. retry absorption
// ---------------------------------------------------------------------

#[test]
fn retry_budget_absorbs_error_bursts_token_identically() {
    let slots = 2;
    let tasks = gen_tasks(6, 0xC0FFEE);
    let reference = reference_seqs(&tasks, slots);
    let policy = mk_policy().with_fault_retries(3);

    // A 3-deep decode burst plus single prefill-path errors, all inside
    // the budget. `with_retries` re-attempts immediately, so a burst at
    // calls {2,3,4} is absorbed by one retry loop: 3 retries, then the
    // call at index 5 succeeds.
    let burst = |plan: FaultPlan| {
        plan.scripted(FaultOp::Decode, 2, FaultKind::Err)
            .scripted(FaultOp::Decode, 3, FaultKind::Err)
            .scripted(FaultOp::Decode, 4, FaultKind::Err)
    };

    // static: wave prefill (call 0) + the decode burst → exactly 4
    // injected errors, exactly 4 counted retries
    let plan = burst(FaultPlan::new().scripted(FaultOp::Prefill, 0, FaultKind::Err));
    let mut b = dense_backend(slots).with_faults(plan);
    let (mut sched, mut kv) = (mk_sched(slots), mk_kv(slots));
    let (seqs, stats) = run_static(&policy, &mut b, &tasks, &mut sched, &mut kv).unwrap();
    for (a, s) in reference.iter().zip(seqs.iter()) {
        seqs_equal(a, s).unwrap();
    }
    let fired = b.faults.as_ref().unwrap().injected_errs;
    assert_eq!(fired, 4, "static: plan must fire exactly");
    assert_eq!(stats.retries as u64, fired, "static: one retry per injected error");
    assert_eq!(stats.failed_tasks, 0);
    assert_eq!(kv.reserved(), 0);

    // continuous: additionally poison the first slot-refill (call 0 of
    // the per-task prefill path) → 5 errors, 5 retries
    let plan = burst(
        FaultPlan::new()
            .scripted(FaultOp::Prefill, 0, FaultKind::Err)
            .scripted(FaultOp::PrefillSlot, 0, FaultKind::Err),
    );
    let mut b = dense_backend(slots).with_faults(plan);
    let (mut sched, mut kv) = (mk_sched(slots), mk_kv(slots));
    let (seqs, stats) = run_continuous(&policy, &mut b, &tasks, &mut sched, &mut kv).unwrap();
    for (a, s) in reference.iter().zip(seqs.iter()) {
        seqs_equal(a, s).unwrap();
    }
    let fired = b.faults.as_ref().unwrap().injected_errs;
    assert_eq!(fired, 5, "continuous: plan must fire exactly");
    assert_eq!(stats.retries as u64, fired, "continuous: one retry per injected error");
    assert_eq!(stats.failed_tasks, 0);
    assert_eq!(sched.stats.quarantined, 0, "absorbed faults must not quarantine");
    assert_eq!(kv.reserved(), 0);

    // pipelined: every lane clone carries its own plan copy, so counts
    // are per-lane — assert absorption (tokens + zero failures), not
    // exact counters
    let plan = burst(FaultPlan::new().scripted(FaultOp::PrefillSlot, 0, FaultKind::Err));
    let proto = dense_backend(slots).with_faults(plan);
    let (mut sched, mut kv) = (mk_sched(slots), mk_kv(slots));
    let (seqs, stats) = run_pipelined(&policy, &proto, &tasks, &mut sched, &mut kv, 2).unwrap();
    for (a, s) in reference.iter().zip(seqs.iter()) {
        seqs_equal(a, s).unwrap();
    }
    assert_eq!(stats.failed_tasks, 0);
    assert_eq!(kv.reserved(), 0);
}

// ---------------------------------------------------------------------
// 2. quarantine: exactly the poisoned work fails, pools conserve
// ---------------------------------------------------------------------

#[test]
fn prompt_keyed_fault_quarantines_exactly_one_task() {
    let slots = 2;
    let tasks = gen_tasks(6, 0xBEEF);
    let reference = reference_seqs(&tasks, slots);
    // with 2 slots the wave admits tasks {0,1}; task 4 arrives by
    // refill, whose prefill carries the prompt the fault is keyed on —
    // and a prompt-keyed fault fires on EVERY attempt, so no retry
    // budget can absorb it
    let doomed = 4;
    let plan = FaultPlan::new().scripted_prompt(tasks[doomed].prompt_ids.clone(), FaultKind::Err);
    let policy =
        mk_policy().with_fault_retries(2).with_fault_policy(FaultPolicy::Quarantine);

    let mut b = dense_backend(slots).with_faults(plan);
    let (mut sched, mut kv) = (mk_sched(slots), mk_kv(slots));
    let (seqs, stats) = run_continuous(&policy, &mut b, &tasks, &mut sched, &mut kv).unwrap();

    assert_eq!(seqs.len(), tasks.len(), "quarantine must still deliver every position");
    assert!(seqs[doomed].failed, "the poisoned task must be marked failed");
    assert!(seqs[doomed].response_ids.is_empty(), "fault hit its prefill: no tokens");
    for (i, s) in seqs.iter().enumerate() {
        if i != doomed {
            assert!(!s.failed, "task {i} must survive");
            seqs_equal(&reference[i], s).unwrap();
        }
    }
    assert_eq!(stats.failed_tasks, 1);
    assert_eq!(stats.retries, 2, "the full budget was spent on the doomed task");
    assert_eq!(b.faults.as_ref().unwrap().injected_errs, 3, "1 attempt + 2 retries");

    // conservation: the quarantine is a release, not a leak
    assert_eq!(sched.stats.quarantined, 1);
    assert_eq!(sched.stats.seq_admissions, sched.stats.seq_releases);
    assert_eq!(sched.stats.live_seqs(), 0);
    assert_eq!(kv.reserved(), 0);
    kv.check_invariants().unwrap();
}

#[test]
fn decode_fault_past_budget_quarantines_the_live_wave_and_continues() {
    let slots = 2;
    let tasks = gen_tasks(6, 0xD0_0D1E);
    let reference = reference_seqs(&tasks, slots);
    // decode is a batch op: a failure past the budget takes down every
    // sequence live at that step, then the engine refills and goes on
    let plan = FaultPlan::new().scripted(FaultOp::Decode, 1, FaultKind::Err);
    let policy = mk_policy().with_fault_policy(FaultPolicy::Quarantine);

    let mut b = dense_backend(slots).with_faults(plan);
    let (mut sched, mut kv) = (mk_sched(slots), mk_kv(slots));
    let (seqs, stats) = run_continuous(&policy, &mut b, &tasks, &mut sched, &mut kv).unwrap();

    assert_eq!(seqs.len(), tasks.len());
    let failed: Vec<usize> =
        seqs.iter().enumerate().filter(|(_, s)| s.failed).map(|(i, _)| i).collect();
    assert!(!failed.is_empty(), "the live wave must have been quarantined");
    assert!(failed.len() <= slots, "at most one wave of casualties");
    assert_eq!(stats.failed_tasks, failed.len());
    assert_eq!(sched.stats.quarantined, failed.len());
    for (i, s) in seqs.iter().enumerate() {
        if !failed.contains(&i) {
            seqs_equal(&reference[i], s).unwrap();
        }
    }
    assert_eq!(sched.stats.seq_admissions, sched.stats.seq_releases);
    assert_eq!(sched.stats.live_seqs(), 0);
    assert_eq!(kv.reserved(), 0);
    kv.check_invariants().unwrap();
}

#[test]
fn static_prefill_fault_quarantines_the_chunk_and_continues() {
    let slots = 2;
    let tasks = gen_tasks(6, 0x57A71C);
    let reference = reference_seqs(&tasks, slots);
    // the static engine's failure domain is the chunk: its wave prefill
    // (call 0) dying past the budget fails tasks {0,1}, later chunks run
    let plan = FaultPlan::new().scripted(FaultOp::Prefill, 0, FaultKind::Err);
    let policy = mk_policy().with_fault_policy(FaultPolicy::Quarantine);

    let mut b = dense_backend(slots).with_faults(plan);
    let (mut sched, mut kv) = (mk_sched(slots), mk_kv(slots));
    let (seqs, stats) = run_static(&policy, &mut b, &tasks, &mut sched, &mut kv).unwrap();

    assert_eq!(seqs.len(), tasks.len());
    for (i, s) in seqs.iter().enumerate() {
        if i < slots {
            assert!(s.failed, "chunk-1 task {i} must be quarantined");
        } else {
            assert!(!s.failed, "task {i} is in a later chunk");
            seqs_equal(&reference[i], s).unwrap();
        }
    }
    assert_eq!(stats.failed_tasks, slots);
    assert_eq!(kv.reserved(), 0, "the poisoned chunk's reservation must drain");
    kv.check_invariants().unwrap();
}

// ---------------------------------------------------------------------
// 3. abort stays loud (and is the default)
// ---------------------------------------------------------------------

#[test]
fn abort_policy_surfaces_the_injected_error() {
    let slots = 2;
    let tasks = gen_tasks(6, 0xAB_0127);
    let plan = FaultPlan::new().scripted(FaultOp::Decode, 1, FaultKind::Err);
    let policy = mk_policy(); // default: retries 0, abort

    let mut b = dense_backend(slots).with_faults(plan);
    let (mut sched, mut kv) = (mk_sched(slots), mk_kv(slots));
    let err = run_continuous(&policy, &mut b, &tasks, &mut sched, &mut kv).unwrap_err();
    assert!(err.contains("injected fault: decode call 1 failed"), "got: {err}");
}

#[test]
fn pipelined_worker_panic_surfaces_payload_without_deadlock() {
    let slots = 2;
    let tasks = gen_tasks(6, 0x9A71C5);
    let plan = FaultPlan::new().scripted(FaultOp::Decode, 3, FaultKind::Panic);
    let proto = dense_backend(slots).with_faults(plan);
    let policy = mk_policy();

    let (mut sched, mut kv) = (mk_sched(slots), mk_kv(slots));
    let err = run_pipelined(&policy, &proto, &tasks, &mut sched, &mut kv, 2).unwrap_err();
    // the join path must fold the panic payload into a readable error
    // (a poisoned internal lock surfacing as a hang would time out CI)
    assert!(err.contains("panicked"), "got: {err}");
    assert!(err.contains("injected fault: decode call 3 panicked"), "got: {err}");
}

#[test]
fn prefill_executor_panic_surfaces_payload() {
    let slots = 2;
    let tasks = gen_tasks(6, 0xE8EC57);
    // async prefill: prepare_prefill runs on the dedicated executor
    // lane; its very first call panicking must come back as an error on
    // the joining side, not strand parked workers
    let plan = FaultPlan::new().scripted(FaultOp::PreparePrefill, 0, FaultKind::Panic);
    let proto = dense_backend(slots).with_faults(plan);
    let policy = mk_policy().with_prefill(PrefillMode::Async);

    let (mut sched, mut kv) = (mk_sched(slots), mk_kv(slots));
    let err = run_pipelined(&policy, &proto, &tasks, &mut sched, &mut kv, 2).unwrap_err();
    assert!(err.contains("panicked"), "got: {err}");
    assert!(err.contains("injected fault: prepare_prefill call 0 panicked"), "got: {err}");
}

// ---------------------------------------------------------------------
// 4. replica failover
// ---------------------------------------------------------------------

fn mk_fleet(
    replicas: usize,
    slots: usize,
    lanes: usize,
    costs: CostModel,
    poison: impl Fn(usize) -> Option<FaultPlan>,
) -> Vec<Replica<MockModelBackend>> {
    (0..replicas)
        .map(|r| {
            let backends = (0..lanes)
                .map(|_| {
                    let b = dense_backend(slots).with_costs(costs);
                    match poison(r) {
                        Some(plan) => b.with_faults(plan),
                        None => b,
                    }
                })
                .collect();
            Replica::new(mk_sched(slots), mk_kv(slots), backends)
        })
        .collect()
}

/// The plan that kills a replica outright: its wave prefill — the first
/// backend call every engine shell makes — panics past any budget.
fn lethal_plan() -> FaultPlan {
    FaultPlan::new().scripted(FaultOp::Prefill, 0, FaultKind::Panic)
}

#[test]
fn fleet_failover_requeues_dead_replica_work_token_identically() {
    let (slots, replicas, dead) = (2, 4, 1usize);
    let tasks = gen_tasks(10, 0xFA11);
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    let costs = CostModel::representative();
    let policy = mk_policy().with_fault_policy(FaultPolicy::Quarantine);

    for engine in [EngineKind::Static, EngineKind::Continuous, EngineKind::Pipelined] {
        let lanes = if engine == EngineKind::Pipelined { 2 } else { 1 };
        let grid = format!("engine={}", engine.label());

        // fault-free fleet reference (steal off: fully deterministic)
        let mut reps = mk_fleet(replicas, slots, lanes, costs, |_| None);
        let (ref_seqs, _, _) =
            rollout_fleet(&policy, engine, &mut reps, &flat, SEED, false).unwrap();

        let mut reps = mk_fleet(replicas, slots, lanes, costs, |r| {
            (r == dead).then(lethal_plan)
        });
        let (seqs, stats, report) =
            rollout_fleet(&policy, engine, &mut reps, &flat, SEED, false)
                .unwrap_or_else(|e| panic!("{grid}: failover must succeed: {e:#}"));

        // the death and every requeue are plan-exact: with stealing off
        // the doomed replica takes its whole queue as its first (fatal)
        // batch, so requeues == tasks the router sent it
        let routed_to_dead = report.routed.iter().filter(|&&r| r == dead).count();
        assert!(routed_to_dead > 0, "{grid}: router starved the test");
        assert_eq!(report.replica_deaths, 1, "{grid}");
        assert_eq!(stats.replica_deaths, 1, "{grid}");
        assert_eq!(report.requeues, routed_to_dead, "{grid}");
        assert_eq!(stats.requeues, routed_to_dead, "{grid}");
        assert_eq!(stats.failed_tasks, 0, "{grid}: requeued tasks must succeed");

        // requeued reruns are token-identical: per-task RNG keys on the
        // (seed, task index) pair, not on placement
        assert_eq!(seqs.len(), tasks.len(), "{grid}");
        for (a, s) in ref_seqs.iter().zip(seqs.iter()) {
            seqs_equal(a, s).unwrap_or_else(|e| panic!("{grid}: {e}"));
        }

        // survivor pools conserve; the dead pool is deliberately
        // stranded (its wall may hold the fatal batch's reservations)
        for (r, rep) in reps.iter().enumerate() {
            if r == dead {
                continue;
            }
            assert_eq!(rep.kv.reserved(), 0, "{grid}: replica {r} leaked KV");
            assert_eq!(rep.sched.stats.live_seqs(), 0, "{grid}: replica {r} not drained");
            assert_eq!(
                rep.sched.stats.seq_admissions, rep.sched.stats.seq_releases,
                "{grid}: replica {r} pool out of balance"
            );
            rep.kv.check_invariants().unwrap();
        }
    }
}

#[test]
fn fleet_with_no_survivors_errors_cleanly() {
    let slots = 2;
    let tasks = gen_tasks(6, 0xDEAD);
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    let policy = mk_policy().with_fault_policy(FaultPolicy::Quarantine);

    let mut reps = mk_fleet(2, slots, 1, CostModel::representative(), |_| Some(lethal_plan()));
    let err = rollout_fleet(&policy, EngineKind::Continuous, &mut reps, &flat, SEED, false)
        .unwrap_err()
        .to_string();
    assert!(err.contains("no survivors"), "got: {err}");
    assert!(err.contains("injected fault"), "the payload must survive the joins: {err}");
}

#[test]
fn fleet_failover_with_stealing_still_delivers_every_task() {
    // stealing + failover mutate the same queues; this is the race
    // smoke: one lethal replica, stealing ON — the step must complete
    // with every task delivered and token-identical (batch composition
    // is timing-dependent, so counters beyond the death are not exact)
    let (slots, replicas, dead) = (2, 4, 2usize);
    let tasks = gen_tasks(12, 0x57EA1);
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    let costs = CostModel::representative();
    let policy = mk_policy().with_fault_policy(FaultPolicy::Quarantine);

    let mut reps = mk_fleet(replicas, slots, 1, costs, |_| None);
    let (reference, _, _) =
        rollout_fleet(&policy, EngineKind::Continuous, &mut reps, &flat, SEED, false).unwrap();

    let mut reps = mk_fleet(replicas, slots, 1, costs, |r| (r == dead).then(lethal_plan));
    let (seqs, stats, _) =
        rollout_fleet(&policy, EngineKind::Continuous, &mut reps, &flat, SEED, true).unwrap();
    assert_eq!(seqs.len(), tasks.len());
    assert_eq!(stats.failed_tasks, 0);
    // the lethal replica dies at most once, and only if the router or a
    // steal actually handed it work before the fleet drained
    assert!(stats.replica_deaths <= 1);
    for (a, s) in reference.iter().zip(seqs.iter()) {
        seqs_equal(a, s).unwrap();
    }
}

#[test]
fn prop_fleet_chaos_death_plus_scattered_errors_is_absorbed() {
    // The acceptance scenario: a 4-replica fleet where one replica dies
    // on its first batch and every survivor sees scattered injected
    // errors well inside the retry budget. Whatever the engine shell,
    // geometry, or workload: the step completes (no hang), tokens are
    // identical to the fault-free fleet, the death/requeue counters
    // match the plan exactly, and survivor pools balance their books.
    propcheck::check(
        "fleet-chaos-failover",
        PropConfig { cases: 24, seed: 0xC4_A051, max_size: 40 },
        |rng, size| {
            let slots = 1 + rng.below(3);
            let n = 4 + rng.below(4 + size / 4);
            let seed = rng.next_u64();
            let tasks = gen_tasks(n, seed);
            let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
            let engine = *rng.choose(&[
                EngineKind::Static,
                EngineKind::Continuous,
                EngineKind::Pipelined,
            ]);
            let lanes = if engine == EngineKind::Pipelined { 1 + rng.below(2) } else { 1 };
            let dead = rng.below(4);
            let chaos_seed = rng.next_u64();
            let costs = CostModel::representative();
            let policy =
                mk_policy().with_fault_retries(4).with_fault_policy(FaultPolicy::Quarantine);
            let grid = format!("engine={} slots={slots} n={n} dead={dead}", engine.label());

            let mut reps = mk_fleet(4, slots, lanes, costs, |_| None);
            let (ref_seqs, _, _) = rollout_fleet(&policy, engine, &mut reps, &flat, seed, false)
                .map_err(|e| format!("{grid}: fault-free run failed: {e:#}"))?;

            let mut reps = mk_fleet(4, slots, lanes, costs, |r| {
                Some(if r == dead {
                    lethal_plan()
                } else {
                    // ~2% of survivor calls fail; 4 retries absorb any
                    // realistic run of them (p^5 per site)
                    FaultPlan::new().with_error_rate(0.02, chaos_seed ^ r as u64)
                })
            });
            let (seqs, stats, report) = rollout_fleet(&policy, engine, &mut reps, &flat, seed, false)
                .map_err(|e| format!("{grid}: chaos run failed: {e:#}"))?;

            let routed_to_dead = report.routed.iter().filter(|&&r| r == dead).count();
            if routed_to_dead == 0 {
                return Err(format!("{grid}: router starved the dead replica (n >= 4?)"));
            }
            if report.replica_deaths != 1 || stats.replica_deaths != 1 {
                return Err(format!("{grid}: deaths {} != plan's 1", report.replica_deaths));
            }
            if report.requeues != routed_to_dead {
                return Err(format!(
                    "{grid}: requeues {} != {} routed to the dead replica",
                    report.requeues, routed_to_dead
                ));
            }
            if stats.failed_tasks != 0 {
                return Err(format!("{grid}: {} tasks failed past the budget", stats.failed_tasks));
            }
            if seqs.len() != tasks.len() {
                return Err(format!("{grid}: {} of {} tasks delivered", seqs.len(), tasks.len()));
            }
            for (a, s) in ref_seqs.iter().zip(seqs.iter()) {
                seqs_equal(a, s).map_err(|e| format!("{grid}: {e}"))?;
            }
            for (r, rep) in reps.iter().enumerate() {
                if r == dead {
                    continue;
                }
                if rep.kv.reserved() != 0 || rep.sched.stats.live_seqs() != 0 {
                    return Err(format!("{grid}: survivor {r} leaked"));
                }
                if rep.sched.stats.seq_admissions != rep.sched.stats.seq_releases {
                    return Err(format!("{grid}: survivor {r} pool out of balance"));
                }
                rep.kv.check_invariants().map_err(|e| format!("{grid}: {e:#}"))?;
            }
            Ok(())
        },
    );
}
