//! Paged KV allocation harness: the PR-2 tentpole guarantees, plus the
//! eval-path regressions, all hermetic on the deterministic mock backend.
//!
//! 1. **Policy equivalence** — paged admission (page-granular reserve /
//!    grow / shrink, preempt-and-requeue on grow stalls) emits the exact
//!    same `response_ids`, bit-identical `sampler_logp`, and identical KV
//!    accounting as worst-case admission, across random geometries, page
//!    sizes, modes, and walls. Admission policy is a scheduling concern;
//!    per-task RNG keeps it invisible in the outputs.
//! 2. **Wall safety** — pages are conserved, the wall is never breached
//!    (`check_invariants` runs inside the engine's decode loop via
//!    debug_assert and here after every run), preempt/requeue always
//!    drains, and nothing leaks.
//! 3. **The throughput claim** — on a skewed-length workload, paged
//!    admission admits strictly wider and finishes in strictly fewer
//!    decode steps than worst-case reservation, dense AND sparse.
//! 4. **Eval regressions** — an empty benchmark yields a zero-item result
//!    (not NaN), and `evaluate_with_backend` is engine-agnostic: static,
//!    continuous (worst-case and paged), and pipelined (several worker
//!    counts, sync and async prefill) produce identical EvalResults.
//! 5. **Admission headroom** — `kv-admit-headroom-pages` is
//!    scheduling-only (token-identical) and damps the admit/preempt
//!    thrash cycle under extreme pressure.
//! 6. **Prefix sharing** — `prefix-sharing = group` (refcounted prompt
//!    pages + copy-on-write forks at compression) is token-identical to
//!    the unshared run on grouped workloads, never leaks a prefix, and
//!    scores identically through the eval path.

use sparse_rl::config::{
    AdmissionPolicy, EngineKind, PrefillMode, PrefixSharing, RolloutMode, SamplingConfig,
};
use sparse_rl::coordinator::{
    evaluate_with_backend, GenSeq, KvMemoryManager, MockModelBackend, RolloutCtx, RolloutPolicy,
    RolloutStats, Scheduler,
};
use sparse_rl::data::task::Task;
use sparse_rl::runtime::Method;
use sparse_rl::util::propcheck::{self, PropConfig};
use sparse_rl::util::rng::Rng;

fn worst_case(slots: usize, reserve: usize) -> Scheduler {
    Scheduler::worst_case(slots, reserve)
}

fn paged(slots: usize, reserve: usize) -> Scheduler {
    Scheduler::worst_case(slots, reserve).with_admission(AdmissionPolicy::Paged)
}

fn seqs_equal(a: &GenSeq, b: &GenSeq) -> Result<(), String> {
    if a.task_idx != b.task_idx || a.finished != b.finished {
        return Err(format!("task {} header diverges", a.task_idx));
    }
    if a.response_ids != b.response_ids {
        return Err(format!(
            "task {}: response_ids diverge under paged admission\n  worst-case: {:?}\n  paged:      {:?}",
            a.task_idx, a.response_ids, b.response_ids
        ));
    }
    if a.sampler_logp != b.sampler_logp {
        return Err(format!("task {}: sampler_logp not bit-identical", a.task_idx));
    }
    let (x, y) = (&a.accounting, &b.accounting);
    if x.integral_actual != y.integral_actual
        || x.peak_actual != y.peak_actual
        || x.steps != y.steps
        || x.compressions != y.compressions
    {
        return Err(format!("task {}: accounting diverges: {x:?} vs {y:?}", a.task_idx));
    }
    Ok(())
}

/// One random paged scenario: geometry, mode, page size, wall.
struct Scenario {
    mode: RolloutMode,
    sampling: SamplingConfig,
    tasks: Vec<Task>,
    slots: usize,
    prompt_len: usize,
    max_seq: usize,
    budget: usize,
    buffer: usize,
    reserve: usize,
    page: usize,
    kv_cap: usize,
    seed: u64,
    eos_pull: f32,
}

impl Scenario {
    fn gen(rng: &mut Rng, size: usize) -> Scenario {
        let slots = 1 + rng.below(5);
        let prompt_len = 24;
        let max_seq = prompt_len + 2 + rng.below(40);
        let budget = 20 + rng.below(8); // sparse capacity must fit a prompt
        let buffer = 4 + rng.below(6);
        let mode = match rng.below(3) {
            0 => RolloutMode::Dense,
            1 => RolloutMode::NaiveSparse(Method::RKv),
            _ => RolloutMode::SparseRl(Method::RKv),
        };
        let sampling = SamplingConfig {
            temperature: *rng.choose(&[1.0f32, 0.85]),
            top_p: *rng.choose(&[1.0f32, 0.92]),
            max_response: 2 + rng.below(30),
        };
        let n = 1 + rng.below(2 * slots + 2 + size / 8);
        let tasks: Vec<Task> = (0..n)
            .map(|_| {
                let ops = 1 + rng.below(2);
                Task::gen(rng, ops, prompt_len)
            })
            .collect();
        let capacity = if mode.is_sparse() { budget + buffer } else { max_seq };
        let reserve = capacity;
        let page = 1 + rng.below(8);
        // the wall must at least hold one worst-case sequence in whole
        // pages (the engine's progress guarantee), and is otherwise
        // anywhere between tight (heavy preemption) and roomy
        let one = reserve.div_ceil(page) * page;
        let width_target = 1 + rng.below(slots + 2);
        let kv_cap = one * width_target + rng.below(one);
        Scenario {
            mode,
            sampling,
            tasks,
            slots,
            prompt_len,
            max_seq,
            budget,
            buffer,
            reserve,
            page,
            kv_cap,
            seed: rng.next_u64(),
            eos_pull: *rng.choose(&[0.25f32, 0.08, 0.02]),
        }
    }

    fn backend(&self) -> MockModelBackend {
        let mut b = if self.mode.is_sparse() {
            MockModelBackend::sparse(
                self.slots,
                self.prompt_len,
                self.max_seq,
                32,
                self.budget,
                self.buffer,
            )
        } else {
            MockModelBackend::dense(self.slots, self.prompt_len, self.max_seq, 32)
        };
        b.eos_pull = self.eos_pull;
        b
    }

    fn policy(&self) -> RolloutPolicy {
        RolloutPolicy::new(self.mode, self.sampling)
    }
}

fn run(
    policy: &RolloutPolicy,
    backend: &mut MockModelBackend,
    tasks: &[Task],
    seed: u64,
    sched: &mut Scheduler,
    kv: &mut KvMemoryManager,
) -> Result<(Vec<GenSeq>, RolloutStats), String> {
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    policy
        .rollout_continuous(backend, &flat, seed, RolloutCtx::new(sched, kv))
        .map_err(|e| e.to_string())
}

#[test]
fn prop_paged_admission_token_identical_and_wall_safe() {
    propcheck::check(
        "paged-worst-case-equivalence",
        PropConfig { cases: 96, seed: 0x9A_6ED0, max_size: 48 },
        |rng, size| {
            let sc = Scenario::gen(rng, size);
            let policy = sc.policy();

            // reference: worst-case admission, token-granular wall
            let mut kv_w = KvMemoryManager::new(sc.kv_cap);
            let mut sched_w = worst_case(sc.slots, sc.reserve);
            let (wc, _) =
                run(&policy, &mut sc.backend(), &sc.tasks, sc.seed, &mut sched_w, &mut kv_w)?;

            // paged admission, page-granular wall
            let mut kv_p = KvMemoryManager::with_pages(sc.kv_cap, sc.page);
            let mut sched_p = paged(sc.slots, sc.reserve);
            let (pg, pg_stats) =
                run(&policy, &mut sc.backend(), &sc.tasks, sc.seed, &mut sched_p, &mut kv_p)?;

            // 1) token/logp/accounting equivalence per task
            if wc.len() != pg.len() {
                return Err("result count mismatch".into());
            }
            for (a, b) in wc.iter().zip(pg.iter()) {
                seqs_equal(a, b)?;
            }

            // 2) wall safety: nothing leaked, invariants hold, observed
            //    residency never breached the pool
            if kv_p.reserved() != 0 || kv_p.used_pages() != 0 {
                return Err(format!("paged run leaked {} tokens", kv_p.reserved()));
            }
            kv_p.check_invariants().map_err(|e| e.to_string())?;
            if pg_stats.max_used_pages > kv_p.total_pages() {
                return Err(format!(
                    "observed {} pages in a pool of {}",
                    pg_stats.max_used_pages,
                    kv_p.total_pages()
                ));
            }
            if pg_stats.max_reserved_kv > kv_p.capacity() {
                return Err("observed token residency breached the wall".into());
            }
            if kv_p.peak_used_pages < pg_stats.max_used_pages {
                return Err("peak_used_pages below an observed residency".into());
            }

            // 3) scheduler bookkeeping: every admission was balanced by a
            //    release (finish or preemption), and the engine counted
            //    the same preemptions the scheduler performed
            if sched_p.stats.live_seqs() != 0 {
                return Err("scheduler live_seqs not drained".into());
            }
            if sched_p.stats.preemptions != pg_stats.preemptions {
                return Err(format!(
                    "preemption counters diverge: sched {} vs stats {}",
                    sched_p.stats.preemptions, pg_stats.preemptions
                ));
            }
            if sched_p.stats.seq_admissions
                != sc.tasks.len() + sched_p.stats.preemptions
            {
                return Err(format!(
                    "admissions {} != tasks {} + preemptions {}",
                    sched_p.stats.seq_admissions,
                    sc.tasks.len(),
                    sched_p.stats.preemptions
                ));
            }

            // 4) paged determinism: a rerun reproduces stats exactly
            let mut kv_p2 = KvMemoryManager::with_pages(sc.kv_cap, sc.page);
            let mut sched_p2 = paged(sc.slots, sc.reserve);
            let (pg2, pg2_stats) =
                run(&policy, &mut sc.backend(), &sc.tasks, sc.seed, &mut sched_p2, &mut kv_p2)?;
            for (a, b) in pg.iter().zip(pg2.iter()) {
                seqs_equal(a, b)?;
            }
            if pg_stats != pg2_stats {
                return Err("paged stats not reproducible".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_prefix_sharing_token_identical_and_pool_safe() {
    // Grouped workloads (GRPO-style duplicated prompts) under paged
    // admission: `prefix-sharing = group` must be a pure accounting
    // change. Tokens, logp, and KV accounting match the unshared run
    // bit-for-bit across random geometries/modes/walls, the refcounted
    // pool drains completely (no prefix outlives its last sharer), and
    // the engine's prefill-attach counters stay self-consistent.
    propcheck::check(
        "prefix-sharing-equivalence",
        PropConfig { cases: 72, seed: 0x5AAE_D0, max_size: 48 },
        |rng, size| {
            let mut sc = Scenario::gen(rng, size);
            let g = 2 + rng.below(3);
            let n = sc.tasks.len();
            for i in 0..n {
                sc.tasks[i] = sc.tasks[(i / g) * g].clone();
            }

            // reference: paged admission, sharing off
            let policy = sc.policy();
            let mut kv_off = KvMemoryManager::with_pages(sc.kv_cap, sc.page);
            let mut sched_off = paged(sc.slots, sc.reserve);
            let (off, off_stats) =
                run(&policy, &mut sc.backend(), &sc.tasks, sc.seed, &mut sched_off, &mut kv_off)?;

            // sharing on: siblings attach to the refcounted prompt prefix
            let shared_policy = sc.policy().with_sharing(PrefixSharing::Group);
            let mut kv_s = KvMemoryManager::with_pages(sc.kv_cap, sc.page);
            let mut sched_s = paged(sc.slots, sc.reserve).with_sharing(PrefixSharing::Group);
            let (sh, sh_stats) = run(
                &shared_policy,
                &mut sc.backend(),
                &sc.tasks,
                sc.seed,
                &mut sched_s,
                &mut kv_s,
            )?;

            // 1) token/logp/accounting equivalence per task
            if off.len() != sh.len() {
                return Err("result count mismatch".into());
            }
            for (a, b) in off.iter().zip(sh.iter()) {
                seqs_equal(a, b)?;
            }

            // 2) the refcounted pool drains: no pages, no prefixes, no
            //    reservations survive the run
            if kv_s.reserved() != 0 || kv_s.used_pages() != 0 {
                return Err(format!("shared run leaked {} tokens", kv_s.reserved()));
            }
            if kv_s.live_prefixes() != 0 {
                return Err(format!("{} prefix entries leaked", kv_s.live_prefixes()));
            }
            kv_s.check_invariants().map_err(|e| e.to_string())?;
            if sched_s.stats.live_seqs() != 0 {
                return Err("shared scheduler live_seqs not drained".into());
            }

            // 3) counter hygiene: every continuous refill is exactly one
            //    slot prefill OR one shared attach (refill counts CAN
            //    differ from the off run — sharing widens admission, which
            //    shifts the preempt/requeue pattern); the off run must not
            //    touch the sharing machinery at all
            if sh_stats.slot_prefills + sh_stats.shared_prefill_attaches != sh_stats.refills {
                return Err(format!(
                    "prefill counters leak: {} slot + {} attach != {} refills",
                    sh_stats.slot_prefills, sh_stats.shared_prefill_attaches, sh_stats.refills
                ));
            }
            if off_stats.shared_prefill_attaches != 0
                || sched_off.stats.shared_admissions != 0
                || sched_off.stats.cow_forks != 0
            {
                return Err("sharing=off run touched the sharing machinery".into());
            }
            Ok(())
        },
    );
}

#[test]
fn paged_admission_raises_width_and_saves_decode_steps() {
    // The acceptance scenario: skewed-length workload on a memory-limited
    // wall. Worst-case admission caps the batch at 3 sequences; paged
    // admission rides actual residency — strictly wider, strictly fewer
    // decode steps, dense and sparse, identical tokens.
    let (slots, prompt_len, max_seq, budget, buffer) = (8usize, 16usize, 160usize, 40usize, 16usize);
    let (page, seed) = (4usize, 7u64);
    let sampling = SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 64 };
    let mut rng = Rng::new(1);
    let tasks: Vec<Task> = (0..48)
        .map(|_| {
            let ops = 1 + rng.below(2);
            Task::gen(&mut rng, ops, prompt_len)
        })
        .collect();

    for mode in [RolloutMode::Dense, RolloutMode::SparseRl(Method::RKv)] {
        let policy = RolloutPolicy::new(mode, sampling);
        let capacity = if mode.is_sparse() { budget + buffer } else { max_seq };
        let reserve = capacity;
        let kv_cap = reserve * 3; // worst-case width: exactly 3
        let backend = || {
            let mut b = if mode.is_sparse() {
                MockModelBackend::sparse(slots, prompt_len, max_seq, 32, budget, buffer)
            } else {
                MockModelBackend::dense(slots, prompt_len, max_seq, 32)
            };
            b.eos_pull = 0.15;
            b
        };

        let mut kv_w = KvMemoryManager::new(kv_cap);
        let mut sched_w = worst_case(slots, reserve);
        let (wc, wc_stats) =
            run(&policy, &mut backend(), &tasks, seed, &mut sched_w, &mut kv_w).unwrap();
        let mut kv_p = KvMemoryManager::with_pages(kv_cap, page);
        let mut sched_p = paged(slots, reserve);
        let (pg, pg_stats) =
            run(&policy, &mut backend(), &tasks, seed, &mut sched_p, &mut kv_p).unwrap();

        for (a, b) in wc.iter().zip(pg.iter()) {
            seqs_equal(a, b).unwrap();
        }
        kv_p.check_invariants().unwrap();
        assert_eq!(wc_stats.peak_live_slots, 3, "{}: geometry drifted", mode.label());
        assert!(
            pg_stats.peak_live_slots > wc_stats.peak_live_slots,
            "{}: paged width {} !> worst-case {}",
            mode.label(),
            pg_stats.peak_live_slots,
            wc_stats.peak_live_slots
        );
        assert!(
            pg_stats.decode_steps < wc_stats.decode_steps,
            "{}: paged decode steps {} !< worst-case {} ({} preemptions)",
            mode.label(),
            pg_stats.decode_steps,
            wc_stats.decode_steps,
            pg_stats.preemptions
        );
    }
}

#[test]
fn admit_headroom_cuts_preemption_thrash() {
    // Extreme pressure: paged admission on a wall two worst-case
    // sequences wide, long responses, cheap prompts. With headroom 0 the
    // scheduler packs admissions flush against the wall, so growth stalls
    // immediately and newly admitted (lowest-progress) sequences are
    // preempted right back off — the admit/preempt thrash cycle the
    // `kv-admit-headroom-pages` knob exists to damp. The knob is
    // scheduling-only (identical tokens), and in aggregate over several
    // seeds the extra headroom must cut the preemption count.
    let (slots, prompt_len, max_seq, budget, buffer) = (6usize, 12usize, 96usize, 24usize, 8usize);
    let page = 4usize;
    let mode = RolloutMode::SparseRl(Method::RKv);
    let sampling = SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 48 };
    let policy = RolloutPolicy::new(mode, sampling);
    let reserve = budget + buffer; // 32 tokens = 8 pages
    let kv_cap = reserve * 2; // 16 pages: heavy growth pressure
    let run_at = |headroom: usize, seed: u64| {
        let mut backend = MockModelBackend::sparse(slots, prompt_len, max_seq, 32, budget, buffer);
        backend.eos_pull = 0.05; // long responses -> sustained growth
        let mut rng = Rng::new(seed);
        let tasks: Vec<Task> = (0..24).map(|_| Task::gen(&mut rng, 1, prompt_len)).collect();
        let mut kv = KvMemoryManager::with_pages(kv_cap, page);
        let mut sched = paged(slots, reserve).with_headroom(headroom);
        let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
        let (seqs, stats) = policy
            .rollout_continuous(&mut backend, &flat, seed, RolloutCtx::new(&mut sched, &mut kv))
            .expect("rollout under pressure");
        assert_eq!(kv.reserved(), 0, "headroom {headroom}: leaked KV");
        kv.check_invariants().unwrap();
        (seqs, stats)
    };

    let (mut thrash0, mut thrash2) = (0usize, 0usize);
    for seed in [3u64, 7, 13, 29] {
        let (s0, st0) = run_at(0, seed);
        let (s2, st2) = run_at(2, seed);
        for (a, b) in s0.iter().zip(s2.iter()) {
            seqs_equal(a, b).expect("headroom changed tokens (BUG)");
        }
        thrash0 += st0.preemptions;
        thrash2 += st2.preemptions;
    }
    assert!(thrash0 > 0, "pressure scenario produced no thrash at headroom 0");
    assert!(
        thrash2 < thrash0,
        "headroom failed to cut preempt/readmit thrash: {thrash2} !< {thrash0}"
    );
}

#[test]
fn paged_wall_too_small_for_one_sequence_errors_cleanly() {
    // a pool that cannot hold even one worst-case sequence must refuse up
    // front (the preempt/requeue loop could otherwise thrash forever)
    let policy = RolloutPolicy::new(
        RolloutMode::Dense,
        SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 8 },
    );
    let mut rng = Rng::new(3);
    let tasks = vec![Task::gen(&mut rng, 1, 24)];
    let mut backend = MockModelBackend::dense(2, 24, 64, 32);
    let mut kv = KvMemoryManager::with_pages(40, 8); // 5 pages < 64 tokens
    let mut sched = paged(2, 64);
    let err = run(&policy, &mut backend, &tasks, 0, &mut sched, &mut kv).unwrap_err();
    assert!(err.contains("deadlock"), "unexpected error: {err}");
}

// ---------------------------------------------------------------- eval --

fn eval_setup(n_items: usize) -> (RolloutPolicy, Vec<Task>, MockModelBackend, usize, usize) {
    let (slots, prompt_len, max_seq) = (4usize, 24usize, 96usize);
    let mut rng = Rng::new(11);
    let tasks: Vec<Task> = (0..n_items).map(|_| Task::gen(&mut rng, 1, prompt_len)).collect();
    let policy = RolloutPolicy::new(
        RolloutMode::Dense,
        SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 24 },
    );
    let backend = MockModelBackend::dense(slots, prompt_len, max_seq, 32);
    (policy, tasks, backend, slots, max_seq)
}

#[test]
fn empty_benchmark_eval_is_zero_items_not_nan() {
    // regression: dividing by tasks.len() / (tasks.len() * k) unguarded
    // produced NaN accuracy that silently poisoned the suite macro-average
    let (policy, _, backend, slots, reserve) = eval_setup(0);
    let mut sched = worst_case(slots, reserve);
    let mut kv = KvMemoryManager::new(reserve * slots);
    let r = evaluate_with_backend(
        &policy,
        &mut [backend],
        EngineKind::Static,
        &mut sched,
        &mut kv,
        "empty",
        &[],
        4,
        0,
    )
    .unwrap();
    assert_eq!(r.items, 0);
    assert_eq!(r.samples, 0);
    assert_eq!(r.accuracy, 0.0);
    assert!(!r.accuracy.is_nan() && !r.mean_response_len.is_nan());
}

#[test]
fn eval_is_engine_agnostic() {
    // regression: evaluate() always static-chunked regardless of the
    // `engine = continuous` knob. The continuous path (and the paged
    // continuous path, and the pipelined path at several worker counts)
    // must score identically — per-task RNG keys off the flat sample id,
    // not the engine.
    let (policy, tasks, _, slots, reserve) = eval_setup(6);
    let k = 3;
    let mk_backends = |n: usize| -> Vec<MockModelBackend> {
        (0..n).map(|_| MockModelBackend::dense(4, 24, 96, 32)).collect()
    };

    let mut results = Vec::new();
    // (engine, admission, page, backend lanes, prefill mode) — for the
    // async-pipelined rows the LAST backend is the prefill-executor lane
    // (the evaluate_with_backend convention), so worker counts are
    // lanes - 1 there
    for (kind, admission, page, lanes, prefill) in [
        (EngineKind::Static, AdmissionPolicy::WorstCase, 1usize, 1usize, PrefillMode::Sync),
        (EngineKind::Continuous, AdmissionPolicy::WorstCase, 1, 1, PrefillMode::Sync),
        (EngineKind::Continuous, AdmissionPolicy::Paged, 4, 1, PrefillMode::Sync),
        (EngineKind::Pipelined, AdmissionPolicy::WorstCase, 1, 2, PrefillMode::Sync),
        (EngineKind::Pipelined, AdmissionPolicy::Paged, 4, 3, PrefillMode::Sync),
        (EngineKind::Pipelined, AdmissionPolicy::WorstCase, 1, 3, PrefillMode::Async),
        (EngineKind::Pipelined, AdmissionPolicy::Paged, 4, 3, PrefillMode::Async),
    ] {
        let mut sched = worst_case(slots, reserve).with_admission(admission);
        let mut kv = KvMemoryManager::with_pages(reserve * 3, page);
        let r = evaluate_with_backend(
            &policy.with_prefill(prefill),
            &mut mk_backends(lanes),
            kind,
            &mut sched,
            &mut kv,
            "agnostic",
            &tasks,
            k,
            42,
        )
        .unwrap();
        assert_eq!(kv.reserved(), 0, "eval leaked KV");
        results.push(r);
    }
    let base = &results[0];
    assert_eq!(base.items, 6);
    assert_eq!(base.samples, 18);
    for r in &results[1..] {
        assert_eq!(r.accuracy, base.accuracy, "accuracy diverged across engines");
        assert_eq!(r.mean_response_len, base.mean_response_len);
        assert_eq!(r.items, base.items);
        assert_eq!(r.samples, base.samples);
        assert_eq!(r.toks_saving, base.toks_saving);
    }

    // prefix sharing must not change a single score either: eval fans k
    // identical prompts per item — exactly the sharing workload
    let mut sched = worst_case(slots, reserve)
        .with_admission(AdmissionPolicy::Paged)
        .with_sharing(PrefixSharing::Group);
    let mut kv = KvMemoryManager::with_pages(reserve * 3, 4);
    let r = evaluate_with_backend(
        &policy.with_sharing(PrefixSharing::Group),
        &mut mk_backends(1),
        EngineKind::Continuous,
        &mut sched,
        &mut kv,
        "agnostic",
        &tasks,
        k,
        42,
    )
    .unwrap();
    assert_eq!(kv.reserved(), 0, "shared eval leaked KV");
    assert_eq!(kv.live_prefixes(), 0, "shared eval leaked a prefix");
    assert!(
        sched.stats.shared_admissions > 0,
        "k identical prompts per item never shared a prefix"
    );
    assert_eq!(r.accuracy, base.accuracy, "prefix sharing changed a score");
    assert_eq!(r.mean_response_len, base.mean_response_len);
    assert_eq!(r.toks_saving, base.toks_saving);
}
