//! Determinism/equivalence harness for the three rollout engines.
//!
//! Runs entirely on the deterministic mock backend (`coordinator::mock`),
//! so these properties execute hermetically — no artifacts, no PJRT. The
//! contract under test is the tentpole guarantee of the engine-layer
//! refactor (one decode core, three scheduling shells):
//!
//! 1. **Token equivalence** — for every task, the static chunked engine
//!    and the continuous slot-recycling engine emit identical
//!    `response_ids`, bit-identical `sampler_logp`, the same `finished`
//!    flag, and the same KV accounting, across random seeds, modes
//!    (dense / naive / sparse-rl), sampling configs, slot widths, memory
//!    walls, AND admission orders (fifo vs shortest-first). This is what
//!    keeps the Eq. 2/5 correction math bit-reproducible regardless of
//!    engine or scheduling knob.
//! 2. **Memory-wall invariants** — reserved KV never exceeds capacity at
//!    any decode step, everything is released at drain, and the manager's
//!    `peak_reserved` high-water mark is monotone-consistent.
//! 3. **Step-exact scheduling** — the continuous engine's decode-step
//!    count equals the scheduler's closed-form list-scheduling prediction
//!    *over the admission order*, and the static engine's equals the
//!    chunked closed form; continuous is never worse and strictly better
//!    under skewed lengths.
//! 4. **Pipelined equivalence** — the pipelined worker-pool engine is
//!    token-identical to continuous (and static) for every task over the
//!    full grid {workers 1/2/4} × {steal on/off} × {fifo,
//!    shortest-first} × {prefill sync/async} × {chunked prefill off/on,
//!    `prefill-chunk-tokens` 0/12} (override the counts with
//!    `ROLLOUT_WORKERS=n`; async runs a REAL prefill-executor thread
//!    against the mock), its slot-step accounting obeys the shared
//!    denominator contract (`occupied + idle == decode_steps * slots`),
//!    and a preemption-heavy multi-worker run on a tiny paged wall —
//!    with and without stealing, in both prefill modes — neither
//!    deadlocks nor leaks a page.
//! 5. **Fleet equivalence** — the replica tier (`rollout_fleet`) is
//!    token-identical to the single-engine paths over the {replicas 1,
//!    2, 4} × {engine} × {replica-steal on/off} grid, each replica's
//!    private pool conserves (drained wall, balanced admissions), zero
//!    cross-replica steals happen when stealing is off or impossible,
//!    and the fleet-level stats compose by parallel merge (makespan =
//!    slowest replica, lanes sum).

use sparse_rl::config::{
    AdmissionOrder, AdmissionPolicy, EngineKind, PrefillMode, PrefixSharing, RolloutMode,
    SamplingConfig,
};
use sparse_rl::coordinator::{
    rollout_fleet, CostModel, GenSeq, KvMemoryManager, MockModelBackend, Replica, RolloutBackend,
    RolloutCtx, RolloutPolicy, RolloutStats, Scheduler,
};
use sparse_rl::data::task::Task;
use sparse_rl::runtime::Method;
use sparse_rl::util::propcheck::{self, PropConfig};
use sparse_rl::util::rng::Rng;

fn mk_sched(slots: usize, reserve: usize) -> Scheduler {
    Scheduler::worst_case(slots, reserve)
}

/// Worker counts the pipelined properties run at. CI pins one count per
/// job via `ROLLOUT_WORKERS` (1 and 4); local runs sweep all three.
fn worker_counts() -> Vec<usize> {
    match std::env::var("ROLLOUT_WORKERS") {
        Ok(v) => vec![v
            .parse()
            .expect("ROLLOUT_WORKERS must be a positive integer")],
        Err(_) => vec![1, 2, 4],
    }
}

/// The sequence the engines admit tasks in: task order under fifo, stable
/// ascending admission cost (`Scheduler::admission_cost`, the unclamped
/// residency prediction) under shortest-first — repeatedly popping the
/// first queue element with minimal cost, with no mid-run arrivals, is
/// exactly a stable sort: the order replay the step-exact closed forms
/// need.
fn admission_order_indices(
    sched: &Scheduler,
    tasks: &[Task],
    max_response: usize,
    order: AdmissionOrder,
) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..tasks.len()).collect();
    if order == AdmissionOrder::ShortestFirst {
        idx.sort_by_key(|&i| sched.admission_cost(tasks[i].prompt_ids.len(), max_response));
    }
    idx
}

/// Drive the static engine exactly the way the trainer does: the shared
/// `rollout_static_queue` driver (chunk admission against the wall,
/// synchronous drain, results in task order).
#[allow(clippy::too_many_arguments)]
fn run_static(
    policy: &RolloutPolicy,
    backend: &mut MockModelBackend,
    tasks: &[Task],
    seed: u64,
    reserve: usize,
    kv: &mut KvMemoryManager,
    order: AdmissionOrder,
) -> Result<(Vec<GenSeq>, RolloutStats), String> {
    let mut sched = mk_sched(backend.slots(), reserve).with_order(order);
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    policy
        .rollout_static_queue(backend, &flat, seed, RolloutCtx::new(&mut sched, kv))
        .map_err(|e| e.to_string())
}

#[allow(clippy::too_many_arguments)]
fn run_continuous(
    policy: &RolloutPolicy,
    backend: &mut MockModelBackend,
    tasks: &[Task],
    seed: u64,
    reserve: usize,
    kv: &mut KvMemoryManager,
    order: AdmissionOrder,
) -> Result<(Vec<GenSeq>, RolloutStats), String> {
    let mut sched = mk_sched(backend.slots(), reserve).with_order(order);
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    policy
        .rollout_continuous(backend, &flat, seed, RolloutCtx::new(&mut sched, kv))
        .map_err(|e| e.to_string())
}

/// Run the pipelined engine with `workers` lanes (one cloned backend
/// each) over the shared scheduler/wall. When the policy selects
/// `prefill = async`, a real executor thread runs on one extra backend
/// clone — the physical delivery path is under test, not simulated.
#[allow(clippy::too_many_arguments)]
fn run_pipelined(
    policy: &RolloutPolicy,
    proto: &MockModelBackend,
    tasks: &[Task],
    seed: u64,
    sched: &mut Scheduler,
    kv: &mut KvMemoryManager,
    workers: usize,
) -> Result<(Vec<GenSeq>, RolloutStats), String> {
    let mut backends: Vec<MockModelBackend> = (0..workers).map(|_| proto.clone()).collect();
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    if policy.prefill.is_async() {
        let mut exec = proto.clone();
        policy
            .rollout_pipelined(&mut backends, Some(&mut exec), &flat, seed, RolloutCtx::new(sched, kv))
            .map_err(|e| e.to_string())
    } else {
        policy
            .rollout_pipelined(&mut backends, None, &flat, seed, RolloutCtx::new(sched, kv))
            .map_err(|e| e.to_string())
    }
}

fn seqs_equal(a: &GenSeq, b: &GenSeq) -> Result<(), String> {
    if a.task_idx != b.task_idx {
        return Err(format!("task_idx {} != {}", a.task_idx, b.task_idx));
    }
    if a.response_ids != b.response_ids {
        return Err(format!(
            "task {}: response_ids diverge\n  a: {:?}\n  b: {:?}",
            a.task_idx, a.response_ids, b.response_ids
        ));
    }
    if a.sampler_logp != b.sampler_logp {
        return Err(format!(
            "task {}: sampler_logp not bit-identical\n  a: {:?}\n  b: {:?}",
            a.task_idx, a.sampler_logp, b.sampler_logp
        ));
    }
    if a.finished != b.finished {
        return Err(format!("task {}: finished {} != {}", a.task_idx, a.finished, b.finished));
    }
    let (x, y) = (&a.accounting, &b.accounting);
    if x.integral_actual != y.integral_actual
        || x.integral_dense != y.integral_dense
        || x.peak_actual != y.peak_actual
        || x.peak_dense != y.peak_dense
        || x.steps != y.steps
        || x.compressions != y.compressions
        || x.evicted != y.evicted
    {
        return Err(format!("task {}: accounting diverges: {x:?} vs {y:?}", a.task_idx));
    }
    Ok(())
}

/// One random scenario: geometry, mode, sampling, tasks, wall.
struct Scenario {
    mode: RolloutMode,
    sampling: SamplingConfig,
    tasks: Vec<Task>,
    slots: usize,
    prompt_len: usize,
    max_seq: usize,
    budget: usize,
    buffer: usize,
    reserve: usize,
    kv_cap: usize,
    seed: u64,
    /// Mock EOS pull: small values make long responses (exercising the
    /// compression path), large ones make short skewed ones.
    eos_pull: f32,
}

impl Scenario {
    fn gen(rng: &mut Rng, size: usize) -> Scenario {
        let slots = 1 + rng.below(5);
        let prompt_len = 24;
        let max_seq = prompt_len + 2 + rng.below(40);
        let budget = 20 + rng.below(8); // sparse capacity must fit a prompt
        let buffer = 4 + rng.below(6);
        let mode = match rng.below(3) {
            0 => RolloutMode::Dense,
            1 => RolloutMode::NaiveSparse(Method::RKv),
            _ => RolloutMode::SparseRl(Method::RKv),
        };
        let sampling = SamplingConfig {
            temperature: *rng.choose(&[1.0f32, 0.85, 0.6]),
            top_p: *rng.choose(&[1.0f32, 0.92]),
            max_response: 2 + rng.below(30),
        };
        let n = 1 + rng.below(2 * slots + 2 + size / 8);
        let mut tasks: Vec<Task> = (0..n)
            .map(|_| {
                let ops = 1 + rng.below(2);
                Task::gen(rng, ops, prompt_len)
            })
            .collect();
        // GRPO-shaped workload about half the time: consecutive runs of g
        // tasks share one prompt — the duplicate-prompt shape prefix
        // sharing targets (per-task RNG still keys on the flat index, so
        // group siblings sample distinct tokens)
        if rng.below(2) == 1 {
            let g = 2 + rng.below(3);
            for i in 0..n {
                tasks[i] = tasks[(i / g) * g].clone();
            }
        }
        let capacity = if mode.is_sparse() { budget + buffer } else { max_seq };
        let reserve = capacity;
        // sometimes slot-limited, sometimes KV-limited (width < slots)
        let width_target = 1 + rng.below(slots + 2);
        let kv_cap = reserve * width_target + rng.below(reserve);
        Scenario {
            mode,
            sampling,
            tasks,
            slots,
            prompt_len,
            max_seq,
            budget,
            buffer,
            reserve,
            kv_cap,
            seed: rng.next_u64(),
            eos_pull: *rng.choose(&[0.25f32, 0.08, 0.02]),
        }
    }

    fn backend(&self) -> MockModelBackend {
        let mut b = if self.mode.is_sparse() {
            MockModelBackend::sparse(
                self.slots,
                self.prompt_len,
                self.max_seq,
                32,
                self.budget,
                self.buffer,
            )
        } else {
            MockModelBackend::dense(self.slots, self.prompt_len, self.max_seq, 32)
        };
        b.eos_pull = self.eos_pull;
        b
    }

    fn policy(&self) -> RolloutPolicy {
        RolloutPolicy::new(self.mode, self.sampling)
    }
}

#[test]
fn prop_static_and_continuous_engines_agree_per_task() {
    propcheck::check(
        "static-continuous-equivalence",
        PropConfig { cases: 96, seed: 0xE9_0001, max_size: 48 },
        |rng, size| {
            let sc = Scenario::gen(rng, size);
            let policy = sc.policy();
            let mut fifo_reference: Option<Vec<GenSeq>> = None;

            for order in [AdmissionOrder::Fifo, AdmissionOrder::ShortestFirst] {
                let mut kv_s = KvMemoryManager::new(sc.kv_cap);
                let (stat_seqs, stat_stats) = run_static(
                    &policy,
                    &mut sc.backend(),
                    &sc.tasks,
                    sc.seed,
                    sc.reserve,
                    &mut kv_s,
                    order,
                )?;

                let mut kv_c = KvMemoryManager::new(sc.kv_cap);
                let (cont_seqs, cont_stats) = run_continuous(
                    &policy,
                    &mut sc.backend(),
                    &sc.tasks,
                    sc.seed,
                    sc.reserve,
                    &mut kv_c,
                    order,
                )?;

                // 1) token-for-token, logp-bit-for-bit equivalence per
                //    task — between engines AND across admission orders
                if stat_seqs.len() != cont_seqs.len() {
                    return Err("result count mismatch".into());
                }
                for (a, b) in stat_seqs.iter().zip(cont_seqs.iter()) {
                    seqs_equal(a, b)?;
                }
                if fifo_reference.is_none() {
                    fifo_reference = Some(stat_seqs.clone());
                } else {
                    let reference = fifo_reference.as_ref().expect("set on the fifo pass");
                    for (a, b) in reference.iter().zip(stat_seqs.iter()) {
                        seqs_equal(a, b)
                            .map_err(|e| format!("admission order changed tokens: {e}"))?;
                    }
                }

                // 2) continuous determinism: a second run is identical
                //    (fifo only — one rerun bounds the property's cost)
                if order == AdmissionOrder::Fifo {
                    let mut kv_c2 = KvMemoryManager::new(sc.kv_cap);
                    let (cont2, cont2_stats) = run_continuous(
                        &policy,
                        &mut sc.backend(),
                        &sc.tasks,
                        sc.seed,
                        sc.reserve,
                        &mut kv_c2,
                        order,
                    )?;
                    for (a, b) in cont_seqs.iter().zip(cont2.iter()) {
                        seqs_equal(a, b)?;
                    }
                    if cont_stats != cont2_stats {
                        return Err("continuous stats not reproducible".into());
                    }

                    // 2b) chunked prefill (`prefill-chunk-tokens` > 0) is
                    //     scheduling-only: token/logp/accounting-identical
                    //     to the monolithic path, with refills served by
                    //     resumable chunks instead of slot prefills. No
                    //     closed-form step prediction here — the packer
                    //     interleaves chunks with decode steps, which the
                    //     monolithic list-scheduling formula doesn't model.
                    let mut kv_ck = KvMemoryManager::new(sc.kv_cap);
                    let (chunk_seqs, chunk_stats) = run_continuous(
                        &policy.with_prefill_chunk_tokens(12),
                        &mut sc.backend(),
                        &sc.tasks,
                        sc.seed,
                        sc.reserve,
                        &mut kv_ck,
                        order,
                    )?;
                    for (a, b) in cont_seqs.iter().zip(chunk_seqs.iter()) {
                        seqs_equal(a, b)
                            .map_err(|e| format!("chunked prefill changed tokens: {e}"))?;
                    }
                    if chunk_stats.refills != cont_stats.refills {
                        return Err(format!(
                            "chunked prefill changed the refill schedule: {} vs {}",
                            chunk_stats.refills, cont_stats.refills
                        ));
                    }
                    if chunk_stats.slot_prefills != 0 {
                        return Err(format!(
                            "chunked run still issued {} monolithic slot prefills",
                            chunk_stats.slot_prefills
                        ));
                    }
                    if chunk_stats.prefill_chunks < chunk_stats.refills {
                        return Err(format!(
                            "{} refills but only {} chunks (each refill needs >= 1)",
                            chunk_stats.refills, chunk_stats.prefill_chunks
                        ));
                    }
                    if kv_ck.reserved() != 0 {
                        return Err(format!(
                            "chunked run leaked {} KV tokens",
                            kv_ck.reserved()
                        ));
                    }
                    kv_ck.check_invariants().map_err(|e| e.to_string())?;
                }

                // 3) memory-wall invariants
                for kv in [&kv_s, &kv_c] {
                    if kv.reserved() != 0 {
                        return Err(format!("{} KV tokens leaked", kv.reserved()));
                    }
                    kv.check_invariants().map_err(|e| e.to_string())?;
                }
                if cont_stats.max_reserved_kv > kv_c.capacity() {
                    return Err(format!(
                        "observed residency {} breached the wall {}",
                        cont_stats.max_reserved_kv,
                        kv_c.capacity()
                    ));
                }
                if kv_c.peak_reserved < cont_stats.max_reserved_kv {
                    return Err("peak_reserved below an observed residency".into());
                }

                // 4) both engines do the same productive decode work; the
                //    continuous engine never needs more decode steps
                if stat_stats.occupied_slot_steps != cont_stats.occupied_slot_steps {
                    return Err(format!(
                        "productive slot-steps diverge: static {} vs continuous {}",
                        stat_stats.occupied_slot_steps, cont_stats.occupied_slot_steps
                    ));
                }
                if cont_stats.decode_steps > stat_stats.decode_steps {
                    return Err(format!(
                        "continuous used MORE decode steps ({} > {})",
                        cont_stats.decode_steps, stat_stats.decode_steps
                    ));
                }

                // 5) step-exact closed forms (scheduler prediction over
                //    the admission order — fifo replays task order,
                //    shortest-first the stable residency sort)
                let sched = mk_sched(sc.slots, sc.reserve).with_order(order);
                let idx = admission_order_indices(
                    &sched,
                    &sc.tasks,
                    sc.sampling.max_response,
                    order,
                );
                let lens: Vec<usize> = idx
                    .iter()
                    .map(|&i| cont_seqs[i].response_ids.len())
                    .collect();
                let pred_c = sched.predicted_decode_steps(&lens, sc.kv_cap);
                if cont_stats.decode_steps != pred_c {
                    return Err(format!(
                        "{}: continuous decode steps {} != predicted {} (lens {:?})",
                        order.label(),
                        cont_stats.decode_steps,
                        pred_c,
                        lens
                    ));
                }
                let pred_s = sched.predicted_decode_steps_static(&lens, sc.kv_cap);
                if stat_stats.decode_steps != pred_s {
                    return Err(format!(
                        "{}: static decode steps {} != predicted {} (lens {:?})",
                        order.label(),
                        stat_stats.decode_steps,
                        pred_s,
                        lens
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_static_results_do_not_depend_on_chunking() {
    // A narrower engine (fewer slots => different chunk boundaries) must
    // still produce identical sequences: per-task RNG means placement is
    // irrelevant even within one engine.
    propcheck::check(
        "static-chunking-independence",
        PropConfig { cases: 48, seed: 0xE9_0002, max_size: 32 },
        |rng, size| {
            let sc = Scenario::gen(rng, size);
            let policy = sc.policy();
            let mut kv_a = KvMemoryManager::new(sc.kv_cap);
            let (wide, _) = run_static(
                &policy,
                &mut sc.backend(),
                &sc.tasks,
                sc.seed,
                sc.reserve,
                &mut kv_a,
                AdmissionOrder::Fifo,
            )?;

            // same scenario, single-slot backend: maximal re-chunking
            let narrow_backend = || {
                let mut b = if sc.mode.is_sparse() {
                    MockModelBackend::sparse(1, sc.prompt_len, sc.max_seq, 32, sc.budget, sc.buffer)
                } else {
                    MockModelBackend::dense(1, sc.prompt_len, sc.max_seq, 32)
                };
                b.eos_pull = sc.eos_pull;
                b
            };
            let mut kv_b = KvMemoryManager::new(sc.kv_cap);
            let (serial, _) = run_static(
                &policy,
                &mut narrow_backend(),
                &sc.tasks,
                sc.seed,
                sc.reserve,
                &mut kv_b,
                AdmissionOrder::Fifo,
            )?;
            for (a, b) in wide.iter().zip(serial.iter()) {
                seqs_equal(a, b)?;
            }
            Ok(())
        },
    );
}

/// The shared denominator contract: one decode invocation contributes
/// exactly `slots` slot-steps, on every engine and any worker count.
fn audit_slot_steps(name: &str, st: &RolloutStats, slots: usize) -> Result<(), String> {
    if st.occupied_slot_steps + st.idle_slot_steps != st.decode_steps * slots {
        return Err(format!(
            "{name}: slot-step denominator broken: {} + {} != {} * {slots}",
            st.occupied_slot_steps, st.idle_slot_steps, st.decode_steps
        ));
    }
    Ok(())
}

#[test]
fn prop_pipelined_matches_continuous_and_static_for_every_task() {
    let counts = worker_counts();
    propcheck::check(
        "three-way-engine-equivalence",
        PropConfig { cases: 48, seed: 0xE9_0003, max_size: 40 },
        |rng, size| {
            let sc = Scenario::gen(rng, size);
            let policy = sc.policy();
            let costs = CostModel::representative();

            let mut kv_s = KvMemoryManager::new(sc.kv_cap);
            let (stat_seqs, stat_stats) = run_static(
                &policy,
                &mut sc.backend().with_costs(costs),
                &sc.tasks,
                sc.seed,
                sc.reserve,
                &mut kv_s,
                AdmissionOrder::Fifo,
            )?;
            let mut kv_c = KvMemoryManager::new(sc.kv_cap);
            let (cont_seqs, cont_stats) = run_continuous(
                &policy,
                &mut sc.backend().with_costs(costs),
                &sc.tasks,
                sc.seed,
                sc.reserve,
                &mut kv_c,
                AdmissionOrder::Fifo,
            )?;
            audit_slot_steps("static", &stat_stats, sc.slots)?;
            audit_slot_steps("continuous", &cont_stats, sc.slots)?;

            // sharing axis, serial lane: refills served by attaching a
            // cached prepared prompt must be token-identical to full
            // prefills, and every refill lands in exactly one of the two
            // disjoint counters
            let mut kv_sh = KvMemoryManager::new(sc.kv_cap);
            let (share_seqs, share_stats) = run_continuous(
                &policy.with_sharing(PrefixSharing::Group),
                &mut sc.backend().with_costs(costs),
                &sc.tasks,
                sc.seed,
                sc.reserve,
                &mut kv_sh,
                AdmissionOrder::Fifo,
            )?;
            for (a, b) in cont_seqs.iter().zip(share_seqs.iter()) {
                seqs_equal(a, b).map_err(|e| format!("sharing=group changed tokens: {e}"))?;
            }
            if share_stats.refills != cont_stats.refills {
                return Err(format!(
                    "sharing=group changed the refill schedule: {} vs {}",
                    share_stats.refills, cont_stats.refills
                ));
            }
            if share_stats.slot_prefills + share_stats.shared_prefill_attaches
                != share_stats.refills
            {
                return Err(format!(
                    "sharing=group: {} prefills + {} attaches != {} refills",
                    share_stats.slot_prefills,
                    share_stats.shared_prefill_attaches,
                    share_stats.refills
                ));
            }
            if cont_stats.shared_prefill_attaches != 0 {
                return Err("sharing=off recorded shared attaches".into());
            }
            // serial-lane identity: makespan is exactly the tick total
            if cont_stats.modeled_makespan_ticks
                != cont_stats.decode_busy_ticks
                    + cont_stats.prefill_blocked_ticks
                    + cont_stats.sched_stall_ticks
            {
                return Err("continuous makespan != sum of its tick components".into());
            }

            // the full pipelined grid: every worker count, stealing on and
            // off, both admission orders, both prefill modes (async runs a
            // real executor thread), chunked prefill off and on — tokens
            // must never move
            for &workers in &counts {
                for steal in [true, false] {
                    for order in [AdmissionOrder::Fifo, AdmissionOrder::ShortestFirst] {
                    for prefill in [PrefillMode::Sync, PrefillMode::Async] {
                    for sharing in [PrefixSharing::Off, PrefixSharing::Group] {
                    for chunk in [0usize, 12] {
                        let grid = format!(
                            "w={workers} steal={steal} order={} prefill={} share={} chunk={chunk}",
                            order.label(),
                            prefill.label(),
                            sharing.label()
                        );
                        let mut kv_p = KvMemoryManager::new(sc.kv_cap);
                        let mut sched_p = mk_sched(sc.slots, sc.reserve)
                            .with_order(order)
                            .with_sharing(sharing);
                        let proto = sc.backend().with_costs(costs);
                        let (pipe_seqs, pipe_stats) = run_pipelined(
                            &policy
                                .with_steal(steal)
                                .with_prefill(prefill)
                                .with_sharing(sharing)
                                .with_prefill_chunk_tokens(chunk),
                            &proto,
                            &sc.tasks,
                            sc.seed,
                            &mut sched_p,
                            &mut kv_p,
                            workers,
                        )?;

                        // token/logp/accounting identity per task, all
                        // engines, every grid point
                        if pipe_seqs.len() != cont_seqs.len() {
                            return Err(format!("{grid}: result count mismatch"));
                        }
                        for ((a, b), c) in
                            stat_seqs.iter().zip(cont_seqs.iter()).zip(pipe_seqs.iter())
                        {
                            seqs_equal(a, b)?;
                            seqs_equal(b, c).map_err(|e| format!("{grid}: {e}"))?;
                        }

                        // denominator contract holds after the cross-lane
                        // merge
                        audit_slot_steps(&format!("pipelined {grid}"), &pipe_stats, sc.slots)?;
                        // identical productive work (worst-case admission:
                        // no preemptions, so every engine decodes each
                        // token exactly once, steal or not)
                        if pipe_stats.preemptions != 0 {
                            return Err(format!("{grid}: worst-case admission preempted"));
                        }
                        if !steal && pipe_stats.steals != 0 {
                            return Err(format!(
                                "{grid}: stole {} refills with stealing off",
                                pipe_stats.steals
                            ));
                        }
                        if pipe_stats.occupied_slot_steps != cont_stats.occupied_slot_steps {
                            return Err(format!(
                                "{grid}: productive slot-steps diverge: pipelined {} vs \
                                 continuous {}",
                                pipe_stats.occupied_slot_steps, cont_stats.occupied_slot_steps
                            ));
                        }
                        // a lane's finish clock can never exceed the total
                        // work charged across lanes
                        if pipe_stats.modeled_makespan_ticks
                            > pipe_stats.decode_busy_ticks
                                + pipe_stats.prefill_blocked_ticks
                                + pipe_stats.sched_stall_ticks
                        {
                            return Err(format!(
                                "{grid}: makespan {} exceeds summed lane work",
                                pipe_stats.modeled_makespan_ticks
                            ));
                        }
                        if pipe_stats.workers != workers {
                            return Err(format!(
                                "{grid}: stats claim {} workers",
                                pipe_stats.workers
                            ));
                        }

                        // wall hygiene: drained, invariants intact,
                        // balanced books
                        if kv_p.reserved() != 0 {
                            return Err(format!(
                                "{grid}: {} KV tokens leaked",
                                kv_p.reserved()
                            ));
                        }
                        kv_p.check_invariants().map_err(|e| e.to_string())?;
                        if sched_p.stats.live_seqs() != 0 {
                            return Err(format!("{grid}: scheduler live_seqs not drained"));
                        }
                        if sched_p.stats.seq_admissions != sc.tasks.len() {
                            return Err(format!(
                                "{grid}: admissions {} != tasks {}",
                                sched_p.stats.seq_admissions,
                                sc.tasks.len()
                            ));
                        }
                        // global admitted width observed by the wall is
                        // bounded by the total slot budget of the pool
                        if kv_p.peak_live_seqs > workers * sc.slots {
                            return Err(format!(
                                "{grid}: peak admitted width {} > {} total slots",
                                kv_p.peak_live_seqs,
                                workers * sc.slots
                            ));
                        }
                        // chunked admission serves every refill by
                        // resumable chunks — never a monolithic slot
                        // prefill, and never through the async executor
                        if chunk > 0 {
                            if pipe_stats.slot_prefills != 0 {
                                return Err(format!(
                                    "{grid}: chunked run issued {} slot prefills",
                                    pipe_stats.slot_prefills
                                ));
                            }
                            if pipe_stats.prefill_chunks < pipe_stats.refills {
                                return Err(format!(
                                    "{grid}: {} refills but only {} chunks",
                                    pipe_stats.refills, pipe_stats.prefill_chunks
                                ));
                            }
                        }
                        // prefill-executor bookkeeping: sync mode and
                        // chunked admission both leave the counters
                        // untouched; monolithic async prepares every
                        // submission exactly once (== total refills) and
                        // the in-flight peak is bounded by submissions
                        if prefill == PrefillMode::Sync || chunk > 0 {
                            if pipe_stats.async_prefills_submitted != 0
                                || pipe_stats.async_prefills_completed != 0
                                || pipe_stats.async_prefill_inflight_peak != 0
                            {
                                return Err(format!(
                                    "{grid}: executor counters touched unexpectedly"
                                ));
                            }
                        } else {
                            if pipe_stats.async_prefills_submitted
                                != pipe_stats.async_prefills_completed
                            {
                                return Err(format!(
                                    "{grid}: {} submitted but {} completed",
                                    pipe_stats.async_prefills_submitted,
                                    pipe_stats.async_prefills_completed
                                ));
                            }
                            if pipe_stats.async_prefills_submitted != pipe_stats.refills {
                                return Err(format!(
                                    "{grid}: {} submissions != {} refills",
                                    pipe_stats.async_prefills_submitted, pipe_stats.refills
                                ));
                            }
                            if pipe_stats.async_prefill_inflight_peak
                                > pipe_stats.async_prefills_submitted
                                || (pipe_stats.refills > 0
                                    && pipe_stats.async_prefill_inflight_peak == 0)
                            {
                                return Err(format!(
                                    "{grid}: implausible in-flight peak {}",
                                    pipe_stats.async_prefill_inflight_peak
                                ));
                            }
                        }
                        // sharing hygiene: off never attaches; a refill
                        // is served by a slot prefill, an attach, or (a
                        // cache-less lane's fallback) a batched
                        // single-row prefill — never more than one
                        if sharing == PrefixSharing::Off
                            && pipe_stats.shared_prefill_attaches != 0
                        {
                            return Err(format!("{grid}: sharing=off attached"));
                        }
                        if pipe_stats.slot_prefills + pipe_stats.shared_prefill_attaches
                            > pipe_stats.refills
                        {
                            return Err(format!(
                                "{grid}: {} prefills + {} attaches > {} refills",
                                pipe_stats.slot_prefills,
                                pipe_stats.shared_prefill_attaches,
                                pipe_stats.refills
                            ));
                        }
                    }
                    }
                    }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn pipelined_preemption_stress_no_deadlock_and_pool_conserved() {
    // Paged admission + a wall barely above one worst-case sequence +
    // several workers + long responses: constant grow stalls, heavy
    // preempt/requeue traffic, workers parking on the wall — now ALSO
    // with drained lanes stealing pending refills from loaded peers, and
    // under both admission orders. The run must drain (no deadlock), stay
    // token-identical to continuous, balance every admission with a
    // release, and leak nothing — at every grid point.
    let (slots, prompt_len, max_seq, budget, buffer) = (2usize, 16usize, 96usize, 24usize, 8usize);
    let (page, seed) = (4usize, 11u64);
    let mode = RolloutMode::SparseRl(Method::RKv);
    let sampling = SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 40 };
    let policy = RolloutPolicy::new(mode, sampling);
    let reserve = budget + buffer; // 32 tokens = 8 pages
    // tiny wall: room for ~1.5 worst-case sequences -> guaranteed stalls
    let kv_cap = reserve + reserve / 2;
    let mut rng = Rng::new(5);
    // GRPO-shaped: 6 groups x 4 siblings sharing one prompt, so the
    // sharing=group grid points drive real prefix refcounts and
    // copy-on-write forks through the preemption storm
    let leads: Vec<Task> = (0..6).map(|_| Task::gen(&mut rng, 1, prompt_len)).collect();
    let tasks: Vec<Task> = (0..24).map(|i| leads[i / 4].clone()).collect();
    let backend = || {
        let mut b = MockModelBackend::sparse(slots, prompt_len, max_seq, 32, budget, buffer);
        b.eos_pull = 0.05; // long responses: lots of growth pressure
        b
    };

    // reference tokens from the deterministic continuous engine
    let mut kv_c = KvMemoryManager::with_pages(kv_cap, page);
    let mut sched_c = mk_sched(slots, reserve).with_admission(AdmissionPolicy::Paged);
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    let (cont_seqs, _) = policy
        .rollout_continuous(&mut backend(), &flat, seed, RolloutCtx::new(&mut sched_c, &mut kv_c))
        .expect("continuous reference");

    for workers in worker_counts() {
        for steal in [true, false] {
            for order in [AdmissionOrder::Fifo, AdmissionOrder::ShortestFirst] {
            for prefill in [PrefillMode::Sync, PrefillMode::Async] {
            for sharing in [PrefixSharing::Off, PrefixSharing::Group] {
            for chunk in [0usize, 12] {
                let grid = format!(
                    "w={workers} steal={steal} order={} prefill={} share={} chunk={chunk}",
                    order.label(),
                    prefill.label(),
                    sharing.label()
                );
                let mut kv = KvMemoryManager::with_pages(kv_cap, page);
                let mut sched = mk_sched(slots, reserve)
                    .with_admission(AdmissionPolicy::Paged)
                    .with_order(order)
                    .with_sharing(sharing);
                let (seqs, stats) = run_pipelined(
                    &policy
                        .with_steal(steal)
                        .with_prefill(prefill)
                        .with_sharing(sharing)
                        .with_prefill_chunk_tokens(chunk),
                    &backend(),
                    &tasks,
                    seed,
                    &mut sched,
                    &mut kv,
                    workers,
                )
                .unwrap_or_else(|e| panic!("{grid}: pipelined stress failed: {e}"));

                assert_eq!(seqs.len(), tasks.len(), "{grid}: dropped tasks");
                for (a, b) in cont_seqs.iter().zip(seqs.iter()) {
                    seqs_equal(a, b).unwrap_or_else(|e| panic!("{grid}: {e}"));
                }
                // pool conservation under preemption + steal traffic
                assert_eq!(kv.reserved(), 0, "{grid}: KV tokens leaked");
                assert_eq!(kv.used_pages(), 0, "{grid}: pages leaked");
                kv.check_invariants().unwrap();
                assert_eq!(sched.stats.live_seqs(), 0, "{grid}: live_seqs not drained");
                assert_eq!(
                    sched.stats.seq_admissions,
                    tasks.len() + sched.stats.preemptions,
                    "{grid}: every admission must balance a finish or a preemption"
                );
                assert_eq!(
                    stats.preemptions, sched.stats.preemptions,
                    "{grid}: engine and scheduler disagree on preemptions"
                );
                if !steal || workers == 1 {
                    assert_eq!(stats.steals, 0, "{grid}: steal fired when impossible");
                }
                // executor bookkeeping survives preempt/steal traffic:
                // every async submission is prepared exactly once, and a
                // preempted-then-requeued task resubmits (so submissions
                // can exceed task count but always equal joins = refills)
                if prefill == PrefillMode::Sync || chunk > 0 {
                    assert_eq!(
                        stats.async_prefills_submitted, 0,
                        "{grid}: executor submission despite sync/chunked admission"
                    );
                } else {
                    assert_eq!(
                        stats.async_prefills_submitted, stats.async_prefills_completed,
                        "{grid}: executor lost a submission"
                    );
                    assert_eq!(
                        stats.async_prefills_submitted, stats.refills,
                        "{grid}: submissions must equal joined refills"
                    );
                }
                if chunk > 0 {
                    assert_eq!(
                        stats.slot_prefills, 0,
                        "{grid}: chunked run issued monolithic slot prefills"
                    );
                    assert!(
                        stats.prefill_chunks >= stats.refills,
                        "{grid}: {} refills but only {} chunks",
                        stats.refills,
                        stats.prefill_chunks
                    );
                }
                assert!(
                    kv.peak_live_seqs <= workers * slots,
                    "{grid}: admitted width {} exceeds the pool's slot budget",
                    kv.peak_live_seqs
                );
                // prefix-pool hygiene: every shared prefix drained with
                // its last sharer; sharing actually engaged on the
                // grouped workload (sibling prompts co-admitted)
                assert_eq!(kv.live_prefixes(), 0, "{grid}: prefix entries leaked");
                if sharing == PrefixSharing::Group {
                    assert!(
                        sched.stats.shared_admissions > 0,
                        "{grid}: grouped workload never shared a prefix"
                    );
                } else {
                    assert_eq!(
                        sched.stats.shared_admissions, 0,
                        "{grid}: sharing=off admitted a shared prefix"
                    );
                    assert_eq!(sched.stats.cow_forks, 0, "{grid}: sharing=off forked");
                }
            }
            }
            }
            }
        }
    }
}

#[test]
fn prop_fleet_is_token_identical_and_conserves_every_replica_pool() {
    // The replicas axis of the grid: for every engine shell, replica
    // count, and replica-steal setting, the fleet must emit exactly the
    // single-engine reference tokens (routing and stealing are pure
    // scheduling), every replica's PRIVATE pool must balance its books,
    // and the fleet-level stats must be the parallel composition of the
    // per-replica stats.
    propcheck::check(
        "fleet-replica-equivalence",
        PropConfig { cases: 32, seed: 0xE9_0004, max_size: 32 },
        |rng, size| {
            let sc = Scenario::gen(rng, size);
            let policy = sc.policy();
            let costs = CostModel::representative();

            // single-engine reference tokens
            let mut kv_c = KvMemoryManager::new(sc.kv_cap);
            let (cont_seqs, _) = run_continuous(
                &policy,
                &mut sc.backend().with_costs(costs),
                &sc.tasks,
                sc.seed,
                sc.reserve,
                &mut kv_c,
                AdmissionOrder::Fifo,
            )?;

            let flat: Vec<(usize, &Task)> = sc.tasks.iter().enumerate().collect();
            for engine in [EngineKind::Static, EngineKind::Continuous, EngineKind::Pipelined] {
                let lanes = if engine == EngineKind::Pipelined { 2 } else { 1 };
                for replicas_n in [1usize, 2, 4] {
                    for replica_steal in [false, true] {
                        let grid = format!(
                            "engine={} replicas={replicas_n} rsteal={replica_steal}",
                            engine.label()
                        );
                        let mut reps: Vec<Replica<MockModelBackend>> = (0..replicas_n)
                            .map(|_| {
                                Replica::new(
                                    mk_sched(sc.slots, sc.reserve),
                                    KvMemoryManager::new(sc.kv_cap),
                                    (0..lanes).map(|_| sc.backend().with_costs(costs)).collect(),
                                )
                            })
                            .collect();
                        let (seqs, stats, report) = rollout_fleet(
                            &policy,
                            engine,
                            &mut reps,
                            &flat,
                            sc.seed,
                            replica_steal,
                        )
                        .map_err(|e| format!("{grid}: {e}"))?;

                        // token/logp/accounting identity, in task order
                        if seqs.len() != cont_seqs.len() {
                            return Err(format!("{grid}: result count mismatch"));
                        }
                        for (a, b) in cont_seqs.iter().zip(seqs.iter()) {
                            seqs_equal(a, b).map_err(|e| format!("{grid}: {e}"))?;
                        }

                        // steal hygiene: zero when off or impossible
                        if (!replica_steal || replicas_n == 1) && report.replica_steals != 0 {
                            return Err(format!(
                                "{grid}: {} cross-replica steals when impossible",
                                report.replica_steals
                            ));
                        }
                        // routing covers every task, in range
                        if report.routed.len() != sc.tasks.len()
                            || report.routed.iter().any(|&r| r >= replicas_n)
                        {
                            return Err(format!("{grid}: bad routing table"));
                        }

                        // per-replica pool conservation: each PRIVATE wall
                        // drained with intact invariants, each scheduler's
                        // admissions balanced
                        let mut fin = 0usize;
                        for (r, rep) in reps.iter().enumerate() {
                            if rep.kv.reserved() != 0 {
                                return Err(format!(
                                    "{grid}: replica {r} leaked {} KV tokens",
                                    rep.kv.reserved()
                                ));
                            }
                            rep.kv.check_invariants().map_err(|e| e.to_string())?;
                            if rep.sched.stats.live_seqs() != 0 {
                                return Err(format!(
                                    "{grid}: replica {r} live_seqs not drained"
                                ));
                            }
                            fin += rep.sched.stats.seq_admissions;
                        }
                        // worst-case admission never preempts, so fleet-wide
                        // admissions == tasks, each on exactly one replica
                        if fin != sc.tasks.len() {
                            return Err(format!(
                                "{grid}: fleet admissions {fin} != tasks {}",
                                sc.tasks.len()
                            ));
                        }

                        // fleet stats = parallel composition of per-replica
                        // stats: denominator fleet-wide, makespan = slowest
                        // replica, lanes sum
                        audit_slot_steps(&grid, &stats, sc.slots)?;
                        if report.per_replica.len() != replicas_n {
                            return Err(format!("{grid}: per-replica stats missing"));
                        }
                        let span = report
                            .per_replica
                            .iter()
                            .map(|s| s.modeled_makespan_ticks)
                            .max()
                            .unwrap_or(0);
                        if stats.modeled_makespan_ticks != span {
                            return Err(format!(
                                "{grid}: fleet makespan {} != replica max {span}",
                                stats.modeled_makespan_ticks
                            ));
                        }
                        let lanes_sum: usize =
                            report.per_replica.iter().map(|s| s.workers).sum();
                        if stats.workers != lanes_sum {
                            return Err(format!(
                                "{grid}: fleet lanes {} != summed {lanes_sum}",
                                stats.workers
                            ));
                        }
                        let steps: usize =
                            report.per_replica.iter().map(|s| s.decode_steps).sum();
                        if stats.decode_steps != steps {
                            return Err(format!("{grid}: decode steps did not sum"));
                        }

                        // steal-off fleets are fully deterministic: a rerun
                        // reproduces stats bit-for-bit (continuous only —
                        // one rerun bounds the property's cost)
                        if !replica_steal && engine == EngineKind::Continuous {
                            let mut reps2: Vec<Replica<MockModelBackend>> = (0..replicas_n)
                                .map(|_| {
                                    Replica::new(
                                        mk_sched(sc.slots, sc.reserve),
                                        KvMemoryManager::new(sc.kv_cap),
                                        (0..lanes)
                                            .map(|_| sc.backend().with_costs(costs))
                                            .collect(),
                                    )
                                })
                                .collect();
                            let (seqs2, stats2, _) = rollout_fleet(
                                &policy,
                                engine,
                                &mut reps2,
                                &flat,
                                sc.seed,
                                false,
                            )
                            .map_err(|e| e.to_string())?;
                            for (a, b) in seqs.iter().zip(seqs2.iter()) {
                                seqs_equal(a, b)?;
                            }
                            if stats != stats2 {
                                return Err(format!(
                                    "{grid}: steal-off fleet stats not reproducible"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn continuous_strictly_beats_static_under_skewed_lengths() {
    // Deterministic scenario with plenty of tasks and naturally skewed
    // EOS-driven lengths: slot recycling must save decode steps outright.
    let mode = RolloutMode::SparseRl(Method::RKv);
    let sampling = SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 48 };
    let policy = RolloutPolicy::new(mode, sampling);
    let (slots, prompt_len, max_seq, budget, buffer) = (4, 24, 96, 28, 8);
    let mut rng = Rng::new(0xBEEF);
    let tasks: Vec<Task> = (0..32)
        .map(|_| {
            let ops = 1 + rng.below(2);
            Task::gen(&mut rng, ops, prompt_len)
        })
        .collect();
    let reserve = budget + buffer;
    let kv_cap = reserve * slots * 4; // slot-limited: pure bubble comparison
    let backend =
        || MockModelBackend::sparse(slots, prompt_len, max_seq, 32, budget, buffer);

    let mut kv_s = KvMemoryManager::new(kv_cap);
    let (stat_seqs, stat_stats) = run_static(
        &policy,
        &mut backend(),
        &tasks,
        7,
        reserve,
        &mut kv_s,
        AdmissionOrder::Fifo,
    )
    .unwrap();
    let mut kv_c = KvMemoryManager::new(kv_cap);
    let (cont_seqs, cont_stats) = run_continuous(
        &policy,
        &mut backend(),
        &tasks,
        7,
        reserve,
        &mut kv_c,
        AdmissionOrder::Fifo,
    )
    .unwrap();

    let lens: Vec<usize> = stat_seqs.iter().map(|s| s.response_ids.len()).collect();
    let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
    assert!(lo < hi, "lengths unexpectedly uniform: {lens:?}");
    for (a, b) in stat_seqs.iter().zip(cont_seqs.iter()) {
        seqs_equal(a, b).unwrap();
    }
    assert!(
        cont_stats.decode_steps < stat_stats.decode_steps,
        "continuous {} !< static {} (lens {:?})",
        cont_stats.decode_steps,
        stat_stats.decode_steps,
        lens
    );
    assert!(
        cont_stats.occupancy() > stat_stats.occupancy(),
        "occupancy did not improve: {} vs {}",
        cont_stats.occupancy(),
        stat_stats.occupancy()
    );
    assert!(cont_stats.refills > 0, "slot recycling never fired");
}
