//! Hermetic serving-front-end harness (`coordinator::serve`) on the mock
//! backend with the representative cost model — no artifacts, no PJRT;
//! every latency below is an exact virtual-clock tick.
//!
//! Properties under test:
//!
//! 1. **Reject-with-estimate, no queue collapse** — under a deterministic
//!    overload trace the SLO admission controller sheds exactly the
//!    infeasible requests, each carrying the modeled cost and completion
//!    tick it was refused on, while the FIFO baseline admits everything
//!    and pushes the tail TTFT out; modeled p99 TTFT under SLO admission
//!    is strictly below FIFO on the same trace.
//! 2. **Streaming is not a second token path** — every admitted request's
//!    streamed response is bit-identical to one closed-batch rollout of
//!    the whole trace (per-task RNG keys off the request index), across
//!    all three engines, and the stream fold's sample counts match the
//!    response lengths exactly (TTFT/e2e one per request, inter-token
//!    `len - 1`).
//! 3. **Bounded ingest** — `serve-queue-depth` sheds arrivals past the
//!    bound on the spot, with estimates.
//! 4. **Priority classes** — among equal deadlines and costs, the higher
//!    priority request dispatches (and streams) first.
//! 5. **Input validation** — unsorted traces and empty lane sets error.

use sparse_rl::config::{EngineKind, RolloutMode, SamplingConfig, ServeAdmission, ServeConfig};
use sparse_rl::coordinator::{
    synthetic_trace, CostModel, GenSeq, KvMemoryManager, MockModelBackend, RolloutCtx,
    RolloutPolicy, Scheduler, ServeOutcome, ServeRequest, ServeServer, ShedReason,
};
use sparse_rl::data::benchmarks;
use sparse_rl::data::task::Task;

const PROMPT_LEN: usize = 24;
const MAX_RESPONSE: usize = 16;
const SEED: u64 = 0x5E64_E001;

fn sampling() -> SamplingConfig {
    SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: MAX_RESPONSE }
}

fn policy() -> RolloutPolicy {
    RolloutPolicy::new(RolloutMode::Dense, sampling())
}

fn backend(slots: usize) -> MockModelBackend {
    MockModelBackend::dense(slots, PROMPT_LEN, PROMPT_LEN + MAX_RESPONSE, 32)
        .with_costs(CostModel::representative())
}

fn sched(slots: usize) -> Scheduler {
    Scheduler::worst_case(slots, PROMPT_LEN + MAX_RESPONSE)
}

/// Ample wall: every slot of every lane can hold a full sequence.
fn wall(slots: usize, lanes: usize) -> KvMemoryManager {
    KvMemoryManager::new((PROMPT_LEN + MAX_RESPONSE) * slots * lanes)
}

fn serve_cfg(admission: ServeAdmission, queue_depth: usize) -> ServeConfig {
    ServeConfig { admission, queue_depth, slo_ticks: 0 }
}

/// The closed-batch oracle: one continuous rollout of every trace task
/// under the trace's request indices. Serving must stream exactly these
/// tokens for whatever subset it admits.
fn closed_batch(tasks: &[Task], slots: usize) -> Vec<GenSeq> {
    let mut b = backend(slots);
    let mut s = sched(slots);
    let mut kv = wall(slots, 1);
    let flat: Vec<(usize, &Task)> = tasks.iter().enumerate().collect();
    let (seqs, _stats) = policy()
        .rollout_continuous(&mut b, &flat, SEED, RolloutCtx::new(&mut s, &mut kv))
        .expect("closed-batch rollout");
    seqs
}

fn response_of(outcome: &ServeOutcome) -> &[i32] {
    match outcome {
        ServeOutcome::Completed { response, .. } => response,
        other => panic!("expected Completed, got {other:?}"),
    }
}

#[test]
fn slo_admission_sheds_overload_with_estimates_and_beats_fifo_p99_ttft() {
    let slots = 2;
    let tasks = benchmarks::training_split(19, PROMPT_LEN, 3);
    let oracle = sched(slots);
    let pred: Vec<u64> = tasks
        .iter()
        .map(|t| oracle.predicted_cost_ticks(t.prompt_ids.len(), MAX_RESPONSE))
        .collect();

    // request 0 warms the server (no deadline); requests 1..=16 burst in
    // at tick 1 with deadlines one tick short of their own modeled cost —
    // infeasible at ANY dispatch tick, so SLO admission must shed all 16
    // up front; requests 17..=18 arrive long after the burst drains and
    // are comfortably feasible.
    let mut trace: Vec<ServeRequest> = Vec::new();
    trace.push(ServeRequest::new(tasks[0].clone(), 0));
    for i in 1..=16usize {
        trace.push(ServeRequest::new(tasks[i].clone(), 1).with_deadline(1 + pred[i] - 1));
    }
    for i in 17..=18usize {
        trace.push(ServeRequest::new(tasks[i].clone(), 4000).with_deadline(4000 + 2 * pred[i]));
    }
    let closed = closed_batch(&tasks, slots);

    let mut slo_server = ServeServer::new(
        policy(),
        EngineKind::Continuous,
        serve_cfg(ServeAdmission::Slo, 0),
        vec![backend(slots)],
        sched(slots),
        wall(slots, 1),
    );
    let slo = slo_server.run(&trace, SEED).expect("slo serve");

    // exactly the infeasible burst is shed, each with the estimate it was
    // refused on (reject-with-estimate: modeled cost + completion tick
    // past the deadline); the queue never collapses — the warmup and the
    // late wave still complete
    assert_eq!(slo.outcomes.len(), trace.len());
    assert_eq!(slo.completed(), 3);
    assert_eq!(slo.shed(), 16);
    for i in 1..=16usize {
        match &slo.outcomes[i] {
            ServeOutcome::Shed { reason, predicted_cost_ticks, predicted_done_tick } => {
                assert_eq!(*reason, ShedReason::Deadline, "request {i}");
                assert_eq!(*predicted_cost_ticks, pred[i], "request {i}");
                assert!(
                    *predicted_done_tick > trace[i].deadline_tick,
                    "request {i}: estimate {predicted_done_tick} must overshoot the deadline"
                );
            }
            other => panic!("request {i}: expected Shed, got {other:?}"),
        }
    }
    // the admitted requests streamed the closed-batch tokens exactly
    let mut completed_len = 0usize;
    for i in [0usize, 17, 18] {
        assert_eq!(
            response_of(&slo.outcomes[i]),
            &closed[i].response_ids[..],
            "request {i}: streamed response diverges from the closed batch"
        );
        completed_len += closed[i].response_ids.len();
    }
    // stream-fold accounting: one TTFT + one e2e sample per completed
    // request, one inter-token sample per consecutive token pair
    assert_eq!(slo.ttft.len(), 3);
    assert_eq!(slo.e2e.len(), 3);
    assert_eq!(slo.inter_token.len(), completed_len - 3);
    for i in [0usize, 17, 18] {
        if let ServeOutcome::Completed { ttft_ticks, e2e_ticks, .. } = &slo.outcomes[i] {
            assert!(e2e_ticks >= ttft_ticks, "request {i}");
        }
    }
    // two dispatch rounds: the warmup, then the late wave (the shed-only
    // pass over the burst dispatches nothing)
    assert_eq!(slo.rounds, 2);

    // FIFO baseline on the SAME trace: no controller, everything admitted
    let mut fifo_server = ServeServer::new(
        policy(),
        EngineKind::Continuous,
        serve_cfg(ServeAdmission::Fifo, 0),
        vec![backend(slots)],
        sched(slots),
        wall(slots, 1),
    );
    let fifo = fifo_server.run(&trace, SEED).expect("fifo serve");
    assert_eq!(fifo.completed(), trace.len());
    assert_eq!(fifo.shed(), 0);
    assert_eq!(fifo.rounds, 3);
    for (i, o) in fifo.outcomes.iter().enumerate() {
        assert_eq!(
            response_of(o),
            &closed[i].response_ids[..],
            "fifo request {i}: streamed response diverges from the closed batch"
        );
    }
    // the headline separation: the burst's queueing delay lands in FIFO's
    // TTFT tail (16 prefills deep), while SLO's completed requests all
    // started essentially on arrival — strictly better modeled p99
    assert!(
        slo.ttft.p99() < fifo.ttft.p99(),
        "slo p99 ttft {} must be strictly below fifo p99 ttft {}",
        slo.ttft.p99(),
        fifo.ttft.p99()
    );
    assert!(slo.ttft.max() < fifo.ttft.max());
    assert!(slo.makespan_ticks <= fifo.makespan_ticks);
}

#[test]
fn served_tokens_match_closed_batch_on_every_engine() {
    let slots = 2;
    let tasks = benchmarks::training_split(8, PROMPT_LEN, 11);
    let closed = closed_batch(&tasks, slots);
    // no deadlines: SLO admission degenerates to admit-everything, so all
    // three engines serve the full trace
    let trace = synthetic_trace(tasks.clone(), 30, 0);
    for (kind, lanes) in [
        (EngineKind::Static, 1usize),
        (EngineKind::Continuous, 1),
        (EngineKind::Pipelined, 2),
    ] {
        let backends: Vec<MockModelBackend> = (0..lanes).map(|_| backend(slots)).collect();
        let mut server = ServeServer::new(
            policy(),
            kind,
            serve_cfg(ServeAdmission::Slo, 0),
            backends,
            sched(slots),
            wall(slots, lanes),
        );
        let report = server.run(&trace, SEED).expect("serve");
        assert_eq!(report.completed(), tasks.len(), "engine {}", kind.label());
        assert_eq!(report.shed(), 0, "engine {}", kind.label());
        for (i, o) in report.outcomes.iter().enumerate() {
            assert_eq!(
                response_of(o),
                &closed[i].response_ids[..],
                "engine {}: request {i} diverges from the closed batch",
                kind.label()
            );
        }
    }
}

#[test]
fn bounded_queue_sheds_on_ingest_with_estimates() {
    let slots = 2;
    let tasks = benchmarks::training_split(6, PROMPT_LEN, 5);
    let oracle = sched(slots);
    let closed = closed_batch(&tasks, slots);
    // all six arrive at tick 0; depth 2 holds the first two, the other
    // four are refused on ingest
    let trace = synthetic_trace(tasks.clone(), 0, 0);
    let mut server = ServeServer::new(
        policy(),
        EngineKind::Continuous,
        serve_cfg(ServeAdmission::Fifo, 2),
        vec![backend(slots)],
        sched(slots),
        wall(slots, 1),
    );
    let report = server.run(&trace, SEED).expect("serve");
    assert_eq!(report.completed(), 2);
    assert_eq!(report.shed(), 4);
    assert_eq!(report.rounds, 1);
    for i in 0..2 {
        assert_eq!(response_of(&report.outcomes[i]), &closed[i].response_ids[..]);
    }
    for i in 2..6 {
        let pred = oracle.predicted_cost_ticks(tasks[i].prompt_ids.len(), MAX_RESPONSE);
        match &report.outcomes[i] {
            ServeOutcome::Shed { reason, predicted_cost_ticks, predicted_done_tick } => {
                assert_eq!(*reason, ShedReason::QueueFull, "request {i}");
                assert_eq!(*predicted_cost_ticks, pred, "request {i}");
                // shed at ingest tick 0, so the estimate is the bare cost
                assert_eq!(*predicted_done_tick, pred, "request {i}");
            }
            other => panic!("request {i}: expected QueueFull shed, got {other:?}"),
        }
    }
}

#[test]
fn priority_dispatches_first_among_equal_deadlines_and_costs() {
    // one slot, two copies of one task (equal deadline, equal cost): the
    // priority-1 request must stream first, so its TTFT is strictly
    // smaller — the stable priority sort feeds the deadline picker's
    // queue-order tie-break
    let slots = 1;
    let task = benchmarks::training_split(1, PROMPT_LEN, 9).remove(0);
    let trace = vec![
        ServeRequest::new(task.clone(), 0),
        ServeRequest::new(task.clone(), 0).with_priority(1),
    ];
    let mut server = ServeServer::new(
        policy(),
        EngineKind::Continuous,
        serve_cfg(ServeAdmission::Slo, 0),
        vec![backend(slots)],
        sched(slots),
        wall(slots, 1),
    );
    let report = server.run(&trace, SEED).expect("serve");
    assert_eq!(report.completed(), 2);
    let ttft = |o: &ServeOutcome| match o {
        ServeOutcome::Completed { ttft_ticks, .. } => *ttft_ticks,
        other => panic!("expected Completed, got {other:?}"),
    };
    assert!(
        ttft(&report.outcomes[1]) < ttft(&report.outcomes[0]),
        "priority request must see first token before the priority-0 one ({} vs {})",
        ttft(&report.outcomes[1]),
        ttft(&report.outcomes[0])
    );
}

#[test]
fn serve_rejects_bad_inputs() {
    let slots = 2;
    let tasks = benchmarks::training_split(2, PROMPT_LEN, 1);
    let unsorted = vec![
        ServeRequest::new(tasks[0].clone(), 10),
        ServeRequest::new(tasks[1].clone(), 0),
    ];
    let mut server = ServeServer::new(
        policy(),
        EngineKind::Continuous,
        serve_cfg(ServeAdmission::Slo, 0),
        vec![backend(slots)],
        sched(slots),
        wall(slots, 1),
    );
    let err = server.run(&unsorted, SEED).unwrap_err().to_string();
    assert!(err.contains("sorted"), "got: {err}");

    let mut empty = ServeServer::new(
        policy(),
        EngineKind::Continuous,
        serve_cfg(ServeAdmission::Slo, 0),
        Vec::<MockModelBackend>::new(),
        sched(slots),
        wall(slots, 1),
    );
    let err = empty.run(&[], SEED).unwrap_err().to_string();
    assert!(err.contains("backend"), "got: {err}");
}
