//! Typed experiment configuration + a small `key = value` config-file
//! format with CLI overrides.
//!
//! The artifact manifest fixes the *shapes* (model dims, batch sizes,
//! budget); this module fixes the *policies*: rollout mode, correction
//! switches, sampling, schedule, and the global KV memory wall.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::{Hyp, Method};
use crate::util::cli::CliArgs;

/// How rollouts are generated (paper §5.1 baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RolloutMode {
    /// Full KV cache (GRPO-Dense upper bound).
    Dense,
    /// Compressed rollouts + Sparse-RL corrections (ours).
    SparseRl(Method),
    /// Compressed rollouts, no corrections (naive baseline; collapses).
    NaiveSparse(Method),
}

impl RolloutMode {
    pub fn parse(s: &str) -> Result<RolloutMode> {
        // forms: dense | sparse-rl:rkv | naive:snapkv
        if s == "dense" {
            return Ok(RolloutMode::Dense);
        }
        if let Some(m) = s.strip_prefix("sparse-rl:") {
            return Ok(RolloutMode::SparseRl(Method::parse(m)?));
        }
        if let Some(m) = s.strip_prefix("naive:") {
            return Ok(RolloutMode::NaiveSparse(Method::parse(m)?));
        }
        bail!("bad rollout mode {s:?} (dense | sparse-rl:<m> | naive:<m>)");
    }

    pub fn is_sparse(&self) -> bool {
        !matches!(self, RolloutMode::Dense)
    }

    pub fn method(&self) -> Option<Method> {
        match self {
            RolloutMode::Dense => None,
            RolloutMode::SparseRl(m) | RolloutMode::NaiveSparse(m) => Some(*m),
        }
    }

    /// Sparse-RL corrections enabled? (rejection sampling + ξ reweighting)
    pub fn corrections(&self) -> bool {
        matches!(self, RolloutMode::SparseRl(_))
    }

    pub fn label(&self) -> String {
        match self {
            RolloutMode::Dense => "dense".into(),
            RolloutMode::SparseRl(m) => format!("sparse-rl:{}", m.name()),
            RolloutMode::NaiveSparse(m) => format!("naive:{}", m.name()),
        }
    }
}

/// Which rollout data path drives generation.
///
/// `Static` is the original chunked engine: a chunk of sequences is
/// admitted together and the whole chunk decodes until its slowest
/// sequence finishes (long-tail bubble). `Continuous` recycles decode
/// slots: a finished sequence releases its KV reservation immediately and
/// the next pending prompt is prefilled into the freed slot mid-flight.
/// `Pipelined` runs `rollout-workers` continuous lanes on worker threads
/// against the shared scheduler/wall, with slot prefills deferred to a
/// dedicated prefill lane so recycling overlaps decode instead of
/// stalling it. All paths produce token-identical sequences per task
/// (per-task RNG), so every mode/baseline can run any engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    #[default]
    Static,
    Continuous,
    Pipelined,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind> {
        Ok(match s {
            "static" | "chunked" => EngineKind::Static,
            "continuous" | "cb" => EngineKind::Continuous,
            "pipelined" | "pipeline" => EngineKind::Pipelined,
            other => bail!("bad engine {other:?} (static | continuous | pipelined)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            EngineKind::Static => "static",
            EngineKind::Continuous => "continuous",
            EngineKind::Pipelined => "pipelined",
        }
    }
}

/// Sampling parameters (paper §5.1: T=1.0, top-p=1.0, max 4096 -> scaled).
#[derive(Debug, Clone, Copy)]
pub struct SamplingConfig {
    pub temperature: f32,
    pub top_p: f32,
    /// Maximum generated tokens per response (excludes prompt).
    pub max_response: usize,
}

impl Default for SamplingConfig {
    fn default() -> Self {
        SamplingConfig { temperature: 1.0, top_p: 1.0, max_response: 96 }
    }
}

/// How compression-induced mismatch is corrected (paper §4 vs the
/// Limitations section's proposed future work).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorrectionMode {
    /// Paper Eq. 6: any token with ξ_t < ε vetoes the whole trajectory.
    Reject,
    /// Future-work variant: keep every trajectory but clamp ξ_t to
    /// [ε, XI_CAP] — continuous token-level correction, no sample waste.
    Clamp,
}

impl CorrectionMode {
    pub fn parse(s: &str) -> Result<CorrectionMode> {
        Ok(match s {
            "reject" | "sequence" => CorrectionMode::Reject,
            "clamp" | "token" => CorrectionMode::Clamp,
            other => bail!("bad correction mode {other:?} (reject | clamp)"),
        })
    }
}

/// RL schedule + correction switches.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Number of RL steps.
    pub steps: usize,
    /// Prompts sampled per step (G rollouts each).
    pub prompts_per_step: usize,
    /// Rollouts per prompt (GRPO group size; paper: 8).
    pub group_size: usize,
    pub hyp: Hyp,
    /// Rejection-sampling threshold ε on ξ_t (paper: 1e-4).
    pub rejection_eps: f64,
    /// Enable M^RS rejection sampling (Eq. 6).
    pub rejection: bool,
    /// Enable ξ importance reweighting (Eq. 7).
    pub reweight: bool,
    /// Sequence-level rejection (paper) vs token-level clamping
    /// (Limitations/future work). Only meaningful for sparse-rl modes.
    pub correction_mode: CorrectionMode,
    /// Train minibatch passes per rollout batch.
    pub updates_per_step: usize,
    /// Training-task difficulty range (operator count). 0 = auto per
    /// model scale (paper §5.1: match data to model capability).
    pub ops_lo: usize,
    pub ops_hi: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 100,
            prompts_per_step: 4,
            group_size: 8,
            hyp: Hyp::default(),
            rejection_eps: 1e-4,
            rejection: true,
            reweight: true,
            correction_mode: CorrectionMode::Reject,
            updates_per_step: 1,
            ops_lo: 0,
            ops_hi: 0,
        }
    }
}

/// How sequences are charged against the KV memory wall.
///
/// `WorstCase` (the seed policy) reserves every sequence's worst-case
/// residency at admission — dense `max_seq`, sparse `budget + buffer` —
/// so admission can never fail mid-decode but width is paid for tokens
/// that are mostly never resident. `Paged` admits with only the pages the
/// prompt needs, grows page-by-page during decode (preempting the
/// lowest-progress sequence when the wall is hit), and shrinks to the
/// compressed residency after each compression event; width tracks
/// *actual* residency.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    #[default]
    WorstCase,
    Paged,
}

impl AdmissionPolicy {
    pub fn parse(s: &str) -> Result<AdmissionPolicy> {
        Ok(match s {
            "worst-case" | "worstcase" | "reserve" => AdmissionPolicy::WorstCase,
            "paged" => AdmissionPolicy::Paged,
            other => bail!("bad admission policy {other:?} (worst-case | paged)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            AdmissionPolicy::WorstCase => "worst-case",
            AdmissionPolicy::Paged => "paged",
        }
    }
}

/// How the pipelined engine performs slot (recycling) prefills.
///
/// `Sync` (default) is the original behavior on real hardware: the decode
/// worker that joins a refill makes the backend prefill call itself,
/// blocking its lane for the call's duration (the virtual clock charges
/// `slot_prefill_ticks` to that lane — honest accounting for a blocking
/// call). `Async` runs a dedicated prefill-executor thread that prepares
/// the cache-independent half of each slot prefill off the decode
/// workers and delivers completions back through the shared state, so
/// recycling overlaps decode for real — the virtual clock models it as
/// the single shared prefill lane. Pure scheduling: per-task RNG keeps
/// tokens bit-identical under either mode (`tests/engine_equivalence.rs`
/// covers the {sync, async} axis of the grid). Single-lane engines
/// ignore the knob (their slot prefills are inherently synchronous).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PrefillMode {
    #[default]
    Sync,
    Async,
}

impl PrefillMode {
    pub fn parse(s: &str) -> Result<PrefillMode> {
        Ok(match s {
            "sync" | "blocking" => PrefillMode::Sync,
            "async" | "executor" => PrefillMode::Async,
            other => bail!("bad prefill mode {other:?} (sync | async)"),
        })
    }

    pub fn is_async(&self) -> bool {
        matches!(self, PrefillMode::Async)
    }

    pub fn label(&self) -> &'static str {
        match self {
            PrefillMode::Sync => "sync",
            PrefillMode::Async => "async",
        }
    }
}

/// Order in which the engines admit pending tasks from the shared queue.
///
/// `Fifo` (default) preserves the original behavior: the queue head is
/// the only admission candidate, so a big task at the head can block the
/// wall while smaller admissible tasks wait behind it. `ShortestFirst`
/// pops the pending task with the smallest *predicted residency*
/// (`Scheduler::admission_cost` — the unclamped prompt+response
/// prediction, so cap ties break toward cheaper prompts) first — the
/// makespan-aware order: small tasks pack the wall wide early and a
/// high-residency task can never head-of-line-block an admissible small
/// one. Ordering is a pure scheduling choice: per-task RNG keeps every
/// task's tokens identical under either order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionOrder {
    #[default]
    Fifo,
    ShortestFirst,
}

impl AdmissionOrder {
    pub fn parse(s: &str) -> Result<AdmissionOrder> {
        Ok(match s {
            "fifo" => AdmissionOrder::Fifo,
            "shortest-first" | "shortest" | "sjf" => AdmissionOrder::ShortestFirst,
            other => bail!("bad admission order {other:?} (fifo | shortest-first)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            AdmissionOrder::Fifo => "fifo",
            AdmissionOrder::ShortestFirst => "shortest-first",
        }
    }
}

/// Whether identical prompts share their KV prefix pages.
///
/// `Off` (default) is the seed behavior: every sequence prefills and
/// reserves its own copy of the prompt KV. `Group` exploits the GRPO
/// fan-out shape — G rollouts of the same prompt (and eval's K samples
/// per task) — by registering each distinct prompt in a prefix registry:
/// the first sequence of a group charges the page-aligned prompt prefix
/// once, later siblings attach to the resident prefix read-only and
/// charge only their private (decode + prompt tail) pages, and a shared
/// prefix forks copy-on-write the moment compression rewrites that
/// sequence's retained pages. Accounting-wise the knob only changes
/// behavior under `admission = paged` (worst-case reservation prices
/// the wall per sequence by definition); the prefill-once-attach-G
/// execution saving applies to the synchronous engine paths. Pure
/// scheduling: per-task RNG keeps tokens bit-identical with sharing on
/// or off.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PrefixSharing {
    #[default]
    Off,
    Group,
}

impl PrefixSharing {
    pub fn parse(s: &str) -> Result<PrefixSharing> {
        Ok(match s {
            "off" | "none" => PrefixSharing::Off,
            "group" | "on" => PrefixSharing::Group,
            other => bail!("bad prefix-sharing value {other:?} (off | group)"),
        })
    }

    pub fn is_group(&self) -> bool {
        matches!(self, PrefixSharing::Group)
    }

    pub fn label(&self) -> &'static str {
        match self {
            PrefixSharing::Off => "off",
            PrefixSharing::Group => "group",
        }
    }
}

/// What the engines do with a task whose backend call has exhausted its
/// retry budget (`fault-retries`).
///
/// `Abort` (default) is the seed behavior bit-exactly: the error
/// propagates and kills the whole rollout batch. `Quarantine` releases
/// the failed task instead — KV pages, decode slot, and scheduler
/// admission all returned, so pool conservation holds — records it as
/// failed (`GenSeq.failed`, counted in `RolloutStats::failed_tasks`),
/// and lets the batch finish; the trainer then drops the failed task's
/// whole GRPO group and trains on the survivors (partial-batch
/// delivery). With no faults injected the knob is unobservable: both
/// policies run the identical fault-free path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FaultPolicy {
    #[default]
    Abort,
    Quarantine,
}

impl FaultPolicy {
    pub fn parse(s: &str) -> Result<FaultPolicy> {
        Ok(match s {
            "abort" => FaultPolicy::Abort,
            "quarantine" => FaultPolicy::Quarantine,
            other => bail!("bad fault policy {other:?} (abort | quarantine)"),
        })
    }

    pub fn is_quarantine(&self) -> bool {
        matches!(self, FaultPolicy::Quarantine)
    }

    pub fn label(&self) -> &'static str {
        match self {
            FaultPolicy::Abort => "abort",
            FaultPolicy::Quarantine => "quarantine",
        }
    }
}

/// How the serving front-end admits queued requests into a dispatch
/// round.
///
/// `Slo` (default) is the deadline-aware admission controller: queued
/// requests are considered in (priority, deadline, modeled cost) order
/// via `Scheduler::pick_next_deadline`, and a request is admitted only
/// when its modeled completion — virtual clock now + the round's
/// accumulated backlog + its own predicted residency × admission cost —
/// fits its deadline; an infeasible request is shed immediately with
/// that estimate (reject-with-estimate, never queue collapse). `Fifo`
/// is the baseline: admit everything in arrival order; overload shows
/// up as unbounded queueing delay instead of sheds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServeAdmission {
    Fifo,
    #[default]
    Slo,
}

impl ServeAdmission {
    pub fn parse(s: &str) -> Result<ServeAdmission> {
        Ok(match s {
            "fifo" => ServeAdmission::Fifo,
            "slo" | "deadline" => ServeAdmission::Slo,
            other => bail!("bad serve admission {other:?} (fifo | slo)"),
        })
    }

    pub fn is_slo(&self) -> bool {
        matches!(self, ServeAdmission::Slo)
    }

    pub fn label(&self) -> &'static str {
        match self {
            ServeAdmission::Fifo => "fifo",
            ServeAdmission::Slo => "slo",
        }
    }
}

/// The streaming serving front-end (`serve` subcommand /
/// `coordinator::serve`). Deadlines and the SLO are in virtual-clock
/// ticks — the same `CostModel` units every modeled makespan uses.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Admission policy for each dispatch round (default `slo`).
    pub admission: ServeAdmission,
    /// Maximum requests waiting in the serve queue; an arrival past a
    /// full queue is shed on ingest with an estimate. 0 = unbounded.
    pub queue_depth: usize,
    /// Default SLO: a request with no explicit deadline gets
    /// `arrival + slo_ticks`. 0 = no deadline (admit everything the
    /// wall accepts; only the queue-depth bound sheds).
    pub slo_ticks: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { admission: ServeAdmission::Slo, queue_depth: 0, slo_ticks: 0 }
    }
}

/// The memory wall: a global KV token budget shared by concurrent
/// sequences (the simulated HBM capacity the scheduler packs against).
#[derive(Debug, Clone, Copy)]
pub struct MemoryConfig {
    /// Total KV tokens that may be resident at once across all slots.
    pub global_kv_tokens: usize,
    /// Tokens per KV page (1 = token-granular, the seed accounting).
    pub kv_page_tokens: usize,
    /// Admission policy: worst-case reservation (seed behavior) or
    /// page-granular actual-residency admission.
    pub admission: AdmissionPolicy,
    /// Free pages a paged admission must leave as growth headroom while
    /// other sequences are live (default 1 = original behavior; 0 admits
    /// flush against the wall and thrashes on preempt/readmit under
    /// pressure; larger values trade admitted width for fewer
    /// preemptions). Ignored under worst-case admission.
    pub kv_admit_headroom_pages: usize,
    /// Prompt-prefix KV sharing across identical prompts (GRPO groups /
    /// eval samples). Default off preserves seed accounting bit-exactly.
    pub prefix_sharing: PrefixSharing,
}

impl Default for MemoryConfig {
    fn default() -> Self {
        MemoryConfig {
            global_kv_tokens: 2048,
            kv_page_tokens: 1,
            admission: AdmissionPolicy::WorstCase,
            kv_admit_headroom_pages: 1,
            prefix_sharing: PrefixSharing::Off,
        }
    }
}

/// Everything an experiment needs.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub artifact_dir: PathBuf,
    pub seed: u64,
    pub mode: RolloutMode,
    /// Rollout data path: static chunked batching, continuous batching
    /// with slot recycling, or pipelined multi-worker batching.
    /// Orthogonal to `mode`.
    pub engine: EngineKind,
    /// Decode lanes (worker threads) for `engine = pipelined`; ignored by
    /// the single-lane engines.
    pub rollout_workers: usize,
    /// Cross-worker work stealing (`engine = pipelined` only): a drained
    /// lane adopts a not-yet-prefilled refill from the most-loaded peer
    /// instead of parking on the condvar. Scheduling-only (per-task RNG
    /// keeps tokens identical); default on.
    pub steal: bool,
    /// Order the engines admit pending tasks in: `fifo` (seed behavior)
    /// or `shortest-first` (makespan-aware; smallest predicted residency
    /// first).
    pub admission_order: AdmissionOrder,
    /// Data-parallel rollout replicas. Each replica is a full engine
    /// instance — its own `Scheduler`, `KvMemoryManager` (private memory
    /// wall) and lane pool — and a global router assigns tasks to the
    /// replica with the least modeled load (predicted residency ×
    /// admission cost, not queue length). Default 1 = the single-engine
    /// path, bit-exact with prior behavior. Scheduling-only: per-task RNG
    /// keeps every task's tokens identical for any replica count.
    pub replicas: usize,
    /// Cross-replica work stealing (`replicas > 1` only): a drained
    /// replica adopts a not-yet-admitted task from the most-loaded peer
    /// (cost-weighted victim selection). Scheduling-only; default on.
    pub replica_steal: bool,
    /// Slot-prefill execution for `engine = pipelined`: `sync` (decode
    /// workers make the prefill calls themselves, blocking their lane —
    /// the original behavior) or `async` (a dedicated prefill-executor
    /// thread overlaps them with decode). Scheduling-only: tokens are
    /// identical either way.
    pub prefill: PrefillMode,
    /// Bounded retry budget for failed backend calls: a call that errors
    /// is retried up to this many times (with virtual-clock backoff
    /// charged to the calling lane) before the fault policy applies.
    /// Default 0 = no retries, the seed behavior.
    pub fault_retries: usize,
    /// Chunked prefill: token budget per device step for the continuous
    /// and pipelined engines. 0 (default) keeps monolithic slot prefills
    /// — the seed behavior; N > 0 packs each engine step with the decode
    /// batch plus one ≤ N-token chunk of the cheapest pending prompt,
    /// bounding per-step latency. Scheduling-only: tokens are identical
    /// either way.
    pub prefill_chunk_tokens: usize,
    /// What happens when a backend call exhausts its retries: `abort`
    /// (seed behavior — the error kills the batch) or `quarantine` (the
    /// failed task is released and recorded; the batch survives).
    pub fault_policy: FaultPolicy,
    pub sampling: SamplingConfig,
    pub train: TrainConfig,
    pub memory: MemoryConfig,
    /// The streaming serving front-end (`serve` subcommand): admission
    /// policy, queue bound, and the default SLO in virtual-clock ticks.
    pub serve: ServeConfig,
    /// Optional checkpoint to start from (pretrained base model).
    pub init_checkpoint: Option<PathBuf>,
    /// Where to write checkpoints/metrics.
    pub out_dir: PathBuf,
}

impl ExperimentConfig {
    /// Every key `apply` recognizes, in the order the match lists them.
    /// The CLI uses this to reject typo'd `--flag`s loudly instead of
    /// dropping them; a unit test pins the list against `apply` itself.
    pub const KNOWN_KEYS: &'static [&'static str] = &[
        "artifacts",
        "seed",
        "mode",
        "engine",
        "rollout-workers",
        "steal",
        "admission-order",
        "replicas",
        "replica-steal",
        "prefill",
        "prefill-chunk-tokens",
        "fault-retries",
        "fault-policy",
        "temperature",
        "top-p",
        "max-response",
        "steps",
        "prompts-per-step",
        "group-size",
        "lr",
        "clip-eps",
        "kl-coef",
        "max-grad-norm",
        "rejection-eps",
        "rejection",
        "reweight",
        "correction-mode",
        "updates-per-step",
        "ops-lo",
        "ops-hi",
        "global-kv-tokens",
        "kv-page-tokens",
        "admission",
        "prefix-sharing",
        "kv-admit-headroom-pages",
        "serve-admission",
        "serve-queue-depth",
        "serve-slo-ticks",
        "init-checkpoint",
        "out-dir",
    ];

    /// Is `key` one `apply` recognizes (whatever its value)?
    pub fn is_known_key(key: &str) -> bool {
        Self::KNOWN_KEYS.contains(&key)
    }

    pub fn new(artifact_dir: &Path) -> Self {
        ExperimentConfig {
            artifact_dir: artifact_dir.to_path_buf(),
            seed: 0,
            mode: RolloutMode::Dense,
            engine: EngineKind::default(),
            rollout_workers: 2,
            steal: true,
            admission_order: AdmissionOrder::default(),
            replicas: 1,
            replica_steal: true,
            prefill: PrefillMode::default(),
            prefill_chunk_tokens: 0,
            fault_retries: 0,
            fault_policy: FaultPolicy::default(),
            sampling: SamplingConfig::default(),
            train: TrainConfig::default(),
            memory: MemoryConfig::default(),
            serve: ServeConfig::default(),
            init_checkpoint: None,
            out_dir: PathBuf::from("runs/default"),
        }
    }

    /// Apply `--key value` CLI overrides (also used for config-file lines).
    pub fn apply(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "artifacts" => self.artifact_dir = PathBuf::from(value),
            "seed" => self.seed = value.parse().context("seed")?,
            "mode" => self.mode = RolloutMode::parse(value)?,
            "engine" => self.engine = EngineKind::parse(value)?,
            "rollout-workers" => {
                let v: usize = value.parse().context("rollout-workers")?;
                if v == 0 {
                    bail!("rollout-workers must be >= 1");
                }
                self.rollout_workers = v;
            }
            "steal" => {
                self.steal = match value {
                    "on" | "true" | "1" => true,
                    "off" | "false" | "0" => false,
                    other => bail!("bad steal value {other:?} (on | off)"),
                }
            }
            "admission-order" => self.admission_order = AdmissionOrder::parse(value)?,
            "replicas" => {
                let v: usize = value.parse().context("replicas")?;
                if v == 0 {
                    bail!("replicas must be >= 1");
                }
                self.replicas = v;
            }
            "replica-steal" => {
                self.replica_steal = match value {
                    "on" | "true" | "1" => true,
                    "off" | "false" | "0" => false,
                    other => bail!("bad replica-steal value {other:?} (on | off)"),
                }
            }
            "prefill" => self.prefill = PrefillMode::parse(value)?,
            "prefill-chunk-tokens" => {
                self.prefill_chunk_tokens =
                    value.parse().context("prefill-chunk-tokens")?
            }
            "fault-retries" => {
                self.fault_retries = value.parse().context("fault-retries")?
            }
            "fault-policy" => self.fault_policy = FaultPolicy::parse(value)?,
            "temperature" => self.sampling.temperature = value.parse().context("temperature")?,
            "top-p" => self.sampling.top_p = value.parse().context("top-p")?,
            "max-response" => self.sampling.max_response = value.parse().context("max-response")?,
            "steps" => self.train.steps = value.parse().context("steps")?,
            "prompts-per-step" => {
                self.train.prompts_per_step = value.parse().context("prompts-per-step")?
            }
            "group-size" => self.train.group_size = value.parse().context("group-size")?,
            "lr" => self.train.hyp.lr = value.parse().context("lr")?,
            "clip-eps" => self.train.hyp.clip_eps = value.parse().context("clip-eps")?,
            "kl-coef" => self.train.hyp.kl_coef = value.parse().context("kl-coef")?,
            "max-grad-norm" => {
                self.train.hyp.max_grad_norm = value.parse().context("max-grad-norm")?
            }
            "rejection-eps" => self.train.rejection_eps = value.parse().context("rejection-eps")?,
            "rejection" => self.train.rejection = value.parse().context("rejection")?,
            "reweight" => self.train.reweight = value.parse().context("reweight")?,
            "correction-mode" => {
                self.train.correction_mode = CorrectionMode::parse(value)?
            }
            "updates-per-step" => {
                self.train.updates_per_step = value.parse().context("updates-per-step")?
            }
            "ops-lo" => self.train.ops_lo = value.parse().context("ops-lo")?,
            "ops-hi" => self.train.ops_hi = value.parse().context("ops-hi")?,
            "global-kv-tokens" => {
                self.memory.global_kv_tokens = value.parse().context("global-kv-tokens")?
            }
            "kv-page-tokens" => {
                let v: usize = value.parse().context("kv-page-tokens")?;
                if v == 0 {
                    bail!("kv-page-tokens must be >= 1");
                }
                self.memory.kv_page_tokens = v;
            }
            "admission" => self.memory.admission = AdmissionPolicy::parse(value)?,
            "prefix-sharing" => {
                self.memory.prefix_sharing = PrefixSharing::parse(value)?
            }
            "kv-admit-headroom-pages" => {
                self.memory.kv_admit_headroom_pages =
                    value.parse().context("kv-admit-headroom-pages")?
            }
            "serve-admission" => self.serve.admission = ServeAdmission::parse(value)?,
            "serve-queue-depth" => {
                self.serve.queue_depth = value.parse().context("serve-queue-depth")?
            }
            "serve-slo-ticks" => {
                self.serve.slo_ticks = value.parse().context("serve-slo-ticks")?
            }
            "init-checkpoint" => self.init_checkpoint = Some(PathBuf::from(value)),
            "out-dir" => self.out_dir = PathBuf::from(value),
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    /// Load `key = value` lines ('#' comments) from a file.
    pub fn load_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("{}:{}: expected key = value", path.display(), lineno + 1))?;
            self.apply(k.trim(), v.trim())
                .with_context(|| format!("{}:{}", path.display(), lineno + 1))?;
        }
        Ok(())
    }

    /// Apply all recognized CLI options (unknown options are left for the
    /// caller to interpret).
    pub fn apply_cli(&mut self, args: &CliArgs) -> Result<()> {
        if let Some(path) = args.opt("config") {
            self.load_file(Path::new(path))?;
        }
        for (k, v) in &args.options {
            if k == "config" {
                continue;
            }
            // Ignore keys this config doesn't know; subcommands have extras.
            let _ = self.apply(k, v);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parsing() {
        assert_eq!(RolloutMode::parse("dense").unwrap(), RolloutMode::Dense);
        assert_eq!(
            RolloutMode::parse("sparse-rl:rkv").unwrap(),
            RolloutMode::SparseRl(Method::RKv)
        );
        assert_eq!(
            RolloutMode::parse("naive:snapkv").unwrap(),
            RolloutMode::NaiveSparse(Method::SnapKv)
        );
        assert!(RolloutMode::parse("bogus").is_err());
    }

    #[test]
    fn corrections_only_for_sparse_rl() {
        assert!(RolloutMode::parse("sparse-rl:h2o").unwrap().corrections());
        assert!(!RolloutMode::parse("naive:h2o").unwrap().corrections());
        assert!(!RolloutMode::Dense.corrections());
    }

    #[test]
    fn apply_overrides() {
        let mut c = ExperimentConfig::new(Path::new("artifacts/tiny"));
        c.apply("steps", "42").unwrap();
        c.apply("mode", "sparse-rl:rkv").unwrap();
        c.apply("lr", "0.001").unwrap();
        assert_eq!(c.train.steps, 42);
        assert!(c.mode.corrections());
        assert!((c.train.hyp.lr - 1e-3).abs() < 1e-9);
        assert!(c.apply("nope", "1").is_err());
    }

    #[test]
    fn engine_kind_parsing() {
        assert_eq!(EngineKind::parse("static").unwrap(), EngineKind::Static);
        assert_eq!(EngineKind::parse("continuous").unwrap(), EngineKind::Continuous);
        assert_eq!(EngineKind::parse("cb").unwrap(), EngineKind::Continuous);
        assert_eq!(EngineKind::parse("pipelined").unwrap(), EngineKind::Pipelined);
        assert_eq!(EngineKind::parse("pipeline").unwrap(), EngineKind::Pipelined);
        assert!(EngineKind::parse("batchy").is_err());
        let mut c = ExperimentConfig::new(Path::new("a"));
        assert_eq!(c.engine, EngineKind::Static); // default preserves behavior
        c.apply("engine", "continuous").unwrap();
        assert_eq!(c.engine, EngineKind::Continuous);
        c.apply("engine", "pipelined").unwrap();
        assert_eq!(c.engine, EngineKind::Pipelined);
    }

    #[test]
    fn rollout_workers_and_headroom_knobs() {
        let mut c = ExperimentConfig::new(Path::new("a"));
        assert_eq!(c.rollout_workers, 2);
        assert_eq!(c.memory.kv_admit_headroom_pages, 1); // seed behavior
        c.apply("rollout-workers", "4").unwrap();
        assert_eq!(c.rollout_workers, 4);
        assert!(c.apply("rollout-workers", "0").is_err());
        c.apply("kv-admit-headroom-pages", "0").unwrap();
        assert_eq!(c.memory.kv_admit_headroom_pages, 0);
        c.apply("kv-admit-headroom-pages", "3").unwrap();
        assert_eq!(c.memory.kv_admit_headroom_pages, 3);
    }

    #[test]
    fn steal_and_admission_order_knobs() {
        let mut c = ExperimentConfig::new(Path::new("a"));
        // defaults: stealing on, fifo order (seed admission behavior)
        assert!(c.steal);
        assert_eq!(c.admission_order, AdmissionOrder::Fifo);
        c.apply("steal", "off").unwrap();
        assert!(!c.steal);
        c.apply("steal", "on").unwrap();
        assert!(c.steal);
        assert!(c.apply("steal", "maybe").is_err());
        c.apply("admission-order", "shortest-first").unwrap();
        assert_eq!(c.admission_order, AdmissionOrder::ShortestFirst);
        c.apply("admission-order", "fifo").unwrap();
        assert_eq!(c.admission_order, AdmissionOrder::Fifo);
        assert_eq!(AdmissionOrder::parse("sjf").unwrap(), AdmissionOrder::ShortestFirst);
        assert!(AdmissionOrder::parse("random").is_err());
        assert_eq!(AdmissionOrder::ShortestFirst.label(), "shortest-first");
    }

    #[test]
    fn replicas_and_replica_steal_knobs() {
        let mut c = ExperimentConfig::new(Path::new("a"));
        // defaults: one replica (the single-engine path), stealing on
        assert_eq!(c.replicas, 1);
        assert!(c.replica_steal);
        c.apply("replicas", "4").unwrap();
        assert_eq!(c.replicas, 4);
        assert!(c.apply("replicas", "0").is_err());
        assert!(c.apply("replicas", "two").is_err());
        c.apply("replica-steal", "off").unwrap();
        assert!(!c.replica_steal);
        c.apply("replica-steal", "on").unwrap();
        assert!(c.replica_steal);
        assert!(c.apply("replica-steal", "maybe").is_err());
    }

    #[test]
    fn prefill_mode_knob() {
        let mut c = ExperimentConfig::new(Path::new("a"));
        // default sync preserves the original (blocking) behavior
        assert_eq!(c.prefill, PrefillMode::Sync);
        assert!(!c.prefill.is_async());
        c.apply("prefill", "async").unwrap();
        assert_eq!(c.prefill, PrefillMode::Async);
        assert!(c.prefill.is_async());
        c.apply("prefill", "sync").unwrap();
        assert_eq!(c.prefill, PrefillMode::Sync);
        assert!(c.apply("prefill", "eager").is_err());
        assert_eq!(PrefillMode::parse("executor").unwrap(), PrefillMode::Async);
        assert_eq!(PrefillMode::Async.label(), "async");
    }

    #[test]
    fn admission_policy_parsing() {
        assert_eq!(
            AdmissionPolicy::parse("worst-case").unwrap(),
            AdmissionPolicy::WorstCase
        );
        assert_eq!(AdmissionPolicy::parse("paged").unwrap(), AdmissionPolicy::Paged);
        assert!(AdmissionPolicy::parse("lazy").is_err());
        let mut c = ExperimentConfig::new(Path::new("a"));
        // defaults preserve the seed behavior exactly
        assert_eq!(c.memory.admission, AdmissionPolicy::WorstCase);
        assert_eq!(c.memory.kv_page_tokens, 1);
        c.apply("admission", "paged").unwrap();
        c.apply("kv-page-tokens", "16").unwrap();
        assert_eq!(c.memory.admission, AdmissionPolicy::Paged);
        assert_eq!(c.memory.kv_page_tokens, 16);
        assert!(c.apply("kv-page-tokens", "0").is_err());
    }

    #[test]
    fn prefix_sharing_knob() {
        let mut c = ExperimentConfig::new(Path::new("a"));
        // default off preserves the seed accounting bit-exactly
        assert_eq!(c.memory.prefix_sharing, PrefixSharing::Off);
        assert!(!c.memory.prefix_sharing.is_group());
        c.apply("prefix-sharing", "group").unwrap();
        assert_eq!(c.memory.prefix_sharing, PrefixSharing::Group);
        assert!(c.memory.prefix_sharing.is_group());
        c.apply("prefix-sharing", "off").unwrap();
        assert_eq!(c.memory.prefix_sharing, PrefixSharing::Off);
        assert!(c.apply("prefix-sharing", "radix").is_err());
        assert_eq!(PrefixSharing::parse("on").unwrap(), PrefixSharing::Group);
        assert_eq!(PrefixSharing::Group.label(), "group");
        assert_eq!(PrefixSharing::Off.label(), "off");
    }

    #[test]
    fn fault_retries_and_fault_policy_knobs() {
        let mut c = ExperimentConfig::new(Path::new("a"));
        // defaults: no retries, abort — the seed failure behavior exactly
        assert_eq!(c.fault_retries, 0);
        assert_eq!(c.fault_policy, FaultPolicy::Abort);
        assert!(!c.fault_policy.is_quarantine());
        c.apply("fault-retries", "3").unwrap();
        assert_eq!(c.fault_retries, 3);
        assert!(c.apply("fault-retries", "many").is_err());
        c.apply("fault-policy", "quarantine").unwrap();
        assert_eq!(c.fault_policy, FaultPolicy::Quarantine);
        assert!(c.fault_policy.is_quarantine());
        c.apply("fault-policy", "abort").unwrap();
        assert_eq!(c.fault_policy, FaultPolicy::Abort);
        assert!(c.apply("fault-policy", "retry-forever").is_err());
        assert_eq!(FaultPolicy::Quarantine.label(), "quarantine");
        assert_eq!(FaultPolicy::Abort.label(), "abort");
    }

    #[test]
    fn prefill_chunk_tokens_knob() {
        let mut c = ExperimentConfig::new(Path::new("a"));
        // default 0 = monolithic slot prefills, the seed behavior exactly
        assert_eq!(c.prefill_chunk_tokens, 0);
        c.apply("prefill-chunk-tokens", "24").unwrap();
        assert_eq!(c.prefill_chunk_tokens, 24);
        c.apply("prefill-chunk-tokens", "0").unwrap();
        assert_eq!(c.prefill_chunk_tokens, 0);
        assert!(c.apply("prefill-chunk-tokens", "lots").is_err());
        assert!(ExperimentConfig::is_known_key("prefill-chunk-tokens"));
    }

    #[test]
    fn serve_knobs() {
        let mut c = ExperimentConfig::new(Path::new("a"));
        // defaults: SLO admission, unbounded queue, no deadline
        assert_eq!(c.serve.admission, ServeAdmission::Slo);
        assert!(c.serve.admission.is_slo());
        assert_eq!(c.serve.queue_depth, 0);
        assert_eq!(c.serve.slo_ticks, 0);
        c.apply("serve-admission", "fifo").unwrap();
        assert_eq!(c.serve.admission, ServeAdmission::Fifo);
        assert!(!c.serve.admission.is_slo());
        c.apply("serve-admission", "deadline").unwrap();
        assert_eq!(c.serve.admission, ServeAdmission::Slo);
        assert!(c.apply("serve-admission", "lifo").is_err());
        c.apply("serve-queue-depth", "64").unwrap();
        assert_eq!(c.serve.queue_depth, 64);
        assert!(c.apply("serve-queue-depth", "deep").is_err());
        c.apply("serve-slo-ticks", "4000").unwrap();
        assert_eq!(c.serve.slo_ticks, 4000);
        assert!(c.apply("serve-slo-ticks", "soon").is_err());
        assert_eq!(ServeAdmission::Fifo.label(), "fifo");
        assert_eq!(ServeAdmission::Slo.label(), "slo");
    }

    #[test]
    fn known_keys_list_matches_apply() {
        // Every advertised key must be recognized by `apply` — i.e. never
        // die with its "unknown config key" arm (bad-VALUE errors are
        // fine). This pins KNOWN_KEYS against the match so the CLI's
        // typo rejection can trust the list.
        for key in ExperimentConfig::KNOWN_KEYS {
            let mut c = ExperimentConfig::new(Path::new("a"));
            if let Err(e) = c.apply(key, "zzz-not-a-value") {
                assert!(
                    !e.to_string().contains("unknown config key"),
                    "KNOWN_KEYS lists {key:?} but apply does not recognize it"
                );
            }
        }
        assert!(ExperimentConfig::is_known_key("fault-policy"));
        assert!(!ExperimentConfig::is_known_key("replica")); // the typo
    }

    #[test]
    fn correction_mode_parsing() {
        assert_eq!(CorrectionMode::parse("reject").unwrap(), CorrectionMode::Reject);
        assert_eq!(CorrectionMode::parse("token").unwrap(), CorrectionMode::Clamp);
        assert!(CorrectionMode::parse("x").is_err());
        let mut c = ExperimentConfig::new(Path::new("a"));
        c.apply("correction-mode", "clamp").unwrap();
        assert_eq!(c.train.correction_mode, CorrectionMode::Clamp);
    }

    #[test]
    fn config_file_roundtrip() {
        let dir = std::env::temp_dir().join("srl_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("exp.conf");
        std::fs::write(&p, "# comment\nsteps = 7\nmode = naive:h2o  # inline\n").unwrap();
        let mut c = ExperimentConfig::new(Path::new("a"));
        c.load_file(&p).unwrap();
        assert_eq!(c.train.steps, 7);
        assert_eq!(c.mode, RolloutMode::NaiveSparse(Method::H2O));
    }
}
