//! Small statistics helpers shared by metrics and the bench harness.

/// Running mean/variance (Welford) — O(1) memory time-series summary.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation (0 for len < 2).
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on a sorted copy; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let w = rank - lo as f64;
        s[lo] * (1.0 - w) + s[hi] * w
    }
}

/// Exponential moving average for smoothing training curves.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> f64 {
        self.value.unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 6.2).abs() < 1e-9);
        assert!((w.min - 1.0).abs() < 1e-12 && (w.max - 16.0).abs() < 1e-12);
        let m = mean(&xs);
        let naive_var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / 4.0;
        assert!((w.var() - naive_var).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..50 {
            e.push(10.0);
        }
        assert!((e.get() - 10.0).abs() < 1e-6);
    }
}
