//! Deterministic PRNG utilities (SplitMix64 / xoshiro256**).
//!
//! The offline registry has no `rand` crate; this module provides the
//! generator the coordinator uses for sampling tokens, shuffling datasets,
//! and generating synthetic tasks. Fully deterministic from a seed so every
//! experiment is reproducible from its config.

/// xoshiro256** seeded via SplitMix64 — fast, high-quality, reproducible.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    // NOTE: stateful per-slot stream forking was removed with the move to
    // placement-independent per-task streams (`coordinator::engine::task_rng`).

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection-free enough for our n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample from a categorical distribution given log-probabilities,
    /// applying `temperature` and nucleus (top-p) truncation — the token
    /// sampler on the rollout hot path.
    ///
    /// With temperature 1.0 and top_p 1.0 this samples the exact softmax of
    /// `logp` (which the decode artifact already normalized). Non-finite
    /// logits carry zero mass; a fully non-finite input falls back to a
    /// uniform draw (see `modified_probs`).
    pub fn sample_logits(&mut self, logp: &[f32], temperature: f32, top_p: f32) -> usize {
        assert!(!logp.is_empty());
        let probs = match modified_probs(logp, temperature, top_p) {
            Some(p) => p,
            None => return self.below(logp.len()), // degenerate: uniform
        };
        let r = self.next_f32();
        let mut acc = 0.0f32;
        for (i, &p) in probs.iter().enumerate() {
            acc += p;
            if r < acc && p > 0.0 {
                return i;
            }
        }
        probs.iter().rposition(|&p| p > 0.0).unwrap_or(0)
    }

    /// Standard normal via Box–Muller (tests / synthetic data).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

/// Materialize the temperature/top-p-modified categorical distribution
/// from log-probs — THE single implementation both token samplers
/// (`Rng::sample_logits` and `coordinator::engine::sample_token`) share,
/// so robustness fixes cannot diverge between them.
///
/// Non-finite logits (NaN from a diverged model, ±inf) carry zero mass.
/// Returns `None` when every logit is non-finite (caller picks a uniform
/// fallback). The top-p nucleus always keeps at least one token — when the
/// top-1 probability alone exceeds `top_p` the cut is exactly {argmax} —
/// and renormalizes the kept mass to 1.
pub fn modified_probs(logp: &[f32], temperature: f32, top_p: f32) -> Option<Vec<f32>> {
    let inv_t = 1.0 / temperature.max(1e-6);
    let mx = logp
        .iter()
        .cloned()
        .filter(|l| l.is_finite())
        .fold(f32::NEG_INFINITY, f32::max);
    if !mx.is_finite() {
        return None;
    }
    let mut probs: Vec<f32> = logp
        .iter()
        .map(|&l| if l.is_finite() { ((l - mx) * inv_t).exp() } else { 0.0 })
        .collect();
    let z: f32 = probs.iter().sum(); // >= 1: the max contributes exp(0)
    for p in probs.iter_mut() {
        *p /= z;
    }
    if top_p < 1.0 {
        // nucleus truncation: keep the smallest prefix of the sorted
        // distribution whose mass reaches top_p
        let mut idx: Vec<usize> = (0..probs.len()).collect();
        // total_cmp: never panics (partial_cmp().unwrap() dies on NaN)
        idx.sort_by(|&a, &b| probs[b].total_cmp(&probs[a]));
        let mut acc = 0.0f32;
        let mut cut = probs.len();
        for (rank, &i) in idx.iter().enumerate() {
            acc += probs[i];
            if acc >= top_p {
                cut = rank + 1;
                break;
            }
        }
        let keep: std::collections::HashSet<usize> = idx[..cut].iter().cloned().collect();
        let mut mass = 0.0;
        for (i, p) in probs.iter_mut().enumerate() {
            if keep.contains(&i) {
                mass += *p;
            } else {
                *p = 0.0;
            }
        }
        // mass > 0: the kept set contains the argmax, whose prob is >= 1/V
        for p in probs.iter_mut() {
            *p /= mass;
        }
    }
    Some(probs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((800..1200).contains(&c), "bucket count {c} out of range");
        }
    }

    #[test]
    fn sample_logits_matches_softmax() {
        let mut r = Rng::new(3);
        let logp = [0.0f32, -1.0, -2.0, -30.0];
        let mut counts = [0usize; 4];
        let n = 50_000;
        for _ in 0..n {
            counts[r.sample_logits(&logp, 1.0, 1.0)] += 1;
        }
        let z: f32 = logp.iter().map(|l| l.exp()).sum();
        for i in 0..4 {
            let expect = (logp[i].exp() / z) as f64;
            let got = counts[i] as f64 / n as f64;
            assert!(
                (got - expect).abs() < 0.01,
                "token {i}: got {got}, expect {expect}"
            );
        }
    }

    #[test]
    fn top_p_truncates_tail() {
        let mut r = Rng::new(5);
        // last token has ~2e-14 mass; top_p=0.9 must never sample it
        let logp = [0.0f32, -0.1, -30.0];
        for _ in 0..10_000 {
            assert_ne!(r.sample_logits(&logp, 1.0, 0.9), 2);
        }
    }

    #[test]
    fn sample_logits_survives_nan() {
        let mut r = Rng::new(17);
        let logp = [f32::NAN, -0.5, -1.0];
        for _ in 0..200 {
            let t = r.sample_logits(&logp, 1.0, 0.9);
            assert!(t == 1 || t == 2, "sampled the NaN token");
        }
        // fully degenerate input: uniform fallback, no panic
        for _ in 0..50 {
            assert!(r.sample_logits(&[f32::NAN; 3], 1.0, 1.0) < 3);
        }
    }

    #[test]
    fn greedy_via_low_temperature() {
        let mut r = Rng::new(11);
        let logp = [-2.0f32, -0.5, -1.0];
        for _ in 0..100 {
            assert_eq!(r.sample_logits(&logp, 1e-4, 1.0), 1);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
