//! Self-contained utility substrates (the offline registry lacks the usual
//! crates, so JSON, RNG, CLI parsing, stats, the bench harness, and the
//! property-testing runner are implemented here).

pub mod bench;
pub mod cli;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod stats;
