//! Minimal property-based testing runner (no `proptest` offline).
//!
//! Runs a property over many seeded random cases; on failure it reports the
//! failing seed/case index so the case is reproducible, and retries with a
//! "smaller" size parameter to give a crude shrink. Used by the coordinator
//! invariant tests (routing, batching, KV accounting, rejection sampling).

use super::rng::Rng;

/// Configuration for a property run.
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    /// Max "size" hint passed to the generator (case index scales up to it).
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 256, seed: 0xC0FFEE, max_size: 64 }
    }
}

/// Check `prop(rng, size)` over `cfg.cases` random cases.
///
/// `prop` returns `Err(msg)` to signal a violated invariant. Size grows
/// from 1 to `max_size` across cases so small counterexamples are tried
/// first (cheap built-in shrinking).
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let size = 1 + case * cfg.max_size / cfg.cases.max(1);
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng, size) {
            panic!(
                "property `{name}` failed at case {case} (size {size}, seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Convenience: check with default config.
pub fn quick<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    check(name, PropConfig::default(), prop);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        quick("reverse-involution", |rng, size| {
            let xs: Vec<u64> = (0..size).map(|_| rng.next_u64()).collect();
            let mut ys = xs.clone();
            ys.reverse();
            ys.reverse();
            if xs == ys {
                Ok(())
            } else {
                Err("reverse twice changed the vec".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_seed() {
        check(
            "always-fails",
            PropConfig { cases: 3, ..Default::default() },
            |_, _| Err("nope".into()),
        );
    }
}
