//! Micro-benchmark harness (the offline registry has no `criterion`).
//!
//! Warmup + timed iterations with mean / std / percentiles, printed in a
//! criterion-like format. Used by the `rust/benches/*.rs` targets
//! (`cargo bench` with `harness = false`).

use std::time::{Duration, Instant};

use super::stats;

/// One benchmark measurement series.
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub samples_ns: Vec<f64>,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        stats::mean(&self.samples_ns)
    }

    pub fn report(&self) -> String {
        let mean = self.mean_ns();
        let p50 = stats::percentile(&self.samples_ns, 50.0);
        let p95 = stats::percentile(&self.samples_ns, 95.0);
        let sd = stats::std(&self.samples_ns);
        format!(
            "{:<44} {:>12} {:>12} {:>12} {:>10}  ({} iters)",
            self.name,
            fmt_ns(mean),
            fmt_ns(p50),
            fmt_ns(p95),
            format!("±{}", fmt_ns(sd)),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with a global time budget per case.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            budget: Duration::from_secs(3),
            min_iters: 5,
            max_iters: 1000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(800),
            min_iters: 3,
            max_iters: 200,
            ..Default::default()
        }
    }

    /// Time `f` repeatedly; prints and records the result.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // Measure
        let mut samples = Vec::new();
        let t1 = Instant::now();
        while (t1.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_nanos() as f64);
        }
        let r = BenchResult { name: name.to_string(), iters: samples.len(), samples_ns: samples };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn header(&self, title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>10}",
            "benchmark", "mean", "p50", "p95", "std"
        );
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            min_iters: 3,
            max_iters: 50,
            results: vec![],
        };
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(b.results()[0].iters >= 3);
        assert!(b.results()[0].mean_ns() >= 0.0);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2500.0), "2.50 µs");
        assert_eq!(fmt_ns(3.5e6), "3.50 ms");
        assert_eq!(fmt_ns(1.25e9), "1.250 s");
    }
}
