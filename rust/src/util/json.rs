//! Minimal JSON parser/serializer.
//!
//! The offline crate registry has no `serde_json`, so the artifact manifest
//! (written by `python/compile/aot.py`) is parsed with this self-contained
//! implementation. Supports the full JSON grammar needed by the manifest:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index access; returns `Json::Null` out of bounds.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
}

/// JSON parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut vec = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(vec));
        }
        loop {
            vec.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(vec));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len()
                        && (self.bytes[self.pos] & 0xC0) == 0x80
                    {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

/// Serialize a value to compact JSON (used for metrics/config dumps).
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_json(v, &mut s);
    s
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{}", n));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(a) => {
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(e, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(&Json::Str(k.clone()), out);
                out.push(':');
                write_json(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("x"));
        assert_eq!(v.get("c").as_bool(), Some(false));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"cfg":{"d":128,"name":"tiny"},"xs":[1,2.5,null,true,"s"]}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&to_string(&v)).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("12 34").is_err());
    }
}
