//! Tiny command-line argument parser (no `clap` in the offline registry).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Typed accessors with defaults keep call sites terse:
//!
//! ```ignore
//! let args = CliArgs::parse(std::env::args().skip(1));
//! let steps: usize = args.get("steps", 100);
//! let model: String = args.get("model", "tiny".to_string());
//! if args.flag("verbose") { ... }
//! ```

use std::collections::BTreeMap;
use std::str::FromStr;

#[derive(Debug, Clone, Default)]
pub struct CliArgs {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl CliArgs {
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = CliArgs::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` if the next token isn't an option,
                    // otherwise a boolean flag
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            out.options.insert(stripped.to_string(), v);
                        }
                        _ => out.flags.push(stripped.to_string()),
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Typed option lookup with a default.
    pub fn get<T: FromStr + Clone>(&self, key: &str, default: T) -> T {
        match self.options.get(key) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("warning: could not parse --{key} {v:?}; using default");
                default.clone()
            }),
            None => default,
        }
    }

    /// Required typed option.
    pub fn require<T: FromStr>(&self, key: &str) -> T {
        let v = self
            .options
            .get(key)
            .unwrap_or_else(|| panic!("missing required option --{key}"));
        v.parse()
            .unwrap_or_else(|_| panic!("could not parse --{key} {v:?}"))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
            || self
                .options
                .get(key)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> CliArgs {
        CliArgs::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn options_and_flags() {
        let a = parse("train --steps 50 --model=tiny --verbose --out dir pos1");
        assert_eq!(a.positional, vec!["train", "pos1"]);
        assert_eq!(a.get::<usize>("steps", 0), 50);
        assert_eq!(a.get::<String>("model", "x".into()), "tiny");
        assert_eq!(a.opt("out"), Some("dir"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_before_option() {
        let a = parse("--dry-run --steps 3");
        assert!(a.flag("dry-run"));
        assert_eq!(a.get::<usize>("steps", 0), 3);
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.get::<f64>("lr", 1e-3), 1e-3);
        assert!(a.opt("missing").is_none());
    }

    #[test]
    #[should_panic(expected = "missing required option")]
    fn require_missing_panics() {
        let a = parse("cmd");
        let _: usize = a.require("steps");
    }
}
