//! Data substrate: tokenizer, synthetic arithmetic-CoT task generator,
//! verifier, and the 7-benchmark evaluation suite (paper Table 3 analog).

pub mod benchmarks;
pub mod expr;
pub mod task;
pub mod tokenizer;

pub use benchmarks::{suite, training_split, Benchmark, Protocol};
pub use task::{verify, Task};
