//! Task formatting + the rule-based binary verifier (paper §5.1: reward 1
//! for a correct final answer, 0 otherwise).
//!
//! Task string format (all within the 32-char vocabulary):
//!   prompt:   `Q:(3+4)*2=?A:`
//!   response: `3+4=7;7*2=14;#14` + EOS
//! The verifier extracts the text after the last `#` and compares the
//! parsed integer against the ground truth — exact match, strict binary.

use crate::util::rng::Rng;

use super::expr::{gen_expr, Expr};
use super::tokenizer::{self, BOS, EOS};

/// One task instance: a prompt and its verifiable answer.
#[derive(Debug, Clone)]
pub struct Task {
    pub expr: Expr,
    pub answer: i64,
    pub prompt_text: String,
    /// Prompt token ids including leading BOS.
    pub prompt_ids: Vec<i32>,
}

impl Task {
    pub fn from_expr(expr: Expr) -> Task {
        let answer = expr.value();
        let prompt_text = format!("Q:{}=?A:", expr.render());
        let mut prompt_ids = vec![BOS];
        prompt_ids.extend(tokenizer::encode(&prompt_text));
        Task { expr, answer, prompt_text, prompt_ids }
    }

    /// Generate a task with `n_ops` operators whose prompt fits in
    /// `max_prompt` tokens.
    pub fn gen(rng: &mut Rng, n_ops: usize, max_prompt: usize) -> Task {
        loop {
            let t = Task::from_expr(gen_expr(rng, n_ops));
            if t.prompt_ids.len() <= max_prompt {
                return t;
            }
        }
    }

    /// The ideal chain-of-thought response (supervised target), with EOS.
    pub fn target_ids(&self) -> Vec<i32> {
        let mut ids = tokenizer::encode(&self.expr.chain_of_thought());
        ids.push(EOS);
        ids
    }

    /// Binary reward for a generated response (token ids, EOS-terminated
    /// or truncated).
    pub fn reward(&self, response_ids: &[i32]) -> f64 {
        if verify(&tokenizer::decode(response_ids), self.answer) {
            1.0
        } else {
            0.0
        }
    }
}

/// Extract the final answer (text after the last '#') and compare.
///
/// Deliberately strict, mirroring the paper's rule-based verifier: missing
/// `#`, unparsable integer, or trailing garbage all score 0.
pub fn verify(response_text: &str, answer: i64) -> bool {
    match response_text.rsplit_once('#') {
        Some((_, tail)) => {
            let tail = tail.trim();
            match tail.parse::<i64>() {
                Ok(v) => v == answer,
                Err(_) => false,
            }
        }
        None => false,
    }
}

/// Detect degenerate repetition (the paper's Appendix-F anomaly): the
/// response ends in >= `min_repeats` copies of the same short motif. Used
/// only for *reporting* anomalous-sample statistics — rejection sampling
/// itself is probability-based (paper Eq. 6), never pattern-based.
pub fn looks_repetitive(ids: &[i32], min_repeats: usize) -> bool {
    let n = ids.len();
    for motif in 2..=12usize {
        if n < motif * min_repeats {
            continue;
        }
        let tail = &ids[n - motif * min_repeats..];
        let pattern = &tail[..motif];
        if tail.chunks(motif).all(|c| c == pattern) {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn verifier_accepts_exact() {
        assert!(verify("3+4=7;#7", 7));
        assert!(verify("#-12", -12));
        assert!(!verify("#8", 7));
        assert!(!verify("no hash", 7));
        assert!(!verify("#", 7));
        assert!(!verify("#7;", 7)); // trailing garbage after the answer
    }

    #[test]
    fn verifier_uses_last_hash() {
        assert!(verify("#3;junk#7", 7));
    }

    #[test]
    fn target_passes_own_verifier() {
        propcheck::quick("target-verifies", |rng, size| {
            let t = Task::gen(rng, 1 + size % 5, 48);
            if t.reward(&t.target_ids()) != 1.0 {
                return Err(format!("target for {} failed", t.prompt_text));
            }
            // and a wrong answer fails
            let mut bad = t.target_ids();
            let k = bad.len() - 2; // last digit before EOS
            bad[k] = if bad[k] == tokenizer::DIGIT0 {
                tokenizer::DIGIT0 + 1
            } else {
                tokenizer::DIGIT0
            };
            if t.reward(&bad) != 0.0 {
                return Err("corrupted answer still verified".into());
            }
            Ok(())
        });
    }

    #[test]
    fn prompt_fits_and_roundtrips() {
        propcheck::quick("prompt-fits", |rng, size| {
            let t = Task::gen(rng, 1 + size % 6, 48);
            if t.prompt_ids.len() > 48 {
                return Err(format!("prompt too long: {}", t.prompt_ids.len()));
            }
            let decoded = tokenizer::decode(&t.prompt_ids);
            if decoded != t.prompt_text {
                return Err(format!("{decoded:?} != {:?}", t.prompt_text));
            }
            Ok(())
        });
    }

    #[test]
    fn repetition_detector() {
        let motif = [5, 6, 7];
        let mut ids: Vec<i32> = vec![1, 2, 3];
        for _ in 0..10 {
            ids.extend_from_slice(&motif);
        }
        assert!(looks_repetitive(&ids, 5));
        let normal = tokenizer::encode("3+4=7;7*2=14;#14");
        assert!(!looks_repetitive(&normal, 4));
    }
}
