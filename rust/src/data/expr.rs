//! Arithmetic expression ASTs: generation, evaluation, chain-of-thought
//! rendering.
//!
//! The synthetic analog of the paper's math training data (DESIGN.md §2):
//! random expression trees over digits 0-9 with {+, -, *}, every
//! intermediate value constrained to |v| <= 99 so chains stay within the
//! token budget of the task format. Difficulty = number of operators,
//! mirroring the paper's Easy/Medium/Hard splits by MATH level.

use crate::util::rng::Rng;

/// Maximum magnitude of any intermediate (and final) value.
pub const MAX_ABS: i64 = 99;

/// Binary operator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Add,
    Sub,
    Mul,
}

impl Op {
    pub fn apply(self, a: i64, b: i64) -> i64 {
        match self {
            Op::Add => a + b,
            Op::Sub => a - b,
            Op::Mul => a * b,
        }
    }

    pub fn symbol(self) -> char {
        match self {
            Op::Add => '+',
            Op::Sub => '-',
            Op::Mul => '*',
        }
    }
}

/// Expression tree. Leaves are single digits 0-9.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Expr {
    Leaf(i64),
    Node(Op, Box<Expr>, Box<Expr>),
}

impl Expr {
    pub fn value(&self) -> i64 {
        match self {
            Expr::Leaf(v) => *v,
            Expr::Node(op, a, b) => op.apply(a.value(), b.value()),
        }
    }

    pub fn n_ops(&self) -> usize {
        match self {
            Expr::Leaf(_) => 0,
            Expr::Node(_, a, b) => 1 + a.n_ops() + b.n_ops(),
        }
    }

    /// Render with full parentheses around compound subtrees (top level
    /// unparenthesized): `(3+4)*2`, `((3+4)*(2-1))-5`.
    pub fn render(&self) -> String {
        match self {
            Expr::Leaf(v) => v.to_string(),
            Expr::Node(op, a, b) => {
                format!("{}{}{}", Self::child(a), op.symbol(), Self::child(b))
            }
        }
    }

    fn child(e: &Expr) -> String {
        match e {
            Expr::Leaf(v) => v.to_string(),
            node => format!("({})", node.render()),
        }
    }

    /// All intermediate values are within [-MAX_ABS, MAX_ABS].
    pub fn bounded(&self) -> bool {
        match self {
            Expr::Leaf(v) => v.abs() <= MAX_ABS,
            Expr::Node(_, a, b) => {
                a.bounded() && b.bounded() && self.value().abs() <= MAX_ABS
            }
        }
    }

    /// Reduce the leftmost innermost operation once; returns the reduction
    /// step `(a, op, b, result)` and the new tree, or None for a leaf.
    pub fn reduce_step(&self) -> Option<((i64, Op, i64, i64), Expr)> {
        match self {
            Expr::Leaf(_) => None,
            Expr::Node(op, a, b) => {
                if let Some((step, a2)) = a.reduce_step() {
                    return Some((step, Expr::Node(*op, Box::new(a2), b.clone())));
                }
                if let Some((step, b2)) = b.reduce_step() {
                    return Some((step, Expr::Node(*op, a.clone(), Box::new(b2))));
                }
                let (av, bv) = (a.value(), b.value());
                let r = op.apply(av, bv);
                Some(((av, *op, bv, r), Expr::Leaf(r)))
            }
        }
    }

    /// Render the chain-of-thought: one `a{op}b=c;` line per reduction,
    /// ending with `#answer`. This is the supervised target format and what
    /// a well-trained policy reproduces during RL rollouts.
    pub fn chain_of_thought(&self) -> String {
        let mut out = String::new();
        let mut cur = self.clone();
        while let Some(((a, op, b, r), next)) = cur.reduce_step() {
            out.push_str(&format!("{}{}{}={};", a, op.symbol(), b, r));
            cur = next;
        }
        out.push('#');
        out.push_str(&self.value().to_string());
        out
    }
}

/// Generate a random expression with exactly `n_ops` operators and all
/// intermediates bounded. Rejection-samples subtrees (cheap at this size).
pub fn gen_expr(rng: &mut Rng, n_ops: usize) -> Expr {
    loop {
        let e = gen_unchecked(rng, n_ops);
        if e.bounded() {
            return e;
        }
    }
}

fn gen_unchecked(rng: &mut Rng, n_ops: usize) -> Expr {
    if n_ops == 0 {
        return Expr::Leaf(rng.range_i64(0, 9));
    }
    // split remaining ops between the two children
    let left_ops = rng.below(n_ops);
    let right_ops = n_ops - 1 - left_ops;
    let op = match rng.below(3) {
        0 => Op::Add,
        1 => Op::Sub,
        _ => Op::Mul,
    };
    Expr::Node(
        op,
        Box::new(gen_unchecked(rng, left_ops)),
        Box::new(gen_unchecked(rng, right_ops)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn leaf_renders_value() {
        assert_eq!(Expr::Leaf(7).render(), "7");
        assert_eq!(Expr::Leaf(7).chain_of_thought(), "#7");
    }

    #[test]
    fn node_renders_with_parens() {
        let e = Expr::Node(
            Op::Mul,
            Box::new(Expr::Node(
                Op::Add,
                Box::new(Expr::Leaf(3)),
                Box::new(Expr::Leaf(4)),
            )),
            Box::new(Expr::Leaf(2)),
        );
        assert_eq!(e.render(), "(3+4)*2");
        assert_eq!(e.value(), 14);
        assert_eq!(e.chain_of_thought(), "3+4=7;7*2=14;#14");
    }

    #[test]
    fn prop_generated_exprs_valid() {
        propcheck::quick("expr-gen", |rng, size| {
            let n_ops = size % 7;
            let e = gen_expr(rng, n_ops);
            if e.n_ops() != n_ops {
                return Err(format!("wanted {n_ops} ops, got {}", e.n_ops()));
            }
            if !e.bounded() {
                return Err(format!("unbounded expr {}", e.render()));
            }
            // CoT's final answer always equals the tree value
            let cot = e.chain_of_thought();
            let ans: i64 = cot.rsplit('#').next().unwrap().parse().unwrap();
            if ans != e.value() {
                return Err(format!("cot answer {ans} != value {}", e.value()));
            }
            // number of ';' steps equals n_ops
            let steps = cot.matches(';').count();
            if steps != n_ops {
                return Err(format!("{steps} CoT steps for {n_ops} ops"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_cot_steps_are_correct_arithmetic() {
        propcheck::quick("cot-steps", |rng, size| {
            let e = gen_expr(rng, 1 + size % 5);
            for step in e.chain_of_thought().split(';') {
                if step.starts_with('#') || step.is_empty() {
                    continue;
                }
                let (lhs, rhs) = step.split_once('=').ok_or("step missing '='")?;
                let rhs: i64 = rhs.parse().map_err(|_| "bad rhs")?;
                // parse "a{op}b" with possibly negative a and b
                let mut op_idx = None;
                for (i, c) in lhs.char_indices().skip(1) {
                    if matches!(c, '+' | '*') || (c == '-' && !lhs[..i].ends_with(|p: char| "+-*".contains(p))) {
                        op_idx = Some(i);
                        break;
                    }
                }
                let i = op_idx.ok_or("no op found")?;
                let a: i64 = lhs[..i].parse().map_err(|_| "bad a")?;
                let opc = lhs.as_bytes()[i] as char;
                let b: i64 = lhs[i + 1..].parse().map_err(|_| "bad b")?;
                let expect = match opc {
                    '+' => a + b,
                    '-' => a - b,
                    '*' => a * b,
                    _ => return Err("bad op".into()),
                };
                if expect != rhs {
                    return Err(format!("step {step} wrong"));
                }
            }
            Ok(())
        });
    }
}
