//! Character-level tokenizer for the arithmetic-CoT task (vocab = 32).
//!
//! The paper trains on natural-language math; our substitution (DESIGN.md
//! §2) uses synthetic arithmetic chains with verifiable answers, so a tiny
//! fixed character vocabulary suffices. The id assignment must match
//! nothing on the Python side — the model is trained from scratch and the
//! manifest only carries `vocab = 32`.

/// Special tokens.
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;

/// Offset of digit '0'; digits are ids 3..=12.
pub const DIGIT0: i32 = 3;

const SYMBOLS: &[(char, i32)] = &[
    ('+', 13),
    ('-', 14),
    ('*', 15),
    ('(', 16),
    (')', 17),
    ('=', 18),
    ('#', 19),
    (';', 20),
    (' ', 21),
    ('Q', 22),
    ('A', 23),
    (':', 24),
    ('?', 25),
];

pub const VOCAB_SIZE: usize = 32;

/// Encode a char; panics on unsupported characters (task strings are fully
/// under our control, so an unknown char is a bug, not input error).
pub fn encode_char(c: char) -> i32 {
    if let Some(d) = c.to_digit(10) {
        return DIGIT0 + d as i32;
    }
    for &(s, id) in SYMBOLS {
        if s == c {
            return id;
        }
    }
    panic!("unencodable character {c:?}");
}

/// Decode an id to a char; special/unknown ids map to printable markers.
pub fn decode_char(id: i32) -> char {
    match id {
        PAD => '_',
        BOS => '^',
        EOS => '$',
        d if (DIGIT0..DIGIT0 + 10).contains(&d) => {
            char::from_digit((d - DIGIT0) as u32, 10).unwrap()
        }
        other => SYMBOLS
            .iter()
            .find(|&&(_, id)| id == other)
            .map(|&(c, _)| c)
            .unwrap_or('?'),
    }
}

/// Encode a string (no BOS/EOS added).
pub fn encode(s: &str) -> Vec<i32> {
    s.chars().map(encode_char).collect()
}

/// Decode a token slice, stopping at EOS, skipping PAD/BOS.
pub fn decode(ids: &[i32]) -> String {
    let mut out = String::new();
    for &id in ids {
        if id == EOS {
            break;
        }
        if id == PAD || id == BOS {
            continue;
        }
        out.push(decode_char(id));
    }
    out
}

/// Decode everything including markers (debugging / anomaly dumps).
pub fn decode_raw(ids: &[i32]) -> String {
    ids.iter().map(|&id| decode_char(id)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_task_chars() {
        let s = "Q:(3+4)*2=?A:3+4=7;7*2=14;#14";
        let ids = encode(s);
        assert_eq!(decode(&ids), s);
    }

    #[test]
    fn ids_in_vocab() {
        for c in "0123456789+-*()=#; QA:?".chars() {
            let id = encode_char(c);
            assert!((0..VOCAB_SIZE as i32).contains(&id), "{c:?} -> {id}");
        }
    }

    #[test]
    fn ids_unique() {
        let mut seen = std::collections::HashSet::new();
        for c in "0123456789+-*()=#; QA:?".chars() {
            assert!(seen.insert(encode_char(c)), "duplicate id for {c:?}");
        }
    }

    #[test]
    fn decode_stops_at_eos() {
        let ids = vec![BOS, DIGIT0 + 7, EOS, DIGIT0 + 9];
        assert_eq!(decode(&ids), "7");
    }

    #[test]
    #[should_panic(expected = "unencodable")]
    fn unknown_char_panics() {
        encode_char('x');
    }
}
