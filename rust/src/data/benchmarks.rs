//! The 7-benchmark evaluation suite + training split (paper Table 3 analog).
//!
//! Each paper benchmark maps to a deterministic synthetic split graded by
//! expression depth (operator count), with the *same item counts* as the
//! paper's Table 3. Seeds are fixed per benchmark, and the training split
//! uses a disjoint seed space, so train/eval never overlap.

use crate::util::rng::Rng;

use super::task::Task;

/// Evaluation protocol for a benchmark (paper §5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Protocol {
    /// One greedy-ish sample per problem.
    Pass1,
    /// Mean accuracy over k samples per problem (AIME24/AMC23: Avg@32).
    AvgK(usize),
}

/// A benchmark definition.
#[derive(Debug, Clone)]
pub struct Benchmark {
    pub name: &'static str,
    pub description: &'static str,
    pub size: usize,
    pub ops_lo: usize,
    pub ops_hi: usize,
    pub protocol: Protocol,
    seed: u64,
}

/// The 7 benchmarks, mirroring paper Table 3 sizes and difficulty ordering.
pub fn suite() -> Vec<Benchmark> {
    vec![
        Benchmark {
            name: "gsm8k",
            description: "grade-school analog: shallow 1-2 op chains",
            size: 1319,
            ops_lo: 1,
            ops_hi: 2,
            protocol: Protocol::Pass1,
            seed: 0xB1,
        },
        Benchmark {
            name: "math500",
            description: "MATH500 analog: 2-3 op chains",
            size: 500,
            ops_lo: 2,
            ops_hi: 3,
            protocol: Protocol::Pass1,
            seed: 0xB2,
        },
        Benchmark {
            name: "gaokao",
            description: "Gaokao analog: 3 op chains",
            size: 385,
            ops_lo: 3,
            ops_hi: 3,
            protocol: Protocol::Pass1,
            seed: 0xB3,
        },
        Benchmark {
            name: "minerva",
            description: "Minerva analog: 3-4 op chains",
            size: 272,
            ops_lo: 3,
            ops_hi: 4,
            protocol: Protocol::Pass1,
            seed: 0xB4,
        },
        Benchmark {
            name: "olympiad",
            description: "OlympiadBench analog: 4-5 op chains",
            size: 675,
            ops_lo: 4,
            ops_hi: 5,
            protocol: Protocol::Pass1,
            seed: 0xB5,
        },
        Benchmark {
            name: "aime24",
            description: "AIME24 analog: deepest 5-6 op chains, Avg@32",
            size: 30,
            ops_lo: 5,
            ops_hi: 6,
            protocol: Protocol::AvgK(32),
            seed: 0xB6,
        },
        Benchmark {
            name: "amc23",
            description: "AMC23 analog: 4-6 op chains, Avg@32",
            size: 40,
            ops_lo: 4,
            ops_hi: 6,
            protocol: Protocol::AvgK(32),
            seed: 0xB7,
        },
    ]
}

impl Benchmark {
    /// Materialize the benchmark's tasks (deterministic).
    pub fn tasks(&self, max_prompt: usize) -> Vec<Task> {
        let mut rng = Rng::new(0x5EED_0000 ^ self.seed);
        (0..self.size)
            .map(|i| {
                let ops = self.ops_lo + (i % (self.ops_hi - self.ops_lo + 1));
                Task::gen(&mut rng, ops, max_prompt)
            })
            .collect()
    }

    pub fn samples_per_item(&self) -> usize {
        match self.protocol {
            Protocol::Pass1 => 1,
            Protocol::AvgK(k) => k,
        }
    }
}

/// Training split analog of SimpleRL-Zoo (paper §5.1): disjoint seed space
/// from all benchmarks. The paper's Easy/Medium/Hard split maps to the op
/// range; §5.1's observation that "successful training critically depends
/// on using data that matches the model's capability" holds here too —
/// weaker scale points train on shallower ranges (see
/// `difficulty_for_model`).
pub fn training_split_ops(
    n: usize,
    max_prompt: usize,
    seed: u64,
    ops_lo: usize,
    ops_hi: usize,
) -> Vec<Task> {
    assert!(ops_lo >= 1 && ops_hi >= ops_lo);
    let mut rng = Rng::new(0x7EA1_0000 ^ seed);
    (0..n)
        .map(|i| {
            let ops = ops_lo + (i % (ops_hi - ops_lo + 1));
            Task::gen(&mut rng, ops, max_prompt)
        })
        .collect()
}

/// Default split: the paper's "hard" analog (3-5 ops).
pub fn training_split(n: usize, max_prompt: usize, seed: u64) -> Vec<Task> {
    training_split_ops(n, max_prompt, seed, 3, 5)
}

/// Capability-matched training difficulty per model scale (paper §5.1).
pub fn difficulty_for_model(model: &str) -> (usize, usize) {
    match model {
        "nano" => (1, 2),
        "tiny" => (1, 3),
        "small" => (2, 4),
        _ => (3, 5),
    }
}

/// Pretraining corpus: worked examples across all difficulties (1-6 ops),
/// the analog of the base model's math pretraining exposure.
pub fn pretrain_corpus(n: usize, max_prompt: usize, seed: u64) -> Vec<Task> {
    let mut rng = Rng::new(0xC0DE_0000 ^ seed);
    (0..n)
        .map(|i| {
            let ops = 1 + (i % 6);
            Task::gen(&mut rng, ops, max_prompt)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_matches_table3_sizes() {
        let s = suite();
        let sizes: Vec<(&str, usize)> = s.iter().map(|b| (b.name, b.size)).collect();
        assert_eq!(
            sizes,
            vec![
                ("gsm8k", 1319),
                ("math500", 500),
                ("gaokao", 385),
                ("minerva", 272),
                ("olympiad", 675),
                ("aime24", 30),
                ("amc23", 40),
            ]
        );
    }

    #[test]
    fn benchmarks_deterministic() {
        let b = &suite()[1];
        let a1 = b.tasks(48);
        let a2 = b.tasks(48);
        assert_eq!(a1.len(), 500);
        for (x, y) in a1.iter().zip(a2.iter()) {
            assert_eq!(x.prompt_text, y.prompt_text);
        }
    }

    #[test]
    fn difficulty_in_range() {
        for b in suite() {
            // sample a prefix to keep the test fast
            for t in b.tasks(48).into_iter().take(25) {
                let ops = t.expr.n_ops();
                assert!(
                    (b.ops_lo..=b.ops_hi).contains(&ops),
                    "{}: {} ops outside [{}, {}]",
                    b.name,
                    ops,
                    b.ops_lo,
                    b.ops_hi
                );
            }
        }
    }

    #[test]
    fn train_disjoint_from_eval() {
        // prompt-string collision between train split and gsm8k analog
        // should be essentially absent for deeper-op train items
        let train = training_split(500, 48, 0);
        let eval: std::collections::HashSet<String> =
            suite()[4].tasks(48).iter().map(|t| t.prompt_text.clone()).collect();
        let collisions = train.iter().filter(|t| eval.contains(&t.prompt_text)).count();
        assert!(collisions < 10, "{collisions} train/eval collisions");
    }

    #[test]
    fn avg_at_32_protocol() {
        let s = suite();
        assert_eq!(s[5].samples_per_item(), 32);
        assert_eq!(s[0].samples_per_item(), 1);
    }
}
