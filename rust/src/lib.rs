//! # sparse-rl
//!
//! Reproduction of *"Sparse-RL: Breaking the Memory Wall in LLM
//! Reinforcement Learning via Stable Sparse Rollouts"* (ACL 2026) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L1** Pallas kernels (decode attention with fused compression stats,
//!   R-KV scoring) — `python/compile/kernels/`, AOT-lowered,
//! * **L2** JAX transformer + GRPO/Sparse-RL train step —
//!   `python/compile/model.py`, AOT-lowered to `artifacts/`,
//! * **L3** this crate: the RL coordinator (rollout engine, memory-wall
//!   scheduler, KV manager, rejection sampling, importance reweighting,
//!   trainer) plus every substrate (tokenizer, task generator, benchmark
//!   suite, metrics, JSON/RNG/CLI/bench utilities).
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `sparse-rl` binary is self-contained.

pub mod compression;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod runtime;
pub mod util;
