//! High-level experiment runners shared by the CLI, examples, and benches.
//!
//! Each paper table/figure harness composes these: pretrain (or load) a
//! base model, run RL under some mode, evaluate on the benchmark suite,
//! and emit the series/rows. Keeping them in the library means the
//! examples stay thin and the benches measure exactly the production code
//! path.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::{ExperimentConfig, RolloutMode};
use crate::coordinator::{evaluate_suite, EvalOptions, EvalResult, Metrics, Trainer};
use crate::data::benchmarks::{self, Benchmark};
use crate::runtime::{ModelEngine, TrainState};

/// Default pretraining schedule per model scale (steps chosen so the base
/// model reaches non-trivial accuracy on shallow tasks, mirroring the
/// paper's requirement that zero-RL data "match the model's capability").
pub fn default_pretrain_steps(model_name: &str) -> usize {
    match model_name {
        "nano" => 400,
        "tiny" => 500,
        "small" => 600,
        "base" => 800,
        _ => 400,
    }
}

/// Pretrain a fresh base model on worked examples; returns the state.
pub fn pretrain_base(
    engine: &ModelEngine,
    steps: usize,
    seed: u64,
    log_every: usize,
) -> Result<(TrainState, Vec<f64>)> {
    let state = TrainState::new(engine.init_params(seed as i32)?);
    let mut cfg = ExperimentConfig::new(&engine.manifest.dir);
    cfg.seed = seed;
    cfg.train.hyp.lr = 1e-3;
    let corpus = benchmarks::pretrain_corpus(4096, engine.manifest.config.prompt_len, seed);
    let mut trainer = Trainer::new(engine, cfg, state, vec![]);
    let losses = trainer.pretrain(&corpus, steps, log_every)?;
    Ok((trainer.state, losses))
}

/// Load a cached pretrained base checkpoint, or pretrain and cache it.
/// Cache key: runs/base/<model>-s<steps>.srl
pub fn load_or_pretrain_base(
    engine: &ModelEngine,
    steps: usize,
    seed: u64,
) -> Result<TrainState> {
    let name = &engine.manifest.config.name;
    let path = PathBuf::from(format!("runs/base/{name}-s{steps}-seed{seed}.srl"));
    if path.exists() {
        let (model, state) = crate::runtime::params::load(&path, engine.manifest.config.n_params)
            .with_context(|| format!("loading cached base {}", path.display()))?;
        anyhow::ensure!(model == *name, "cached base is for model {model}, wanted {name}");
        eprintln!("loaded cached base model {}", path.display());
        return Ok(state);
    }
    eprintln!("pretraining base model ({steps} steps)...");
    let (state, _losses) = pretrain_base(engine, steps, seed, steps / 10)?;
    crate::runtime::params::save(&path, name, &state, false)?;
    eprintln!("cached base model at {}", path.display());
    Ok(state)
}

/// Run an RL experiment; returns the trainer (metrics + final state).
pub fn run_rl<'a>(
    engine: &'a ModelEngine,
    mut cfg: ExperimentConfig,
    init: TrainState,
    print_every: usize,
) -> Result<Trainer<'a>> {
    let (auto_lo, auto_hi) = benchmarks::difficulty_for_model(&engine.manifest.config.name);
    let ops_lo = if cfg.train.ops_lo == 0 { auto_lo } else { cfg.train.ops_lo };
    let ops_hi = if cfg.train.ops_hi == 0 { auto_hi } else { cfg.train.ops_hi.max(ops_lo) };
    let tasks = benchmarks::training_split_ops(
        8192,
        engine.manifest.config.prompt_len,
        cfg.seed,
        ops_lo,
        ops_hi,
    );
    cfg.artifact_dir = engine.manifest.dir.clone();
    let steps = cfg.train.steps;
    let label = cfg.mode.label();
    let mut trainer = Trainer::new(engine, cfg, init, tasks);
    for step in 0..steps {
        let r = trainer.rl_step()?;
        if print_every > 0 && (step % print_every == 0 || step + 1 == steps) {
            println!(
                "[{label}] step {step:>4} reward {:.3} len {:>5.1} ent {:.3} kl {:.2e} rej {:.3} gnorm {:.3} save {:.2}",
                r.reward_mean,
                r.response_len_mean,
                r.entropy_mean,
                r.mismatch_kl,
                r.rejection_rate,
                r.grad_norm,
                r.toks_saving,
            );
        }
    }
    Ok(trainer)
}

/// Evaluate a checkpoint on the full suite (optionally item-limited).
/// `opts` picks the rollout engine and memory-wall knobs
/// (`EvalOptions::default()` = static chunking, worst-case admission).
pub fn eval_checkpoint(
    engine: &ModelEngine,
    params: &[f32],
    mode: RolloutMode,
    limit: usize,
    seed: u64,
    opts: &EvalOptions,
) -> Result<(Vec<EvalResult>, f64)> {
    let suite = benchmarks::suite();
    evaluate_suite(engine, params, mode, &suite, limit, seed, opts)
}

/// Persist a trainer's metrics + checkpoint under its out_dir.
pub fn save_run(trainer: &Trainer, tag: &str) -> Result<(PathBuf, PathBuf)> {
    let dir = trainer.cfg.out_dir.clone();
    std::fs::create_dir_all(&dir).ok();
    let csv = dir.join(format!("{tag}-metrics.csv"));
    trainer.metrics.write_csv(&csv)?;
    let ckpt = dir.join(format!("{tag}.srl"));
    crate::runtime::params::save(
        &ckpt,
        &trainer.engine.manifest.config.name,
        &trainer.state,
        false,
    )?;
    Ok((csv, ckpt))
}

/// Pretty-print a metrics series as a sparkline-ish text row (figures in
/// terminal form; the CSVs carry the full data).
pub fn print_series(metrics: &Metrics, name: &str, buckets: usize) {
    let s: Vec<f64> = metrics
        .series(name)
        .into_iter()
        .filter(|v| !v.is_nan())
        .collect();
    if s.is_empty() {
        println!("  {name:<16} (no data)");
        return;
    }
    let bucket = (s.len() as f64 / buckets as f64).ceil().max(1.0) as usize;
    let vals: Vec<f64> = s
        .chunks(bucket)
        .map(|c| c.iter().sum::<f64>() / c.len() as f64)
        .collect();
    let cells: Vec<String> = vals.iter().map(|v| format!("{v:>8.3}")).collect();
    println!("  {name:<16} {}", cells.join(" "));
}

/// Resolve an artifacts dir for a model preset from common roots.
pub fn find_artifacts(model: &str) -> Result<PathBuf> {
    for root in ["artifacts", "../artifacts"] {
        let p = Path::new(root).join(model);
        if p.join("manifest.json").exists() {
            return Ok(p);
        }
    }
    anyhow::bail!(
        "artifacts for {model:?} not found; build with \
         `cd python && python -m compile.aot --preset {model} --out-dir ../artifacts`"
    )
}

/// Standard benchmark suite accessor (re-export for examples).
pub fn suite() -> Vec<Benchmark> {
    benchmarks::suite()
}
