//! KV memory manager — the "memory wall" (paper §1), now a page pool.
//!
//! Simulates the accelerator's KV-cache capacity as a global pool of
//! fixed-size pages (`page_tokens` tokens each; `page_tokens = 1` is the
//! token-granular degenerate case and reproduces the original whole-token
//! accounting bit-for-bit). Two admission regimes build on it:
//!
//! * **Worst-case reservation** (the seed policy, paper §1's OOM-avoidance
//!   story): every sequence reserves its worst-case residency up front —
//!   dense `max_seq`, sparse `budget + buffer` — so admissible width is
//!   `capacity / worst_case` regardless of what sequences actually hold.
//! * **Paged residency** (this PR): a sequence is admitted with only the
//!   pages its prompt needs, `grow`s page-by-page as decode writes land,
//!   and `shrink`s back to its compressed residency after each compression
//!   event. Admissible width tracks *actual* residency, which is what
//!   raises effective rollout width under a fixed budget (Sparrow,
//!   arXiv:2606.08446; Shadow-Mask, arXiv:2605.06850).
//!
//! The trade-off: worst-case admission can never fail mid-decode (width is
//! paid for at admission), while paged admission can hit the wall on a
//! `grow` — the scheduler/engine resolve that by preempting the
//! lowest-progress sequence and requeueing it (see `scheduler.rs`), so the
//! wall is never breached and a drain is always reachable.
//!
//! Accounting is dual: `reserved()` counts *logical tokens* (what callers
//! asked for), `used_pages()` counts pool pages (what the wall charges).
//! The gap between `used_pages * page_tokens` and `reserved` is internal
//! fragmentation (`fragmentation()`).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Sequence handle for reservations.
pub type SeqId = u64;

#[derive(Debug)]
pub struct KvMemoryManager {
    /// Total KV tokens that may be resident simultaneously
    /// (normalized to a whole number of pages).
    capacity: usize,
    /// Tokens per page (1 = token-granular, the seed behavior).
    page_tokens: usize,
    total_pages: usize,
    used_pages: usize,
    /// Logical tokens reserved (sum over live sequences).
    reserved: usize,
    seqs: BTreeMap<SeqId, usize>,
    /// High-water mark of reserved tokens.
    pub peak_reserved: usize,
    /// High-water mark of pool pages in use.
    pub peak_used_pages: usize,
    /// High-water mark of concurrently live sequences — the globally
    /// admitted width. With the pipelined engine this is the one counter
    /// that sees ALL worker lanes at once (each lane only observes its own
    /// slots), so the multi-worker width claims and the
    /// `peak <= workers * slots` conservation checks read it.
    pub peak_live_seqs: usize,
    /// Count of rejected admission attempts (pressure signal).
    pub rejections: u64,
    /// Count of rejected mid-decode `grow` attempts (preemption signal).
    pub grow_rejections: u64,
}

impl KvMemoryManager {
    /// Token-granular pool (page size 1): identical admission arithmetic
    /// to the original whole-token manager.
    pub fn new(capacity: usize) -> Self {
        Self::with_pages(capacity, 1)
    }

    /// Page-granular pool: `capacity` tokens split into pages of
    /// `page_tokens` (capacity is rounded down to whole pages).
    pub fn with_pages(capacity: usize, page_tokens: usize) -> Self {
        assert!(page_tokens >= 1, "page_tokens must be >= 1");
        let total_pages = capacity / page_tokens;
        KvMemoryManager {
            capacity: total_pages * page_tokens,
            page_tokens,
            total_pages,
            used_pages: 0,
            reserved: 0,
            seqs: BTreeMap::new(),
            peak_reserved: 0,
            peak_used_pages: 0,
            peak_live_seqs: 0,
            rejections: 0,
            grow_rejections: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    pub fn used_pages(&self) -> usize {
        self.used_pages
    }

    pub fn free_pages(&self) -> usize {
        self.total_pages - self.used_pages
    }

    /// Pages needed to hold `tokens` resident tokens.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Logical tokens reserved.
    pub fn reserved(&self) -> usize {
        self.reserved
    }

    /// Tokens still allocatable (whole free pages).
    pub fn available(&self) -> usize {
        self.free_pages() * self.page_tokens
    }

    /// How many sequences each reserving `per_seq` tokens fit right now.
    pub fn admissible(&self, per_seq: usize) -> usize {
        if per_seq == 0 {
            return usize::MAX;
        }
        self.free_pages() / self.pages_for(per_seq)
    }

    /// Reserve `tokens` for a sequence; fails when the wall is hit.
    pub fn reserve(&mut self, seq: SeqId, tokens: usize) -> Result<()> {
        if self.seqs.contains_key(&seq) {
            bail!("sequence {seq} already holds a reservation");
        }
        let pages = self.pages_for(tokens);
        if pages > self.free_pages() {
            self.rejections += 1;
            bail!(
                "KV memory wall: need {tokens}, only {} of {} available",
                self.available(),
                self.capacity
            );
        }
        self.used_pages += pages;
        self.reserved += tokens;
        self.peak_reserved = self.peak_reserved.max(self.reserved);
        self.peak_used_pages = self.peak_used_pages.max(self.used_pages);
        self.seqs.insert(seq, tokens);
        self.peak_live_seqs = self.peak_live_seqs.max(self.seqs.len());
        Ok(())
    }

    /// Grow a live reservation to `new_tokens` (mid-decode residency
    /// growth, paged admission). Returns `Ok(false)` — without side
    /// effects beyond the rejection counter — when the extra pages don't
    /// fit; the caller preempts and retries. `new_tokens <= current` is a
    /// no-op success.
    pub fn grow(&mut self, seq: SeqId, new_tokens: usize) -> Result<bool> {
        let cur = match self.seqs.get(&seq) {
            Some(&t) => t,
            None => bail!("sequence {seq} holds no reservation"),
        };
        if new_tokens <= cur {
            return Ok(true);
        }
        let delta_pages = self.pages_for(new_tokens) - self.pages_for(cur);
        if delta_pages > self.free_pages() {
            self.grow_rejections += 1;
            return Ok(false);
        }
        self.used_pages += delta_pages;
        self.reserved += new_tokens - cur;
        self.peak_reserved = self.peak_reserved.max(self.reserved);
        self.peak_used_pages = self.peak_used_pages.max(self.used_pages);
        self.seqs.insert(seq, new_tokens);
        Ok(true)
    }

    /// Release a sequence's reservation (finished / evicted / preempted).
    pub fn release(&mut self, seq: SeqId) -> Result<usize> {
        match self.seqs.remove(&seq) {
            Some(tokens) => {
                self.used_pages -= self.pages_for(tokens);
                self.reserved -= tokens;
                Ok(tokens)
            }
            None => bail!("sequence {seq} holds no reservation"),
        }
    }

    /// Shrink a live reservation (e.g. after compression established a
    /// tighter bound). Growing via `shrink` is rejected — use `grow`, so
    /// the wall check always runs.
    pub fn shrink(&mut self, seq: SeqId, new_tokens: usize) -> Result<()> {
        match self.seqs.get(&seq) {
            Some(&cur) => {
                if new_tokens > cur {
                    bail!("shrink({seq}) would grow {} -> {}", cur, new_tokens);
                }
                self.used_pages -= self.pages_for(cur) - self.pages_for(new_tokens);
                self.reserved -= cur - new_tokens;
                self.seqs.insert(seq, new_tokens);
                Ok(())
            }
            None => bail!("sequence {seq} holds no reservation"),
        }
    }

    pub fn live_sequences(&self) -> usize {
        self.seqs.len()
    }

    /// Structural invariants the property tests hold at every step:
    /// token and page accounting both equal the sums over live
    /// reservations, pages never exceed the pool, reserved tokens fit in
    /// the pages charged for them, and the high-water marks are
    /// monotone-consistent (at least current residency, never above the
    /// wall).
    pub fn check_invariants(&self) -> Result<()> {
        let sum: usize = self.seqs.values().sum();
        if self.reserved != sum {
            bail!("reserved {} != sum of live reservations {}", self.reserved, sum);
        }
        let page_sum: usize = self.seqs.values().map(|&t| self.pages_for(t)).sum();
        if self.used_pages != page_sum {
            bail!("used_pages {} != sum of live page counts {}", self.used_pages, page_sum);
        }
        if self.used_pages > self.total_pages {
            bail!(
                "used_pages {} exceeds pool {} (wall was breached)",
                self.used_pages,
                self.total_pages
            );
        }
        if self.reserved > self.used_pages * self.page_tokens {
            bail!(
                "reserved {} tokens exceed charged pages {} x {}",
                self.reserved,
                self.used_pages,
                self.page_tokens
            );
        }
        if self.peak_reserved < self.reserved {
            bail!(
                "peak_reserved {} below current reserved {}",
                self.peak_reserved,
                self.reserved
            );
        }
        if self.peak_reserved > self.capacity {
            bail!(
                "peak_reserved {} exceeds capacity {} (wall was breached)",
                self.peak_reserved,
                self.capacity
            );
        }
        if self.peak_used_pages < self.used_pages {
            bail!(
                "peak_used_pages {} below current used_pages {}",
                self.peak_used_pages,
                self.used_pages
            );
        }
        if self.peak_used_pages > self.total_pages {
            bail!(
                "peak_used_pages {} exceeds pool {} (wall was breached)",
                self.peak_used_pages,
                self.total_pages
            );
        }
        if self.peak_live_seqs < self.seqs.len() {
            bail!(
                "peak_live_seqs {} below current live count {}",
                self.peak_live_seqs,
                self.seqs.len()
            );
        }
        Ok(())
    }

    /// Token utilization in [0, 1] (logical tokens / capacity).
    pub fn utilization(&self) -> f64 {
        if self.capacity == 0 {
            0.0
        } else {
            self.reserved as f64 / self.capacity as f64
        }
    }

    /// Page occupancy in [0, 1] (pages in use / pool pages).
    pub fn page_occupancy(&self) -> f64 {
        if self.total_pages == 0 {
            0.0
        } else {
            self.used_pages as f64 / self.total_pages as f64
        }
    }

    /// Internal fragmentation in [0, 1): fraction of charged page tokens
    /// not backing a logical reservation. 0 when nothing is resident and
    /// always 0 at page size 1.
    pub fn fragmentation(&self) -> f64 {
        let charged = self.used_pages * self.page_tokens;
        if charged == 0 {
            0.0
        } else {
            1.0 - self.reserved as f64 / charged as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn admission_widths_dense_vs_sparse() {
        // the paper's core arithmetic: 2048-token wall, dense seqs reserve
        // 208 (worst case), sparse reserve 48
        let m = KvMemoryManager::new(2048);
        assert_eq!(m.admissible(208), 9);
        assert_eq!(m.admissible(48), 42);
    }

    #[test]
    fn wall_rejects_overcommit() {
        let mut m = KvMemoryManager::new(100);
        m.reserve(1, 60).unwrap();
        assert!(m.reserve(2, 60).is_err());
        assert_eq!(m.rejections, 1);
        m.release(1).unwrap();
        m.reserve(2, 60).unwrap();
    }

    #[test]
    fn peak_live_seqs_tracks_admitted_width() {
        let mut m = KvMemoryManager::new(100);
        m.reserve(1, 10).unwrap();
        m.reserve(2, 10).unwrap();
        assert_eq!(m.peak_live_seqs, 2);
        m.release(1).unwrap();
        m.reserve(3, 10).unwrap();
        assert_eq!(m.peak_live_seqs, 2, "peak is a high-water mark");
        m.reserve(4, 10).unwrap();
        assert_eq!(m.peak_live_seqs, 3);
        m.check_invariants().unwrap();
    }

    #[test]
    fn duplicate_and_unknown_rejected() {
        let mut m = KvMemoryManager::new(100);
        m.reserve(1, 10).unwrap();
        assert!(m.reserve(1, 10).is_err());
        assert!(m.release(99).is_err());
    }

    #[test]
    fn shrink_only_shrinks() {
        let mut m = KvMemoryManager::new(100);
        m.reserve(1, 50).unwrap();
        m.shrink(1, 30).unwrap();
        assert_eq!(m.reserved(), 30);
        assert!(m.shrink(1, 40).is_err());
    }

    #[test]
    fn pages_round_up_and_grow_page_by_page() {
        let mut m = KvMemoryManager::with_pages(64, 16);
        assert_eq!(m.total_pages(), 4);
        m.reserve(1, 10).unwrap(); // 1 page
        assert_eq!(m.used_pages(), 1);
        assert_eq!(m.available(), 48);
        // growing within the page costs nothing
        assert!(m.grow(1, 16).unwrap());
        assert_eq!(m.used_pages(), 1);
        // crossing the boundary takes a fresh page
        assert!(m.grow(1, 17).unwrap());
        assert_eq!(m.used_pages(), 2);
        // fragmentation: 17 tokens on 32 charged
        assert!((m.fragmentation() - (1.0 - 17.0 / 32.0)).abs() < 1e-9);
        // a second sequence can take the remaining 2 pages but not 3
        m.reserve(2, 32).unwrap();
        assert!(!m.grow(2, 33).unwrap());
        assert_eq!(m.grow_rejections, 1);
        // shrink frees whole pages only
        m.shrink(1, 16).unwrap();
        assert_eq!(m.used_pages(), 3);
        assert!(m.grow(2, 48).unwrap());
        m.check_invariants().unwrap();
        assert_eq!(m.release(1).unwrap(), 16);
        assert_eq!(m.release(2).unwrap(), 48);
        assert_eq!(m.used_pages(), 0);
        assert_eq!(m.reserved(), 0);
    }

    #[test]
    fn grow_on_unknown_sequence_is_an_error() {
        let mut m = KvMemoryManager::with_pages(64, 8);
        assert!(m.grow(42, 10).is_err());
    }

    #[test]
    fn capacity_normalized_to_whole_pages() {
        let m = KvMemoryManager::with_pages(100, 16);
        assert_eq!(m.total_pages(), 6);
        assert_eq!(m.capacity(), 96);
        assert_eq!(m.admissible(17), 3); // 2 pages each, 6 in the pool
    }

    #[test]
    fn prop_accounting_conserves() {
        propcheck::quick("kv-conservation", |rng, size| {
            let cap = 64 + size * 8;
            let mut m = KvMemoryManager::new(cap);
            let mut live: Vec<(SeqId, usize)> = vec![];
            let mut next_id = 0u64;
            for _ in 0..200 {
                if rng.chance(0.6) || live.is_empty() {
                    let want = 1 + rng.below(cap / 4 + 1);
                    next_id += 1;
                    if m.reserve(next_id, want).is_ok() {
                        live.push((next_id, want));
                    }
                } else {
                    let k = rng.below(live.len());
                    let (id, _) = live.swap_remove(k);
                    m.release(id).map_err(|e| e.to_string())?;
                }
                let expect: usize = live.iter().map(|(_, t)| t).sum();
                if m.reserved() != expect {
                    return Err(format!("reserved {} != sum {}", m.reserved(), expect));
                }
                if m.reserved() > cap {
                    return Err("over capacity".into());
                }
                if m.live_sequences() != live.len() {
                    return Err("live count mismatch".into());
                }
                m.check_invariants().map_err(|e| e.to_string())?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_paged_pool_conserves_under_grow_shrink() {
        // Random reserve/grow/shrink/release interleavings at random page
        // sizes: pages and tokens both conserve, the pool is never
        // overdrawn, and failed grows leave no trace.
        propcheck::quick("kv-paged-conservation", |rng, size| {
            let page = 1 + rng.below(16);
            let pool_pages = 4 + rng.below(16 + size);
            let cap = page * pool_pages;
            let mut m = KvMemoryManager::with_pages(cap, page);
            let mut live: Vec<(SeqId, usize)> = vec![];
            let mut next_id = 0u64;
            for _ in 0..200 {
                match if live.is_empty() { 0 } else { rng.below(4) } {
                    0 => {
                        next_id += 1;
                        let want = 1 + rng.below(cap / 2 + 1);
                        let fits = m.pages_for(want) <= m.free_pages();
                        let got = m.reserve(next_id, want).is_ok();
                        if got != fits {
                            return Err(format!("reserve({want}) = {got}, fits = {fits}"));
                        }
                        if got {
                            live.push((next_id, want));
                        }
                    }
                    1 => {
                        let k = rng.below(live.len());
                        let (id, cur) = live[k];
                        let target = cur + rng.below(2 * page + 1);
                        let delta = m.pages_for(target) - m.pages_for(cur);
                        let fits = delta <= m.free_pages();
                        let grown = m.grow(id, target).map_err(|e| e.to_string())?;
                        if grown != fits {
                            return Err(format!("grow({cur}->{target}) = {grown}, fits = {fits}"));
                        }
                        if grown {
                            live[k].1 = target;
                        }
                    }
                    2 => {
                        let k = rng.below(live.len());
                        let (id, cur) = live[k];
                        let target = rng.below(cur + 1);
                        m.shrink(id, target).map_err(|e| e.to_string())?;
                        live[k].1 = target;
                    }
                    _ => {
                        let k = rng.below(live.len());
                        let (id, toks) = live.swap_remove(k);
                        let freed = m.release(id).map_err(|e| e.to_string())?;
                        if freed != toks {
                            return Err(format!("released {freed}, reserved {toks}"));
                        }
                    }
                }
                let tok_sum: usize = live.iter().map(|(_, t)| t).sum();
                let page_sum: usize = live.iter().map(|(_, t)| m.pages_for(*t)).sum();
                if m.reserved() != tok_sum || m.used_pages() != page_sum {
                    return Err(format!(
                        "pool out of sync: {}/{} vs {}/{}",
                        m.reserved(),
                        m.used_pages(),
                        tok_sum,
                        page_sum
                    ));
                }
                m.check_invariants().map_err(|e| e.to_string())?;
            }
            // a full drain always reaches the empty pool
            for (id, _) in live.drain(..) {
                m.release(id).map_err(|e| e.to_string())?;
            }
            if m.used_pages() != 0 || m.reserved() != 0 {
                return Err("drain left residue".into());
            }
            Ok(())
        });
    }
}
