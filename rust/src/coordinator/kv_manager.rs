//! KV memory manager — the "memory wall" (paper §1).
//!
//! Simulates the accelerator's KV-cache capacity as a global token pool.
//! Sequences must *reserve* their worst-case residency before admission
//! (exactly the OOM-avoidance policy the paper describes: "rollout batch
//! sizes must be constrained" under dense caches). Dense sequences reserve
//! `max_seq` tokens (long-tail worst case); sparse sequences reserve only
//! `budget + buffer`. The resulting admissible width is what drives the
//! dense-vs-sparse throughput gap in the benches.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Sequence handle for reservations.
pub type SeqId = u64;

#[derive(Debug)]
pub struct KvMemoryManager {
    /// Total KV tokens that may be resident simultaneously.
    capacity: usize,
    reserved: usize,
    seqs: BTreeMap<SeqId, usize>,
    /// High-water mark of reserved tokens.
    pub peak_reserved: usize,
    /// Count of rejected admission attempts (pressure signal).
    pub rejections: u64,
}

impl KvMemoryManager {
    pub fn new(capacity: usize) -> Self {
        KvMemoryManager {
            capacity,
            reserved: 0,
            seqs: BTreeMap::new(),
            peak_reserved: 0,
            rejections: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn reserved(&self) -> usize {
        self.reserved
    }

    pub fn available(&self) -> usize {
        self.capacity - self.reserved
    }

    /// How many sequences each reserving `per_seq` tokens fit right now.
    pub fn admissible(&self, per_seq: usize) -> usize {
        if per_seq == 0 {
            return usize::MAX;
        }
        self.available() / per_seq
    }

    /// Reserve `tokens` for a sequence; fails when the wall is hit.
    pub fn reserve(&mut self, seq: SeqId, tokens: usize) -> Result<()> {
        if self.seqs.contains_key(&seq) {
            bail!("sequence {seq} already holds a reservation");
        }
        if tokens > self.available() {
            self.rejections += 1;
            bail!(
                "KV memory wall: need {tokens}, only {} of {} available",
                self.available(),
                self.capacity
            );
        }
        self.reserved += tokens;
        self.peak_reserved = self.peak_reserved.max(self.reserved);
        self.seqs.insert(seq, tokens);
        Ok(())
    }

    /// Release a sequence's reservation (finished / evicted).
    pub fn release(&mut self, seq: SeqId) -> Result<usize> {
        match self.seqs.remove(&seq) {
            Some(tokens) => {
                self.reserved -= tokens;
                Ok(tokens)
            }
            None => bail!("sequence {seq} holds no reservation"),
        }
    }

    /// Shrink a live reservation (e.g. after compression established a
    /// tighter bound). Growing is rejected — grow-by-release-and-reserve so
    /// the wall check always runs.
    pub fn shrink(&mut self, seq: SeqId, new_tokens: usize) -> Result<()> {
        match self.seqs.get_mut(&seq) {
            Some(cur) => {
                if new_tokens > *cur {
                    bail!("shrink({seq}) would grow {} -> {}", cur, new_tokens);
                }
                self.reserved -= *cur - new_tokens;
                *cur = new_tokens;
                Ok(())
            }
            None => bail!("sequence {seq} holds no reservation"),
        }
    }

    pub fn live_sequences(&self) -> usize {
        self.seqs.len()
    }

    /// Structural invariants the property tests hold at every step:
    /// reserved tokens equal the sum over live reservations, never exceed
    /// capacity, and the high-water mark is monotone-consistent (at least
    /// the current residency, never above the wall).
    pub fn check_invariants(&self) -> Result<()> {
        let sum: usize = self.seqs.values().sum();
        if self.reserved != sum {
            bail!("reserved {} != sum of live reservations {}", self.reserved, sum);
        }
        if self.reserved > self.capacity {
            bail!("reserved {} exceeds capacity {}", self.reserved, self.capacity);
        }
        if self.peak_reserved < self.reserved {
            bail!(
                "peak_reserved {} below current reserved {}",
                self.peak_reserved,
                self.reserved
            );
        }
        if self.peak_reserved > self.capacity {
            bail!(
                "peak_reserved {} exceeds capacity {} (wall was breached)",
                self.peak_reserved,
                self.capacity
            );
        }
        Ok(())
    }

    /// Utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        if self.capacity == 0 {
            0.0
        } else {
            self.reserved as f64 / self.capacity as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn admission_widths_dense_vs_sparse() {
        // the paper's core arithmetic: 2048-token wall, dense seqs reserve
        // 208 (worst case), sparse reserve 48
        let m = KvMemoryManager::new(2048);
        assert_eq!(m.admissible(208), 9);
        assert_eq!(m.admissible(48), 42);
    }

    #[test]
    fn wall_rejects_overcommit() {
        let mut m = KvMemoryManager::new(100);
        m.reserve(1, 60).unwrap();
        assert!(m.reserve(2, 60).is_err());
        assert_eq!(m.rejections, 1);
        m.release(1).unwrap();
        m.reserve(2, 60).unwrap();
    }

    #[test]
    fn duplicate_and_unknown_rejected() {
        let mut m = KvMemoryManager::new(100);
        m.reserve(1, 10).unwrap();
        assert!(m.reserve(1, 10).is_err());
        assert!(m.release(99).is_err());
    }

    #[test]
    fn shrink_only_shrinks() {
        let mut m = KvMemoryManager::new(100);
        m.reserve(1, 50).unwrap();
        m.shrink(1, 30).unwrap();
        assert_eq!(m.reserved(), 30);
        assert!(m.shrink(1, 40).is_err());
    }

    #[test]
    fn prop_accounting_conserves() {
        propcheck::quick("kv-conservation", |rng, size| {
            let cap = 64 + size * 8;
            let mut m = KvMemoryManager::new(cap);
            let mut live: Vec<(SeqId, usize)> = vec![];
            let mut next_id = 0u64;
            for _ in 0..200 {
                if rng.chance(0.6) || live.is_empty() {
                    let want = 1 + rng.below(cap / 4 + 1);
                    next_id += 1;
                    if m.reserve(next_id, want).is_ok() {
                        live.push((next_id, want));
                    }
                } else {
                    let k = rng.below(live.len());
                    let (id, _) = live.swap_remove(k);
                    m.release(id).map_err(|e| e.to_string())?;
                }
                let expect: usize = live.iter().map(|(_, t)| t).sum();
                if m.reserved() != expect {
                    return Err(format!("reserved {} != sum {}", m.reserved(), expect));
                }
                if m.reserved() > cap {
                    return Err("over capacity".into());
                }
                if m.live_sequences() != live.len() {
                    return Err("live count mismatch".into());
                }
                m.check_invariants().map_err(|e| e.to_string())?;
            }
            Ok(())
        });
    }
}
