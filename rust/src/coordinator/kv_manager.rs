//! KV memory manager — the "memory wall" (paper §1), now a refcounting
//! page pool with prefix sharing.
//!
//! Simulates the accelerator's KV-cache capacity as a global pool of
//! fixed-size pages (`page_tokens` tokens each; `page_tokens = 1` is the
//! token-granular degenerate case and reproduces the original whole-token
//! accounting bit-for-bit). Two admission regimes build on it:
//!
//! * **Worst-case reservation** (the seed policy, paper §1's OOM-avoidance
//!   story): every sequence reserves its worst-case residency up front —
//!   dense `max_seq`, sparse `budget + buffer` — so admissible width is
//!   `capacity / worst_case` regardless of what sequences actually hold.
//! * **Paged residency** (PR 2): a sequence is admitted with only the
//!   pages its prompt needs, `grow`s page-by-page as decode writes land,
//!   and `shrink`s back to its compressed residency after each compression
//!   event. Admissible width tracks *actual* residency, which is what
//!   raises effective rollout width under a fixed budget (Sparrow,
//!   arXiv:2606.08446; Shadow-Mask, arXiv:2605.06850).
//!
//! On top of paged residency this pool supports **refcounted prefix
//! sharing** (SGLang's RadixAttention idea specialized to the GRPO group
//! shape): G sequences generated from one prompt map the same page-aligned
//! prompt prefix read-only. The prefix's pages are charged against the
//! wall ONCE and carry a refcount; each sharer additionally owns its
//! private pages (prompt tail past the page boundary + decode growth).
//! Because the sparse path *rewrites* retained KV planes at compression, a
//! sharer must fork to a fully private reservation (`fork_to_private`,
//! copy-on-write) before its first compression event — detaching from the
//! prefix (freeing it when the last sharer leaves) and charging its full
//! compressed residency privately. A denied fork behaves exactly like a
//! denied `grow`: no state change, `grow_rejections` bumped, caller
//! preempts someone and retries.
//!
//! The trade-off: worst-case admission can never fail mid-decode (width is
//! paid for at admission), while paged admission can hit the wall on a
//! `grow` (or a CoW fork) — the scheduler/engine resolve that by
//! preempting the lowest-progress sequence and requeueing it (see
//! `scheduler.rs`), so the wall is never breached and a drain is always
//! reachable.
//!
//! Accounting is dual: `reserved()` counts *logical tokens* (what callers
//! asked for; a shared prefix's tokens count once), `used_pages()` counts
//! pool pages (what the wall charges; a shared prefix's pages count once).
//! The gap between `used_pages * page_tokens` and `reserved` is internal
//! fragmentation (`fragmentation()`).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Sequence handle for reservations.
pub type SeqId = u64;

/// One live sequence's holdings: its private tokens plus an optional
/// attachment to a refcounted shared prefix.
#[derive(Debug, Clone, Copy)]
struct SeqEntry {
    /// Tokens this sequence owns exclusively (prompt tail past the shared
    /// page boundary + decode growth), or its whole residency when
    /// `prefix` is `None`.
    private: usize,
    /// Shared prefix this sequence reads, if any.
    prefix: Option<u64>,
}

/// A resident shared prompt prefix (page-aligned token run charged once).
#[derive(Debug, Clone, Copy)]
struct PrefixEntry {
    tokens: usize,
    refs: usize,
}

#[derive(Debug)]
pub struct KvMemoryManager {
    /// Total KV tokens that may be resident simultaneously
    /// (normalized to a whole number of pages).
    capacity: usize,
    /// Tokens per page (1 = token-granular, the seed behavior).
    page_tokens: usize,
    total_pages: usize,
    used_pages: usize,
    /// Logical tokens reserved (sum over live sequences' private tokens
    /// plus each resident shared prefix once).
    reserved: usize,
    seqs: BTreeMap<SeqId, SeqEntry>,
    /// Resident shared prefixes by caller-chosen id (the scheduler keys
    /// them by prompt identity), each refcounted by its live sharers.
    prefixes: BTreeMap<u64, PrefixEntry>,
    /// High-water mark of reserved tokens.
    pub peak_reserved: usize,
    /// High-water mark of pool pages in use.
    pub peak_used_pages: usize,
    /// High-water mark of concurrently live sequences — the globally
    /// admitted width. With the pipelined engine this is the one counter
    /// that sees ALL worker lanes at once (each lane only observes its own
    /// slots), so the multi-worker width claims and the
    /// `peak <= workers * slots` conservation checks read it.
    pub peak_live_seqs: usize,
    /// Count of rejected admission attempts (pressure signal).
    pub rejections: u64,
    /// Count of rejected mid-decode `grow` / CoW-fork attempts
    /// (preemption signal).
    pub grow_rejections: u64,
}

impl KvMemoryManager {
    /// Token-granular pool (page size 1): identical admission arithmetic
    /// to the original whole-token manager.
    pub fn new(capacity: usize) -> Self {
        Self::with_pages(capacity, 1)
    }

    /// Page-granular pool: `capacity` tokens split into pages of
    /// `page_tokens` (capacity is rounded down to whole pages).
    pub fn with_pages(capacity: usize, page_tokens: usize) -> Self {
        assert!(page_tokens >= 1, "page_tokens must be >= 1");
        let total_pages = capacity / page_tokens;
        KvMemoryManager {
            capacity: total_pages * page_tokens,
            page_tokens,
            total_pages,
            used_pages: 0,
            reserved: 0,
            seqs: BTreeMap::new(),
            prefixes: BTreeMap::new(),
            peak_reserved: 0,
            peak_used_pages: 0,
            peak_live_seqs: 0,
            rejections: 0,
            grow_rejections: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    pub fn used_pages(&self) -> usize {
        self.used_pages
    }

    pub fn free_pages(&self) -> usize {
        self.total_pages - self.used_pages
    }

    /// Pages needed to hold `tokens` resident tokens.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Logical tokens reserved.
    pub fn reserved(&self) -> usize {
        self.reserved
    }

    /// Tokens still allocatable (whole free pages).
    pub fn available(&self) -> usize {
        self.free_pages() * self.page_tokens
    }

    /// How many sequences each reserving `per_seq` tokens fit right now.
    pub fn admissible(&self, per_seq: usize) -> usize {
        if per_seq == 0 {
            return usize::MAX;
        }
        self.free_pages() / self.pages_for(per_seq)
    }

    fn bump_peaks(&mut self) {
        self.peak_reserved = self.peak_reserved.max(self.reserved);
        self.peak_used_pages = self.peak_used_pages.max(self.used_pages);
        self.peak_live_seqs = self.peak_live_seqs.max(self.seqs.len());
    }

    /// Reserve `tokens` for a sequence; fails when the wall is hit.
    pub fn reserve(&mut self, seq: SeqId, tokens: usize) -> Result<()> {
        if self.seqs.contains_key(&seq) {
            bail!("sequence {seq} already holds a reservation");
        }
        let pages = self.pages_for(tokens);
        if pages > self.free_pages() {
            self.rejections += 1;
            bail!(
                "KV memory wall: need {tokens}, only {} of {} available",
                self.available(),
                self.capacity
            );
        }
        self.used_pages += pages;
        self.reserved += tokens;
        self.seqs.insert(seq, SeqEntry { private: tokens, prefix: None });
        self.bump_peaks();
        Ok(())
    }

    /// Pages a `reserve_shared` with these arguments would charge right
    /// now: the private pages, plus the prefix pages only when the prefix
    /// is not already resident. The scheduler's headroom predicate prices
    /// admission with this.
    pub fn shared_admit_pages(
        &self,
        prefix_id: u64,
        prefix_tokens: usize,
        private_tokens: usize,
    ) -> usize {
        let prefix_pages = if self.prefixes.contains_key(&prefix_id) {
            0
        } else {
            self.pages_for(prefix_tokens)
        };
        prefix_pages + self.pages_for(private_tokens)
    }

    /// Reserve a sequence that shares a page-aligned prompt prefix.
    ///
    /// The first sharer of `prefix_id` charges `prefix_tokens` (which
    /// must be a whole number of pages) plus its private tokens; later
    /// sharers attach to the resident prefix (refcount + 1) and charge
    /// only their private tokens. Returns `Ok(true)` when the call
    /// attached to an already-resident prefix, `Ok(false)` when it paid
    /// for the prefix itself. All-or-nothing: a wall rejection leaves no
    /// trace beyond the `rejections` counter.
    pub fn reserve_shared(
        &mut self,
        seq: SeqId,
        prefix_id: u64,
        prefix_tokens: usize,
        private_tokens: usize,
    ) -> Result<bool> {
        if self.seqs.contains_key(&seq) {
            bail!("sequence {seq} already holds a reservation");
        }
        if prefix_tokens == 0 || prefix_tokens % self.page_tokens != 0 {
            bail!(
                "shared prefix must be a whole number of pages, got {} tokens at page size {}",
                prefix_tokens,
                self.page_tokens
            );
        }
        if let Some(p) = self.prefixes.get(&prefix_id) {
            if p.tokens != prefix_tokens {
                bail!(
                    "prefix {prefix_id} is resident with {} tokens, caller claims {}",
                    p.tokens,
                    prefix_tokens
                );
            }
        }
        let need = self.shared_admit_pages(prefix_id, prefix_tokens, private_tokens);
        if need > self.free_pages() {
            self.rejections += 1;
            bail!(
                "KV memory wall: shared admit needs {} pages, only {} free",
                need,
                self.free_pages()
            );
        }
        let attached = match self.prefixes.get_mut(&prefix_id) {
            Some(p) => {
                p.refs += 1;
                true
            }
            None => {
                self.prefixes
                    .insert(prefix_id, PrefixEntry { tokens: prefix_tokens, refs: 1 });
                self.used_pages += self.pages_for(prefix_tokens);
                self.reserved += prefix_tokens;
                false
            }
        };
        self.used_pages += self.pages_for(private_tokens);
        self.reserved += private_tokens;
        self.seqs
            .insert(seq, SeqEntry { private: private_tokens, prefix: Some(prefix_id) });
        self.bump_peaks();
        Ok(attached)
    }

    /// The shared prefix a live sequence reads, if any.
    pub fn seq_prefix(&self, seq: SeqId) -> Option<u64> {
        self.seqs.get(&seq).and_then(|e| e.prefix)
    }

    /// Live sharers of a prefix (0 when the prefix is not resident).
    pub fn prefix_refs(&self, prefix_id: u64) -> usize {
        self.prefixes.get(&prefix_id).map_or(0, |p| p.refs)
    }

    /// Number of resident shared prefixes.
    pub fn live_prefixes(&self) -> usize {
        self.prefixes.len()
    }

    /// Grow a live reservation to a total residency of `new_tokens`
    /// (mid-decode growth, paged admission). For a prefix-sharing
    /// sequence the total includes the shared prefix, but only the
    /// private portion past it is (re)charged. Returns `Ok(false)` —
    /// without side effects beyond the rejection counter — when the extra
    /// pages don't fit; the caller preempts and retries. `new_tokens <=
    /// current total` is a no-op success.
    pub fn grow(&mut self, seq: SeqId, new_tokens: usize) -> Result<bool> {
        let entry = match self.seqs.get(&seq) {
            Some(&e) => e,
            None => bail!("sequence {seq} holds no reservation"),
        };
        let prefix_tokens = entry
            .prefix
            .map(|pid| self.prefixes[&pid].tokens)
            .unwrap_or(0);
        let cur_total = entry.private + prefix_tokens;
        if new_tokens <= cur_total {
            return Ok(true);
        }
        let new_private = new_tokens - prefix_tokens;
        let delta_pages = self.pages_for(new_private) - self.pages_for(entry.private);
        if delta_pages > self.free_pages() {
            self.grow_rejections += 1;
            return Ok(false);
        }
        self.used_pages += delta_pages;
        self.reserved += new_tokens - cur_total;
        self.seqs
            .insert(seq, SeqEntry { private: new_private, prefix: entry.prefix });
        self.bump_peaks();
        Ok(true)
    }

    /// Copy-on-write fork: detach `seq` from its shared prefix and make
    /// its entire residency (`new_tokens`, typically the compressed
    /// retained set) private. Compression rewrites retained planes, so
    /// the engine calls this the moment a sharer's pages would be
    /// mutated. The pages freed by detaching (this sequence's private
    /// pages, plus the prefix pages when it is the last sharer) are
    /// available to the fork itself. Returns `Ok(false)` with NO state
    /// change (beyond `grow_rejections`) when the fork doesn't fit — the
    /// caller preempts a victim and retries, exactly like a denied
    /// `grow`.
    pub fn fork_to_private(&mut self, seq: SeqId, new_tokens: usize) -> Result<bool> {
        let entry = match self.seqs.get(&seq) {
            Some(&e) => e,
            None => bail!("sequence {seq} holds no reservation"),
        };
        let pid = match entry.prefix {
            Some(pid) => pid,
            None => bail!("sequence {seq} shares no prefix; nothing to fork"),
        };
        let prefix = self.prefixes[&pid];
        let last = prefix.refs == 1;
        let freed_pages = self.pages_for(entry.private)
            + if last { self.pages_for(prefix.tokens) } else { 0 };
        let need = self.pages_for(new_tokens);
        if need > self.free_pages() + freed_pages {
            self.grow_rejections += 1;
            return Ok(false);
        }
        // Detach from the prefix (free it when we were the last reader)…
        if last {
            self.prefixes.remove(&pid);
            self.used_pages -= self.pages_for(prefix.tokens);
            self.reserved -= prefix.tokens;
        } else {
            self.prefixes.get_mut(&pid).unwrap().refs -= 1;
        }
        // …and swap the private holding for the full forked residency.
        self.used_pages -= self.pages_for(entry.private);
        self.reserved -= entry.private;
        self.used_pages += need;
        self.reserved += new_tokens;
        self.seqs.insert(seq, SeqEntry { private: new_tokens, prefix: None });
        self.bump_peaks();
        Ok(true)
    }

    /// Release a sequence's reservation (finished / evicted / preempted).
    /// Returns the tokens this release removed from `reserved()` — the
    /// sequence's private tokens, plus its shared prefix's tokens when it
    /// was the last sharer.
    pub fn release(&mut self, seq: SeqId) -> Result<usize> {
        match self.seqs.remove(&seq) {
            Some(entry) => {
                self.used_pages -= self.pages_for(entry.private);
                self.reserved -= entry.private;
                let mut freed = entry.private;
                if let Some(pid) = entry.prefix {
                    let p = self.prefixes.get_mut(&pid).expect("dangling prefix ref");
                    if p.refs == 1 {
                        let tokens = p.tokens;
                        self.prefixes.remove(&pid);
                        self.used_pages -= self.pages_for(tokens);
                        self.reserved -= tokens;
                        freed += tokens;
                    } else {
                        p.refs -= 1;
                    }
                }
                Ok(freed)
            }
            None => bail!("sequence {seq} holds no reservation"),
        }
    }

    /// Shrink a live reservation (e.g. after compression established a
    /// tighter bound). Growing via `shrink` is rejected — use `grow`, so
    /// the wall check always runs. A prefix-sharing sequence cannot
    /// shrink in place: compression rewrites shared pages, so the caller
    /// must `fork_to_private` first (the scheduler routes this).
    pub fn shrink(&mut self, seq: SeqId, new_tokens: usize) -> Result<()> {
        match self.seqs.get(&seq) {
            Some(&entry) => {
                if entry.prefix.is_some() {
                    bail!(
                        "shrink({seq}) on a prefix-sharing sequence; fork_to_private first"
                    );
                }
                let cur = entry.private;
                if new_tokens > cur {
                    bail!("shrink({seq}) would grow {} -> {}", cur, new_tokens);
                }
                self.used_pages -= self.pages_for(cur) - self.pages_for(new_tokens);
                self.reserved -= cur - new_tokens;
                self.seqs.insert(seq, SeqEntry { private: new_tokens, prefix: None });
                Ok(())
            }
            None => bail!("sequence {seq} holds no reservation"),
        }
    }

    pub fn live_sequences(&self) -> usize {
        self.seqs.len()
    }

    /// Structural invariants the property tests hold at every step:
    /// token and page accounting both equal the sums over live private
    /// holdings plus each resident shared prefix ONCE, every prefix's
    /// refcount equals the number of live sequences attached to it (and
    /// is never 0 — the last release/fork frees the prefix), prefixes are
    /// whole pages, pages never exceed the pool, reserved tokens fit in
    /// the pages charged for them, and the high-water marks are
    /// monotone-consistent (at least current residency, never above the
    /// wall).
    pub fn check_invariants(&self) -> Result<()> {
        let prefix_tok: usize = self.prefixes.values().map(|p| p.tokens).sum();
        let sum: usize = self.seqs.values().map(|e| e.private).sum::<usize>() + prefix_tok;
        if self.reserved != sum {
            bail!("reserved {} != sum of live reservations {}", self.reserved, sum);
        }
        let page_sum: usize = self
            .seqs
            .values()
            .map(|e| self.pages_for(e.private))
            .sum::<usize>()
            + self
                .prefixes
                .values()
                .map(|p| self.pages_for(p.tokens))
                .sum::<usize>();
        if self.used_pages != page_sum {
            bail!("used_pages {} != sum of live page counts {}", self.used_pages, page_sum);
        }
        for (pid, p) in &self.prefixes {
            if p.refs == 0 {
                bail!("prefix {pid} is resident with refcount 0");
            }
            if p.tokens == 0 || p.tokens % self.page_tokens != 0 {
                bail!(
                    "prefix {pid} holds {} tokens, not a whole number of pages ({})",
                    p.tokens,
                    self.page_tokens
                );
            }
            let readers = self
                .seqs
                .values()
                .filter(|e| e.prefix == Some(*pid))
                .count();
            if readers != p.refs {
                bail!(
                    "prefix {pid} refcount {} != {} live sequences attached to it",
                    p.refs,
                    readers
                );
            }
        }
        for (seq, e) in &self.seqs {
            if let Some(pid) = e.prefix {
                if !self.prefixes.contains_key(&pid) {
                    bail!("sequence {seq} references missing prefix {pid}");
                }
            }
        }
        if self.used_pages > self.total_pages {
            bail!(
                "used_pages {} exceeds pool {} (wall was breached)",
                self.used_pages,
                self.total_pages
            );
        }
        if self.reserved > self.used_pages * self.page_tokens {
            bail!(
                "reserved {} tokens exceed charged pages {} x {}",
                self.reserved,
                self.used_pages,
                self.page_tokens
            );
        }
        if self.peak_reserved < self.reserved {
            bail!(
                "peak_reserved {} below current reserved {}",
                self.peak_reserved,
                self.reserved
            );
        }
        if self.peak_reserved > self.capacity {
            bail!(
                "peak_reserved {} exceeds capacity {} (wall was breached)",
                self.peak_reserved,
                self.capacity
            );
        }
        if self.peak_used_pages < self.used_pages {
            bail!(
                "peak_used_pages {} below current used_pages {}",
                self.peak_used_pages,
                self.used_pages
            );
        }
        if self.peak_used_pages > self.total_pages {
            bail!(
                "peak_used_pages {} exceeds pool {} (wall was breached)",
                self.peak_used_pages,
                self.total_pages
            );
        }
        if self.peak_live_seqs < self.seqs.len() {
            bail!(
                "peak_live_seqs {} below current live count {}",
                self.peak_live_seqs,
                self.seqs.len()
            );
        }
        Ok(())
    }

    /// Token utilization in [0, 1] (logical tokens / capacity).
    pub fn utilization(&self) -> f64 {
        if self.capacity == 0 {
            0.0
        } else {
            self.reserved as f64 / self.capacity as f64
        }
    }

    /// Page occupancy in [0, 1] (pages in use / pool pages).
    pub fn page_occupancy(&self) -> f64 {
        if self.total_pages == 0 {
            0.0
        } else {
            self.used_pages as f64 / self.total_pages as f64
        }
    }

    /// Internal fragmentation in [0, 1): fraction of charged page tokens
    /// not backing a logical reservation. 0 when nothing is resident and
    /// always 0 at page size 1.
    pub fn fragmentation(&self) -> f64 {
        let charged = self.used_pages * self.page_tokens;
        if charged == 0 {
            0.0
        } else {
            1.0 - self.reserved as f64 / charged as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn admission_widths_dense_vs_sparse() {
        // the paper's core arithmetic: 2048-token wall, dense seqs reserve
        // 208 (worst case), sparse reserve 48
        let m = KvMemoryManager::new(2048);
        assert_eq!(m.admissible(208), 9);
        assert_eq!(m.admissible(48), 42);
    }

    #[test]
    fn wall_rejects_overcommit() {
        let mut m = KvMemoryManager::new(100);
        m.reserve(1, 60).unwrap();
        assert!(m.reserve(2, 60).is_err());
        assert_eq!(m.rejections, 1);
        m.release(1).unwrap();
        m.reserve(2, 60).unwrap();
    }

    #[test]
    fn peak_live_seqs_tracks_admitted_width() {
        let mut m = KvMemoryManager::new(100);
        m.reserve(1, 10).unwrap();
        m.reserve(2, 10).unwrap();
        assert_eq!(m.peak_live_seqs, 2);
        m.release(1).unwrap();
        m.reserve(3, 10).unwrap();
        assert_eq!(m.peak_live_seqs, 2, "peak is a high-water mark");
        m.reserve(4, 10).unwrap();
        assert_eq!(m.peak_live_seqs, 3);
        m.check_invariants().unwrap();
    }

    #[test]
    fn duplicate_and_unknown_rejected() {
        let mut m = KvMemoryManager::new(100);
        m.reserve(1, 10).unwrap();
        assert!(m.reserve(1, 10).is_err());
        assert!(m.release(99).is_err());
    }

    #[test]
    fn shrink_only_shrinks() {
        let mut m = KvMemoryManager::new(100);
        m.reserve(1, 50).unwrap();
        m.shrink(1, 30).unwrap();
        assert_eq!(m.reserved(), 30);
        assert!(m.shrink(1, 40).is_err());
    }

    #[test]
    fn pages_round_up_and_grow_page_by_page() {
        let mut m = KvMemoryManager::with_pages(64, 16);
        assert_eq!(m.total_pages(), 4);
        m.reserve(1, 10).unwrap(); // 1 page
        assert_eq!(m.used_pages(), 1);
        assert_eq!(m.available(), 48);
        // growing within the page costs nothing
        assert!(m.grow(1, 16).unwrap());
        assert_eq!(m.used_pages(), 1);
        // crossing the boundary takes a fresh page
        assert!(m.grow(1, 17).unwrap());
        assert_eq!(m.used_pages(), 2);
        // fragmentation: 17 tokens on 32 charged
        assert!((m.fragmentation() - (1.0 - 17.0 / 32.0)).abs() < 1e-9);
        // a second sequence can take the remaining 2 pages but not 3
        m.reserve(2, 32).unwrap();
        assert!(!m.grow(2, 33).unwrap());
        assert_eq!(m.grow_rejections, 1);
        // shrink frees whole pages only
        m.shrink(1, 16).unwrap();
        assert_eq!(m.used_pages(), 3);
        assert!(m.grow(2, 48).unwrap());
        m.check_invariants().unwrap();
        assert_eq!(m.release(1).unwrap(), 16);
        assert_eq!(m.release(2).unwrap(), 48);
        assert_eq!(m.used_pages(), 0);
        assert_eq!(m.reserved(), 0);
    }

    #[test]
    fn grow_on_unknown_sequence_is_an_error() {
        let mut m = KvMemoryManager::with_pages(64, 8);
        assert!(m.grow(42, 10).is_err());
    }

    #[test]
    fn capacity_normalized_to_whole_pages() {
        let m = KvMemoryManager::with_pages(100, 16);
        assert_eq!(m.total_pages(), 6);
        assert_eq!(m.capacity(), 96);
        assert_eq!(m.admissible(17), 3); // 2 pages each, 6 in the pool
    }

    #[test]
    fn shared_prefix_charges_once_and_refcounts() {
        let mut m = KvMemoryManager::with_pages(64, 8); // 8 pages
        // first sharer pays the 2-page prefix + 1 private page
        assert!(!m.reserve_shared(1, 7, 16, 4).unwrap());
        assert_eq!(m.used_pages(), 3);
        assert_eq!(m.reserved(), 20);
        assert_eq!(m.prefix_refs(7), 1);
        // second sharer attaches: only its private page is charged
        assert!(m.reserve_shared(2, 7, 16, 4).unwrap());
        assert_eq!(m.used_pages(), 4);
        assert_eq!(m.reserved(), 24);
        assert_eq!(m.prefix_refs(7), 2);
        assert_eq!(m.seq_prefix(2), Some(7));
        m.check_invariants().unwrap();
        // shared admit pricing: resident prefix costs nothing, a fresh
        // prefix costs its pages
        assert_eq!(m.shared_admit_pages(7, 16, 4), 1);
        assert_eq!(m.shared_admit_pages(8, 16, 4), 3);
        // releasing a non-last sharer keeps the prefix resident
        assert_eq!(m.release(1).unwrap(), 4);
        assert_eq!(m.used_pages(), 3);
        assert_eq!(m.prefix_refs(7), 1);
        m.check_invariants().unwrap();
        // the last sharer's release frees the prefix pages too
        assert_eq!(m.release(2).unwrap(), 20);
        assert_eq!(m.used_pages(), 0);
        assert_eq!(m.reserved(), 0);
        assert_eq!(m.live_prefixes(), 0);
        m.check_invariants().unwrap();
    }

    #[test]
    fn shared_prefix_validates_shape() {
        let mut m = KvMemoryManager::with_pages(64, 8);
        // prefix must be whole pages and non-empty
        assert!(m.reserve_shared(1, 7, 12, 4).is_err());
        assert!(m.reserve_shared(1, 7, 0, 4).is_err());
        m.reserve_shared(1, 7, 16, 4).unwrap();
        // token-count mismatch against the resident prefix is a bug
        assert!(m.reserve_shared(2, 7, 24, 4).is_err());
        // duplicate sequence id is rejected before any accounting
        assert!(m.reserve_shared(1, 7, 16, 4).is_err());
        // in-place shrink on a sharer is refused (CoW fork required)
        assert!(m.shrink(1, 2).is_err());
        m.check_invariants().unwrap();
    }

    #[test]
    fn fork_to_private_detaches_and_cow_copies() {
        let mut m = KvMemoryManager::with_pages(64, 8); // 8 pages
        m.reserve_shared(1, 7, 16, 4).unwrap();
        m.reserve_shared(2, 7, 16, 4).unwrap();
        assert_eq!(m.used_pages(), 4);
        // fork seq 1 to a 24-token private residency (the CoW copy):
        // its 1 private page frees, 3 fresh pages charge, prefix stays
        assert!(m.fork_to_private(1, 24).unwrap());
        assert_eq!(m.seq_prefix(1), None);
        assert_eq!(m.prefix_refs(7), 1);
        assert_eq!(m.used_pages(), 6); // 3 (seq1) + 2 (prefix) + 1 (seq2)
        assert_eq!(m.reserved(), 44); // 24 + 16 + 4
        m.check_invariants().unwrap();
        // forking the LAST sharer frees the prefix pages into the fork
        assert!(m.fork_to_private(2, 24).unwrap());
        assert_eq!(m.live_prefixes(), 0);
        assert_eq!(m.used_pages(), 6); // 3 + 3
        assert_eq!(m.reserved(), 48);
        m.check_invariants().unwrap();
        // forked sequences release like plain ones
        assert_eq!(m.release(1).unwrap(), 24);
        assert_eq!(m.release(2).unwrap(), 24);
        assert_eq!(m.used_pages(), 0);
    }

    #[test]
    fn denied_fork_leaves_no_trace() {
        let mut m = KvMemoryManager::with_pages(40, 8); // 5 pages
        m.reserve_shared(1, 7, 16, 4).unwrap(); // 3 pages
        m.reserve_shared(2, 7, 16, 4).unwrap(); // +1 page
        m.reserve(3, 8).unwrap(); // +1 page; pool full
        // seq 2 forking to 32 tokens needs 4 pages; free(0) + its own
        // private page = 1 available -> denied, untouched
        let before = (m.used_pages(), m.reserved(), m.prefix_refs(7));
        assert!(!m.fork_to_private(2, 32).unwrap());
        assert_eq!(m.grow_rejections, 1);
        assert_eq!((m.used_pages(), m.reserved(), m.prefix_refs(7)), before);
        assert_eq!(m.seq_prefix(2), Some(7));
        m.check_invariants().unwrap();
        // fork on a non-sharing or unknown sequence is an error
        assert!(m.fork_to_private(3, 8).is_err());
        assert!(m.fork_to_private(99, 8).is_err());
    }

    #[test]
    fn grow_charges_only_private_pages_for_sharers() {
        let mut m = KvMemoryManager::with_pages(64, 8);
        m.reserve_shared(1, 7, 16, 4).unwrap(); // 2 prefix pages + 1 private
        // total residency 20 -> 24 stays inside the private page
        assert!(m.grow(1, 24).unwrap());
        assert_eq!(m.used_pages(), 3);
        // 25 crosses into a second private page
        assert!(m.grow(1, 25).unwrap());
        assert_eq!(m.used_pages(), 4);
        assert_eq!(m.reserved(), 25);
        m.check_invariants().unwrap();
    }

    #[test]
    fn prop_accounting_conserves() {
        propcheck::quick("kv-conservation", |rng, size| {
            let cap = 64 + size * 8;
            let mut m = KvMemoryManager::new(cap);
            let mut live: Vec<(SeqId, usize)> = vec![];
            let mut next_id = 0u64;
            for _ in 0..200 {
                if rng.chance(0.6) || live.is_empty() {
                    let want = 1 + rng.below(cap / 4 + 1);
                    next_id += 1;
                    if m.reserve(next_id, want).is_ok() {
                        live.push((next_id, want));
                    }
                } else {
                    let k = rng.below(live.len());
                    let (id, _) = live.swap_remove(k);
                    m.release(id).map_err(|e| e.to_string())?;
                }
                let expect: usize = live.iter().map(|(_, t)| t).sum();
                if m.reserved() != expect {
                    return Err(format!("reserved {} != sum {}", m.reserved(), expect));
                }
                if m.reserved() > cap {
                    return Err("over capacity".into());
                }
                if m.live_sequences() != live.len() {
                    return Err("live count mismatch".into());
                }
                m.check_invariants().map_err(|e| e.to_string())?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_paged_pool_conserves_under_grow_shrink() {
        // Random reserve/reserve_shared/grow/fork/shrink/release
        // interleavings at random page sizes, checked against a shadow
        // model: pages and tokens both conserve with every shared prefix
        // counted ONCE, refcounts match the shadow sharer counts, a
        // denied grow or fork leaves no trace, releasing the last sharer
        // frees the prefix, and a full drain always reaches the empty
        // pool.
        propcheck::quick("kv-paged-conservation", |rng, size| {
            let page = 1 + rng.below(16);
            let pool_pages = 4 + rng.below(16 + size);
            let cap = page * pool_pages;
            let mut m = KvMemoryManager::with_pages(cap, page);
            // shadow: (id, private tokens, Some((prefix id, prefix tokens)))
            let mut live: Vec<(SeqId, usize, Option<(u64, usize)>)> = vec![];
            let mut next_id = 0u64;
            // a small universe of prefix identities with fixed shapes
            let prefix_shape = |pid: u64| page * (1 + pid as usize % 3);
            for _ in 0..200 {
                match if live.is_empty() { rng.below(2) * 4 } else { rng.below(6) } {
                    0 => {
                        next_id += 1;
                        let want = 1 + rng.below(cap / 2 + 1);
                        let fits = m.pages_for(want) <= m.free_pages();
                        let got = m.reserve(next_id, want).is_ok();
                        if got != fits {
                            return Err(format!("reserve({want}) = {got}, fits = {fits}"));
                        }
                        if got {
                            live.push((next_id, want, None));
                        }
                    }
                    1 => {
                        let k = rng.below(live.len());
                        let (id, cur, pfx) = live[k];
                        let ptoks = pfx.map(|(_, t)| t).unwrap_or(0);
                        let target = ptoks + cur + rng.below(2 * page + 1);
                        let delta = m.pages_for(target - ptoks) - m.pages_for(cur);
                        let fits = delta <= m.free_pages();
                        let grown = m.grow(id, target).map_err(|e| e.to_string())?;
                        if grown != fits {
                            return Err(format!("grow(->{target}) = {grown}, fits = {fits}"));
                        }
                        if grown {
                            live[k].1 = target - ptoks;
                        }
                    }
                    2 => {
                        let k = rng.below(live.len());
                        let (id, cur, pfx) = live[k];
                        if pfx.is_some() {
                            // sharers may not shrink in place
                            if m.shrink(id, 0).is_ok() {
                                return Err("shrink succeeded on a sharer".into());
                            }
                        } else {
                            let target = rng.below(cur + 1);
                            m.shrink(id, target).map_err(|e| e.to_string())?;
                            live[k].1 = target;
                        }
                    }
                    3 => {
                        let k = rng.below(live.len());
                        let (id, toks, pfx) = live.swap_remove(k);
                        let last = pfx.map_or(false, |(pid, _)| {
                            !live.iter().any(|(_, _, p)| p.map(|(q, _)| q) == Some(pid))
                        });
                        let expect = toks + if last { pfx.unwrap().1 } else { 0 };
                        let freed = m.release(id).map_err(|e| e.to_string())?;
                        if freed != expect {
                            return Err(format!("released {freed}, expected {expect}"));
                        }
                    }
                    4 => {
                        // shared admission against one of 3 prefix ids
                        let pid = rng.below(3) as u64;
                        let ptoks = prefix_shape(pid);
                        let private = rng.below(2 * page + 1);
                        next_id += 1;
                        let need = m.shared_admit_pages(pid, ptoks, private);
                        let fits = need <= m.free_pages();
                        let got = m.reserve_shared(next_id, pid, ptoks, private).is_ok();
                        if got != fits {
                            return Err(format!(
                                "reserve_shared(pid {pid}) = {got}, fits = {fits}"
                            ));
                        }
                        if got {
                            live.push((next_id, private, Some((pid, ptoks))));
                        }
                    }
                    _ => {
                        // CoW fork of a random sharer (no-op pick if none)
                        let sharers: Vec<usize> = (0..live.len())
                            .filter(|&k| live[k].2.is_some())
                            .collect();
                        if let Some(&k) = sharers.get(rng.below(sharers.len().max(1))) {
                            let (id, cur, pfx) = live[k];
                            let (pid, ptoks) = pfx.unwrap();
                            let target = rng.below(ptoks + cur + page) + 1;
                            let last = live
                                .iter()
                                .filter(|(_, _, p)| p.map(|(q, _)| q) == Some(pid))
                                .count()
                                == 1;
                            let avail = m.free_pages()
                                + m.pages_for(cur)
                                + if last { m.pages_for(ptoks) } else { 0 };
                            let fits = m.pages_for(target) <= avail;
                            let forked =
                                m.fork_to_private(id, target).map_err(|e| e.to_string())?;
                            if forked != fits {
                                return Err(format!(
                                    "fork(->{target}) = {forked}, fits = {fits}"
                                ));
                            }
                            if forked {
                                live[k] = (id, target, None);
                            }
                        }
                    }
                }
                // shadow-model totals: every distinct live prefix once
                let mut shadow_prefixes: BTreeMap<u64, usize> = BTreeMap::new();
                for (_, _, p) in &live {
                    if let Some((pid, t)) = p {
                        shadow_prefixes.insert(*pid, *t);
                    }
                }
                let tok_sum: usize = live.iter().map(|(_, t, _)| t).sum::<usize>()
                    + shadow_prefixes.values().sum::<usize>();
                let page_sum: usize =
                    live.iter().map(|(_, t, _)| m.pages_for(*t)).sum::<usize>()
                        + shadow_prefixes.values().map(|&t| m.pages_for(t)).sum::<usize>();
                if m.reserved() != tok_sum || m.used_pages() != page_sum {
                    return Err(format!(
                        "pool out of sync: {}/{} vs {}/{}",
                        m.reserved(),
                        m.used_pages(),
                        tok_sum,
                        page_sum
                    ));
                }
                if m.live_prefixes() != shadow_prefixes.len() {
                    return Err(format!(
                        "{} resident prefixes, shadow has {}",
                        m.live_prefixes(),
                        shadow_prefixes.len()
                    ));
                }
                for (&pid, _) in &shadow_prefixes {
                    let refs = live
                        .iter()
                        .filter(|(_, _, p)| p.map(|(q, _)| q) == Some(pid))
                        .count();
                    if m.prefix_refs(pid) != refs {
                        return Err(format!(
                            "prefix {pid} refcount {} != shadow {}",
                            m.prefix_refs(pid),
                            refs
                        ));
                    }
                }
                m.check_invariants().map_err(|e| e.to_string())?;
            }
            // a full drain always reaches the empty pool
            for (id, _, _) in live.drain(..) {
                m.release(id).map_err(|e| e.to_string())?;
            }
            if m.used_pages() != 0 || m.reserved() != 0 || m.live_prefixes() != 0 {
                return Err("drain left residue".into());
            }
            Ok(())
        });
    }
}
