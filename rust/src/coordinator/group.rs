//! GRPO group-relative advantages (paper Eq. 10).
//!
//! For each prompt, G responses are sampled and each reward is normalized
//! against the group's mean and standard deviation:
//!   Â_i = (r_i - mean(r)) / std(r)
//! Degenerate groups (all rewards equal, std = 0) yield zero advantages —
//! no gradient signal, exactly as in GRPO implementations.

/// Rewards for one group -> advantages.
pub fn group_advantages(rewards: &[f64]) -> Vec<f64> {
    let g = rewards.len();
    if g == 0 {
        return vec![];
    }
    let mean = rewards.iter().sum::<f64>() / g as f64;
    let var = rewards.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>() / g as f64;
    let std = var.sqrt();
    if std < 1e-8 {
        return vec![0.0; g];
    }
    rewards.iter().map(|r| (r - mean) / std).collect()
}

/// Advantages for a flat batch laid out as consecutive groups of size `g`.
pub fn batched_group_advantages(rewards: &[f64], g: usize) -> Vec<f64> {
    assert!(g > 0 && rewards.len() % g == 0, "batch not divisible into groups");
    rewards
        .chunks(g)
        .flat_map(|grp| group_advantages(grp))
        .collect()
}

/// Summary statistics of one rollout batch's rewards.
#[derive(Debug, Clone, Copy, Default)]
pub struct RewardSummary {
    pub mean: f64,
    /// Fraction of groups with non-zero advantage signal (not all-same).
    pub informative_groups: f64,
}

pub fn summarize(rewards: &[f64], g: usize) -> RewardSummary {
    if rewards.is_empty() {
        return RewardSummary::default();
    }
    let mean = rewards.iter().sum::<f64>() / rewards.len() as f64;
    let groups = rewards.chunks(g);
    let n_groups = rewards.len().div_ceil(g);
    let informative = groups
        .filter(|grp| {
            let first = grp[0];
            grp.iter().any(|&r| (r - first).abs() > 1e-9)
        })
        .count();
    RewardSummary { mean, informative_groups: informative as f64 / n_groups as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn binary_rewards_normalize() {
        // 2 successes of 4: mean 0.5, std 0.5 -> advantages ±1
        let adv = group_advantages(&[1.0, 0.0, 1.0, 0.0]);
        assert!((adv[0] - 1.0).abs() < 1e-9);
        assert!((adv[1] + 1.0).abs() < 1e-9);
    }

    #[test]
    fn degenerate_group_zero() {
        assert_eq!(group_advantages(&[1.0; 8]), vec![0.0; 8]);
        assert_eq!(group_advantages(&[0.0; 8]), vec![0.0; 8]);
    }

    #[test]
    fn prop_advantages_zero_mean_unit_std() {
        propcheck::quick("adv-normalized", |rng, size| {
            let g = 2 + size % 14;
            let rewards: Vec<f64> = (0..g).map(|_| rng.below(2) as f64).collect();
            let adv = group_advantages(&rewards);
            let first = rewards[0];
            if rewards.iter().all(|&r| (r - first).abs() < 1e-12) {
                if adv.iter().any(|&a| a != 0.0) {
                    return Err("degenerate group produced signal".into());
                }
                return Ok(());
            }
            let mean: f64 = adv.iter().sum::<f64>() / g as f64;
            let var: f64 = adv.iter().map(|a| (a - mean) * (a - mean)).sum::<f64>() / g as f64;
            if mean.abs() > 1e-9 {
                return Err(format!("mean {mean}"));
            }
            if (var - 1.0).abs() > 1e-6 {
                return Err(format!("var {var}"));
            }
            Ok(())
        });
    }

    #[test]
    fn batched_layout() {
        let adv = batched_group_advantages(&[1.0, 0.0, 0.0, 0.0, 1.0, 1.0], 2);
        assert_eq!(adv.len(), 6);
        assert!(adv[0] > 0.0 && adv[1] < 0.0);
        assert_eq!(&adv[4..], &[0.0, 0.0]);
    }

    #[test]
    fn summary_counts_informative() {
        let s = summarize(&[1.0, 0.0, 1.0, 1.0], 2);
        assert!((s.mean - 0.75).abs() < 1e-9);
        assert!((s.informative_groups - 0.5).abs() < 1e-9);
    }
}
