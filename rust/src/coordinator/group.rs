//! GRPO group-relative advantages (paper Eq. 10).
//!
//! For each prompt, G responses are sampled and each reward is normalized
//! against the group's mean and standard deviation:
//!   Â_i = (r_i - mean(r)) / std(r)
//! Degenerate groups (all rewards equal, std = 0) yield zero advantages —
//! no gradient signal, exactly as in GRPO implementations.
//!
//! Flat-batch layout contract (one rule for the whole module): a batch is
//! consecutive groups of exactly `g` rewards. An empty batch is fine
//! (empty output); `g = 0` with a non-empty batch, or a trailing partial
//! group, is a caller bug reported as `Err` — never a panic
//! (`chunks(0)`), and never silently averaged over a miscounted group
//! total (`div_ceil` on a partial tail).

use anyhow::{bail, Result};

/// The shared layout check: number of groups in a flat batch of `n`
/// rewards with group size `g`.
fn check_groups(n: usize, g: usize) -> Result<usize> {
    if n == 0 {
        return Ok(0);
    }
    if g == 0 {
        bail!("group size 0 with {n} rewards");
    }
    if n % g != 0 {
        bail!("batch of {n} rewards has a trailing partial group (group size {g})");
    }
    Ok(n / g)
}

/// Rewards for one group -> advantages.
pub fn group_advantages(rewards: &[f64]) -> Vec<f64> {
    let g = rewards.len();
    if g == 0 {
        return vec![];
    }
    let mean = rewards.iter().sum::<f64>() / g as f64;
    let var = rewards.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>() / g as f64;
    let std = var.sqrt();
    if std < 1e-8 {
        return vec![0.0; g];
    }
    rewards.iter().map(|r| (r - mean) / std).collect()
}

/// Advantages for a flat batch laid out as consecutive groups of size `g`
/// (see the module-level layout contract).
pub fn batched_group_advantages(rewards: &[f64], g: usize) -> Result<Vec<f64>> {
    if check_groups(rewards.len(), g)? == 0 {
        return Ok(vec![]);
    }
    Ok(rewards.chunks(g).flat_map(group_advantages).collect())
}

/// Summary statistics of one rollout batch's rewards.
#[derive(Debug, Clone, Copy, Default)]
pub struct RewardSummary {
    pub mean: f64,
    /// Fraction of groups with non-zero advantage signal (not all-same).
    pub informative_groups: f64,
}

/// Summarize a flat batch under the same layout contract as
/// [`batched_group_advantages`]: the two can never disagree on what a
/// valid batch is (this one used to panic on `g = 0` via `chunks(0)` and
/// to miscount a trailing partial group via `div_ceil`).
pub fn summarize(rewards: &[f64], g: usize) -> Result<RewardSummary> {
    let n_groups = check_groups(rewards.len(), g)?;
    if n_groups == 0 {
        return Ok(RewardSummary::default());
    }
    let mean = rewards.iter().sum::<f64>() / rewards.len() as f64;
    let informative = rewards
        .chunks(g)
        .filter(|grp| {
            let first = grp[0];
            grp.iter().any(|&r| (r - first).abs() > 1e-9)
        })
        .count();
    Ok(RewardSummary { mean, informative_groups: informative as f64 / n_groups as f64 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn binary_rewards_normalize() {
        // 2 successes of 4: mean 0.5, std 0.5 -> advantages ±1
        let adv = group_advantages(&[1.0, 0.0, 1.0, 0.0]);
        assert!((adv[0] - 1.0).abs() < 1e-9);
        assert!((adv[1] + 1.0).abs() < 1e-9);
    }

    #[test]
    fn degenerate_group_zero() {
        assert_eq!(group_advantages(&[1.0; 8]), vec![0.0; 8]);
        assert_eq!(group_advantages(&[0.0; 8]), vec![0.0; 8]);
    }

    #[test]
    fn prop_advantages_zero_mean_unit_std() {
        propcheck::quick("adv-normalized", |rng, size| {
            let g = 2 + size % 14;
            let rewards: Vec<f64> = (0..g).map(|_| rng.below(2) as f64).collect();
            let adv = group_advantages(&rewards);
            let first = rewards[0];
            if rewards.iter().all(|&r| (r - first).abs() < 1e-12) {
                if adv.iter().any(|&a| a != 0.0) {
                    return Err("degenerate group produced signal".into());
                }
                return Ok(());
            }
            let mean: f64 = adv.iter().sum::<f64>() / g as f64;
            let var: f64 = adv.iter().map(|a| (a - mean) * (a - mean)).sum::<f64>() / g as f64;
            if mean.abs() > 1e-9 {
                return Err(format!("mean {mean}"));
            }
            if (var - 1.0).abs() > 1e-6 {
                return Err(format!("var {var}"));
            }
            Ok(())
        });
    }

    #[test]
    fn batched_layout() {
        let adv = batched_group_advantages(&[1.0, 0.0, 0.0, 0.0, 1.0, 1.0], 2).unwrap();
        assert_eq!(adv.len(), 6);
        assert!(adv[0] > 0.0 && adv[1] < 0.0);
        assert_eq!(&adv[4..], &[0.0, 0.0]);
    }

    #[test]
    fn summary_counts_informative() {
        let s = summarize(&[1.0, 0.0, 1.0, 1.0], 2).unwrap();
        assert!((s.mean - 0.75).abs() < 1e-9);
        assert!((s.informative_groups - 0.5).abs() < 1e-9);
    }

    #[test]
    fn zero_group_size_is_an_error_not_a_panic() {
        // summarize used to reach `chunks(0)` here and panic
        assert!(summarize(&[1.0, 0.0], 0).is_err());
        assert!(batched_group_advantages(&[1.0, 0.0], 0).is_err());
    }

    #[test]
    fn partial_trailing_group_rejected_by_both() {
        // one contract: summarize used to average a 5-reward batch over
        // div_ceil(5, 2) = 3 "groups" while batched_group_advantages
        // asserted — now both report the layout bug the same way
        assert!(summarize(&[1.0; 5], 2).is_err());
        assert!(batched_group_advantages(&[1.0; 5], 2).is_err());
    }

    #[test]
    fn empty_batch_is_fine_for_any_group_size() {
        for g in [0usize, 1, 7] {
            let s = summarize(&[], g).unwrap();
            assert_eq!(s.mean, 0.0);
            assert_eq!(s.informative_groups, 0.0);
            assert!(batched_group_advantages(&[], g).unwrap().is_empty());
        }
    }
}
