//! Memory-aware rollout scheduler.
//!
//! Packs pending prompts into the decode batch subject to the KV memory
//! wall. Two *admission policies* decide what a sequence is charged
//! (`config::AdmissionPolicy`):
//!
//! * **Worst-case** (seed behavior, default): every admitted sequence
//!   reserves its worst-case residency up front (dense: `max_seq`; sparse:
//!   `budget+buffer`). Admission can never fail mid-decode, but width is
//!   `capacity / worst_case` — exactly where dense rollouts lose
//!   throughput (paper §1: "rollout batch sizes must be constrained" to
//!   dodge long-tail OOM).
//! * **Paged**: a sequence is admitted with only the pages its prompt
//!   needs, `grow`s page-by-page as decode writes land, and shrinks to the
//!   compressed residency after each compression event (`compressed`).
//!   Width tracks *actual* residency — the admissible-batch gain the paper
//!   attributes to sparse caches applies to both modes. The cost: a `grow`
//!   can hit the wall mid-decode; the continuous engine resolves it by
//!   preempting the lowest-progress sequence (`preempt`) and requeueing
//!   it, so the wall is never breached and a drain is always reachable.
//!
//! Two admission granularities serve the two rollout engines:
//!
//! * **Chunk-level** (`next_chunk` / `finish_chunk`, static engine): a
//!   whole chunk reserves together and releases together when the slowest
//!   sequence in it finishes. Under paged admission the chunk cannot be
//!   preempted, so each member reserves its *predicted* residency
//!   (`min(prompt + max_response, worst_case)`, page-rounded) — still a
//!   safe bound, but per-sequence-tight, so chunks are sized by predicted
//!   paged residency instead of the global worst case.
//! * **Sequence-level** (`try_admit` / `grow` / `release_seq`, continuous
//!   engine): each sequence reserves on admission and releases the moment
//!   it finishes, letting the engine refill the freed slot immediately.
//!   The closed-form `predicted_decode_steps` models the worst-case
//!   schedule (greedy earliest-free-slot, queue order) step-exactly; under
//!   paged admission the effective width is data-dependent, so the closed
//!   forms bound it via `predicted_decode_steps_with` (see `width_paged`).

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::config::{AdmissionOrder, AdmissionPolicy, PrefixSharing};
use crate::runtime::Manifest;

use super::kv_manager::{KvMemoryManager, SeqId};

/// The dynamic engines' pending-task queue with an order-aware pop.
///
/// Fifo keeps a plain deque. Shortest-first keeps a sorted index — a
/// `BTreeSet` keyed by `(cost, stamp)` — replacing the old
/// scan-the-whole-queue-per-pick (O(n²) over a full drain; the PR-4
/// follow-up). Stamps encode deque order: `push_back` stamps increase,
/// `push_front` stamps decrease, so the set's minimum `(cost, stamp)` is
/// exactly the FIRST queue element with minimal cost — the stable
/// first-min tie-break `Scheduler::pick_next` specifies. `pick_next`
/// stays as the executable reference semantics; the propcheck replays
/// random push-front/pop traffic against it to pin the tie-break.
///
/// Costs are per task position and fixed for the queue's lifetime
/// (`Scheduler::admission_cost` of every task, computed once per
/// rollout), so requeued (preempted) tasks re-enter with their original
/// cost — only their stamp (queue position) changes.
#[derive(Debug)]
pub struct AdmissionQueue {
    order: AdmissionOrder,
    cost: Vec<usize>,
    fifo: VecDeque<usize>,
    sorted: BTreeSet<(usize, i64, usize)>,
    front_stamp: i64,
    back_stamp: i64,
}

impl AdmissionQueue {
    /// Build a queue holding task positions `0..cost.len()` in order,
    /// popped according to `order` over the per-position `cost` vector.
    pub fn new(order: AdmissionOrder, cost: Vec<usize>) -> AdmissionQueue {
        let n = cost.len();
        let mut q = AdmissionQueue {
            order,
            cost,
            fifo: VecDeque::with_capacity(n),
            sorted: BTreeSet::new(),
            front_stamp: -1,
            back_stamp: 0,
        };
        for pos in 0..n {
            q.push_back(pos);
        }
        q
    }

    pub fn len(&self) -> usize {
        match self.order {
            AdmissionOrder::Fifo => self.fifo.len(),
            AdmissionOrder::ShortestFirst => self.sorted.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn cost_of(&self, pos: usize) -> usize {
        self.cost.get(pos).copied().unwrap_or(usize::MAX)
    }

    fn push_back(&mut self, pos: usize) {
        match self.order {
            AdmissionOrder::Fifo => self.fifo.push_back(pos),
            AdmissionOrder::ShortestFirst => {
                let stamp = self.back_stamp;
                self.back_stamp += 1;
                self.sorted.insert((self.cost_of(pos), stamp, pos));
            }
        }
    }

    /// Requeue a task at the queue head (preemption path): among equal
    /// costs it now wins the next pick, exactly like the old
    /// `VecDeque::push_front` + first-min scan.
    pub fn push_front(&mut self, pos: usize) {
        match self.order {
            AdmissionOrder::Fifo => self.fifo.push_front(pos),
            AdmissionOrder::ShortestFirst => {
                let stamp = self.front_stamp;
                self.front_stamp -= 1;
                self.sorted.insert((self.cost_of(pos), stamp, pos));
            }
        }
    }

    /// The task position the engine should try to admit next (`None` iff
    /// empty); `pop` removes exactly this element.
    pub fn peek(&self) -> Option<usize> {
        match self.order {
            AdmissionOrder::Fifo => self.fifo.front().copied(),
            AdmissionOrder::ShortestFirst => self.sorted.first().map(|&(_, _, pos)| pos),
        }
    }

    /// Remove and return the element `peek` reported.
    pub fn pop(&mut self) -> Option<usize> {
        match self.order {
            AdmissionOrder::Fifo => self.fifo.pop_front(),
            AdmissionOrder::ShortestFirst => self.sorted.pop_first().map(|(_, _, pos)| pos),
        }
    }
}

/// One scheduled chunk: which pending items occupy which decode slots.
#[derive(Debug, Clone)]
pub struct Chunk {
    /// Indices into the pending queue, one per occupied slot (slot i of
    /// the decode batch holds pending[task_of_slot[i]]).
    pub items: Vec<usize>,
    /// Worst-case reservation bound the chunk was admitted under (paged
    /// chunks reserve per-member predicted residency instead).
    pub reserve_per_seq: usize,
}

/// Scheduling statistics for the utilization benches.
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedulerStats {
    pub chunks: usize,
    pub scheduled_seqs: usize,
    /// Σ over chunks of occupied slots / R (decode-slot utilization).
    pub slot_utilization_sum: f64,
    /// Σ over chunks of reserved KV / capacity at admission time.
    pub kv_utilization_sum: f64,
    /// Sequence-level admissions (continuous engine).
    pub seq_admissions: usize,
    /// Sequence-level releases (continuous engine; includes preemptions).
    pub seq_releases: usize,
    /// Admission attempts refused by the memory wall (continuous engine:
    /// a freed slot had to idle because no KV could be reserved).
    pub admit_stalls: usize,
    /// Mid-decode grow attempts refused by the wall (paged admission;
    /// includes denied copy-on-write forks under prefix sharing).
    pub grow_stalls: usize,
    /// Sequences preempted and requeued to resolve a grow stall.
    pub preemptions: usize,
    /// Sequences released by task quarantine (`fault-policy = quarantine`
    /// after a backend call exhausted its retry budget). Conservation over
    /// a full drain: `seq_admissions == finished + preemptions +
    /// quarantined` — a quarantined task's pages and slot return to the
    /// pool exactly like a preemption's, it just never reruns.
    pub quarantined: usize,
    /// Admissions that attached to an already-resident shared prompt
    /// prefix instead of paying for it (prefix sharing).
    pub shared_admissions: usize,
    /// Copy-on-write forks: sharers detached from their prefix at their
    /// first compression event (prefix sharing).
    pub cow_forks: usize,
}

impl SchedulerStats {
    pub fn mean_slot_utilization(&self) -> f64 {
        if self.chunks == 0 {
            0.0
        } else {
            self.slot_utilization_sum / self.chunks as f64
        }
    }

    pub fn mean_kv_utilization(&self) -> f64 {
        if self.chunks == 0 {
            0.0
        } else {
            self.kv_utilization_sum / self.chunks as f64
        }
    }

    /// Sequences currently admitted and not yet released.
    pub fn live_seqs(&self) -> usize {
        self.seq_admissions - self.seq_releases
    }
}

/// Plans admissions over a queue of pending sequences.
pub struct Scheduler {
    /// Decode slot width (from the manifest).
    pub slots: usize,
    /// Worst-case KV tokens one sequence may hold (dense: `max_seq`;
    /// sparse: `budget+buffer`). Under paged admission this is the growth
    /// *ceiling*, not the admission charge.
    pub reserve_per_seq: usize,
    /// What a sequence is charged at admission (see module docs).
    pub admission: AdmissionPolicy,
    /// Free pages a paged admission must leave behind while other
    /// sequences are live (`kv-admit-headroom-pages`; default 1 — the
    /// original hard-coded behavior). Admitting flush against the wall
    /// (headroom 0) guarantees the next grow stalls and the newcomer is
    /// immediately preempted — a pure admit/preempt thrash cycle under
    /// pressure; larger headroom trades admitted width for fewer
    /// preemptions. Ignored by worst-case admission, and bypassed when
    /// the pool is empty (progress guarantee).
    pub admit_headroom_pages: usize,
    /// Order pending tasks are admitted in (`admission-order`): `fifo`
    /// (seed behavior — the queue head is the only candidate) or
    /// `shortest-first` (makespan-aware — smallest predicted residency
    /// first, so a big task never head-of-line-blocks a small admissible
    /// one). Pure scheduling: per-task RNG keeps tokens order-invariant.
    pub order: AdmissionOrder,
    /// Prompt-prefix KV sharing (`prefix-sharing`): `Group` lets
    /// sequences with identical prompts (a GRPO group / eval's K samples)
    /// share their page-aligned prompt prefix through the refcounted
    /// pool, charging it once. Accounting only changes under paged
    /// admission (worst-case prices per sequence by definition). Default
    /// off — the seed accounting, bit-exact.
    pub sharing: PrefixSharing,
    pub stats: SchedulerStats,
    /// Prompt identity -> prefix id for the refcounted pool. Keyed by the
    /// exact prompt token run; ids are stable for the scheduler's
    /// lifetime, and a dead prefix (all sharers released) is simply
    /// re-charged fresh on its next use (`shared_admit_pages` checks
    /// residency, not this registry).
    prefix_ids: BTreeMap<Vec<i32>, u64>,
    next_prefix_id: u64,
}

impl Scheduler {
    /// `sparse` selects the reservation bound (the whole memory-wall
    /// story is this one line: capacity-bounded vs length-bounded).
    /// Defaults to worst-case admission — the seed behavior.
    pub fn new(manifest: &Manifest, sparse: bool) -> Self {
        let reserve = if sparse {
            manifest.shapes.sparse_capacity
        } else {
            manifest.config.max_seq
        };
        Self::worst_case(manifest.shapes.decode_batch, reserve)
    }

    /// Bare worst-case scheduler (tests/benches construct these directly).
    pub fn worst_case(slots: usize, reserve_per_seq: usize) -> Self {
        Scheduler {
            slots,
            reserve_per_seq,
            admission: AdmissionPolicy::WorstCase,
            admit_headroom_pages: 1,
            order: AdmissionOrder::Fifo,
            sharing: PrefixSharing::Off,
            stats: SchedulerStats::default(),
            prefix_ids: BTreeMap::new(),
            next_prefix_id: 0,
        }
    }

    /// Select the admission policy (builder style).
    pub fn with_admission(mut self, admission: AdmissionPolicy) -> Self {
        self.admission = admission;
        self
    }

    /// Set the paged-admission headroom (builder style; see
    /// `admit_headroom_pages`).
    pub fn with_headroom(mut self, pages: usize) -> Self {
        self.admit_headroom_pages = pages;
        self
    }

    /// Select the admission order (builder style; see `order`).
    pub fn with_order(mut self, order: AdmissionOrder) -> Self {
        self.order = order;
        self
    }

    /// Select prompt-prefix sharing (builder style; see `sharing`).
    pub fn with_sharing(mut self, sharing: PrefixSharing) -> Self {
        self.sharing = sharing;
        self
    }

    /// Predicted worst-case residency of one task: its cache never holds
    /// more than prompt + `max_response` generated tokens + one trailing
    /// write, nor more than the per-seq bound. THE reservation oracle:
    /// static paged chunk sizing reads this clamped form.
    pub fn predicted_residency(&self, prompt_tokens: usize, max_response: usize) -> usize {
        self.admission_cost(prompt_tokens, max_response).min(self.reserve_per_seq)
    }

    /// The shortest-first ORDERING key: the unclamped residency
    /// prediction. Deliberately not capped at `reserve_per_seq` — two
    /// tasks both clamped to the bound can still differ wildly in their
    /// paged admission charge (prompt pages), and ordering by the
    /// unclamped value breaks those ties toward the cheaper prompt, so a
    /// cap-tied giant cannot head-of-line-block an admissible smaller
    /// task. On unclamped values it orders identically to
    /// `predicted_residency`. The engines and the equivalence tests'
    /// order replays all read this one formula.
    pub fn admission_cost(&self, prompt_tokens: usize, max_response: usize) -> usize {
        prompt_tokens + max_response + 1
    }

    /// Which queue element the engine should try to admit next, as an
    /// index into `queue` (`None` iff empty). Fifo: the head.
    /// Shortest-first: the first element with the smallest admission
    /// cost (`cost[task]`, from `admission_cost`; stable — ties keep
    /// queue order, so uniform-cost queues degrade to exact fifo
    /// behavior).
    ///
    /// This linear scan is the executable REFERENCE semantics. The
    /// production engines pop through [`AdmissionQueue`], whose sorted
    /// index gives the same stable first-min order in O(log n) per
    /// operation — the propcheck below replays random queue traffic
    /// through both and requires identical pick sequences.
    pub fn pick_next(&self, queue: &VecDeque<usize>, cost: &[usize]) -> Option<usize> {
        match self.order {
            AdmissionOrder::Fifo => {
                if queue.is_empty() {
                    None
                } else {
                    Some(0)
                }
            }
            AdmissionOrder::ShortestFirst => (0..queue.len())
                .min_by_key(|&qi| cost.get(queue[qi]).copied().unwrap_or(usize::MAX)),
        }
    }

    /// Deadline-aware generalization of [`Scheduler::pick_next`] (the
    /// serving front-end's queue pick): earliest-deadline-first over the
    /// queue, with `admission_cost` breaking deadline ties and queue order
    /// breaking cost ties — the same stable first-min discipline as
    /// `pick_next`. Missing entries read as "no deadline" (`u64::MAX`),
    /// so a queue whose deadlines are ALL infinite degenerates EXACTLY to
    /// shortest-first (`pick_next` under `AdmissionOrder::ShortestFirst`
    /// is the oracle; the propcheck replays random queues through both).
    /// Ignores `self.order` deliberately: the serve loop's admission mode
    /// (`serve-admission = fifo|slo`) decides which picker runs, not the
    /// rollout-engine ordering knob.
    pub fn pick_next_deadline(
        &self,
        queue: &VecDeque<usize>,
        cost: &[usize],
        deadline: &[u64],
    ) -> Option<usize> {
        (0..queue.len()).min_by_key(|&qi| {
            let task = queue[qi];
            (
                deadline.get(task).copied().unwrap_or(u64::MAX),
                cost.get(task).copied().unwrap_or(usize::MAX),
            )
        })
    }

    /// Modeled completion cost of one request, in virtual-clock ticks:
    /// `predicted_residency × admission_cost` — the same load model the
    /// fleet router balances replicas by, reused as the serving
    /// front-end's admission controller. A request is admitted when
    /// `now + predicted_cost_ticks` fits its deadline and shed with this
    /// estimate otherwise, so overload degrades to honest rejections
    /// instead of queue collapse.
    pub fn predicted_cost_ticks(&self, prompt_tokens: usize, max_response: usize) -> u64 {
        self.predicted_residency(prompt_tokens, max_response) as u64
            * self.admission_cost(prompt_tokens, max_response) as u64
    }

    /// Tokens a fresh sequence with `prompt_tokens` of prompt is charged
    /// at admission. Worst-case: the full bound. Paged: the prompt plus
    /// the first decode write (page-rounded by the manager).
    pub fn admit_reserve(&self, prompt_tokens: usize) -> usize {
        match self.admission {
            AdmissionPolicy::WorstCase => self.reserve_per_seq,
            AdmissionPolicy::Paged => (prompt_tokens + 1).min(self.reserve_per_seq),
        }
    }

    /// Admit the next chunk from `pending` (indices not yet scheduled).
    /// Reserves KV for every admitted sequence; returns None when nothing
    /// can be admitted (caller should drain running chunks first).
    ///
    /// `residency[i]` is the predicted worst-case residency of pending
    /// item value `i` (task position) — `predicted_residency`, i.e.
    /// `min(prompt + max_response + 1, reserve_per_seq)`. Only paged
    /// admission reads it; worst-case callers may pass `&[]`.
    pub fn next_chunk(
        &mut self,
        pending: &mut Vec<usize>,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
        residency: &[usize],
    ) -> Option<Chunk> {
        if pending.is_empty() {
            return None;
        }
        let member = |item: usize| -> usize {
            residency
                .get(item)
                .copied()
                .unwrap_or(self.reserve_per_seq)
                .min(self.reserve_per_seq)
        };
        let width = match self.admission {
            AdmissionPolicy::WorstCase => self
                .slots
                .min(kv.admissible(self.reserve_per_seq))
                .min(pending.len()),
            AdmissionPolicy::Paged => {
                // greedy prefix fill by predicted per-member residency
                let mut free = kv.free_pages();
                let mut w = 0usize;
                for &item in pending.iter().take(self.slots) {
                    let pages = kv.pages_for(member(item));
                    if pages > free {
                        break;
                    }
                    free -= pages;
                    w += 1;
                }
                w
            }
        };
        if width == 0 {
            return None;
        }
        let items: Vec<usize> = pending.drain(..width).collect();
        for (slot, &item) in items.iter().enumerate() {
            let reserve = match self.admission {
                AdmissionPolicy::WorstCase => self.reserve_per_seq,
                AdmissionPolicy::Paged => member(item),
            };
            kv.reserve(seq_id_base + slot as u64, reserve)
                .expect("admission width guaranteed room");
        }
        self.stats.chunks += 1;
        self.stats.scheduled_seqs += width;
        self.stats.slot_utilization_sum += width as f64 / self.slots as f64;
        self.stats.kv_utilization_sum += kv.utilization();
        Some(Chunk { items, reserve_per_seq: self.reserve_per_seq })
    }

    /// Release a finished chunk's reservations.
    pub fn finish_chunk(&mut self, chunk: &Chunk, kv: &mut KvMemoryManager, seq_id_base: u64) {
        for slot in 0..chunk.items.len() {
            kv.release(seq_id_base + slot as u64).expect("reservation exists");
        }
    }

    /// Sequence-level admission (continuous engine): reserve this
    /// sequence's admission charge (worst-case bound, or prompt pages when
    /// paged), or refuse without side effects beyond the stall counter
    /// when the wall is full. Refusal is not an error — the engine keeps
    /// decoding and retries after the next release.
    ///
    /// Paged admission keeps `admit_headroom_pages` pages of growth
    /// headroom whenever other sequences are live (default 1): admitting
    /// flush against the wall guarantees the next grow stalls and the
    /// newcomer (lowest progress) is immediately preempted — a pure
    /// admit/preempt thrash cycle. With an empty pool the full pool is
    /// usable (progress guarantee).
    pub fn try_admit(
        &mut self,
        kv: &mut KvMemoryManager,
        seq: SeqId,
        prompt_tokens: usize,
    ) -> bool {
        let want = self.admit_reserve(prompt_tokens);
        let ok = match self.admission {
            AdmissionPolicy::WorstCase => kv.admissible(want) > 0,
            AdmissionPolicy::Paged => {
                let pages = kv.pages_for(want);
                if kv.live_sequences() == 0 {
                    pages <= kv.free_pages()
                } else {
                    pages.saturating_add(self.admit_headroom_pages) <= kv.free_pages()
                }
            }
        };
        if !ok {
            self.stats.admit_stalls += 1;
            return false;
        }
        kv.reserve(seq, want).expect("admission check guaranteed room");
        self.stats.seq_admissions += 1;
        true
    }

    /// Prompt-aware sequence admission: like `try_admit`, but under
    /// `prefix-sharing = group` + paged admission the sequence shares its
    /// page-aligned prompt prefix through the refcounted pool. The FIRST
    /// sequence of a prompt charges exactly what `try_admit` would (the
    /// prefix is page-aligned, so `pages(prefix) + pages(private) ==
    /// pages(total)`); siblings attach to the resident prefix and charge
    /// only their private pages — which is where G-way groups get their
    /// admission-width win. Falls back to `try_admit` whenever sharing is
    /// off, admission is worst-case, or the prompt is too short to span a
    /// page.
    pub fn try_admit_prompt(
        &mut self,
        kv: &mut KvMemoryManager,
        seq: SeqId,
        prompt: &[i32],
    ) -> bool {
        let want = self.admit_reserve(prompt.len());
        let page = kv.page_tokens();
        let shared = (prompt.len() / page) * page;
        if !self.sharing.is_group()
            || self.admission != AdmissionPolicy::Paged
            || shared == 0
            || want <= shared
        {
            return self.try_admit(kv, seq, prompt.len());
        }
        let pid = match self.prefix_ids.get(prompt) {
            Some(&pid) => pid,
            None => {
                let pid = self.next_prefix_id;
                self.next_prefix_id += 1;
                self.prefix_ids.insert(prompt.to_vec(), pid);
                pid
            }
        };
        let private = want - shared;
        let pages = kv.shared_admit_pages(pid, shared, private);
        let ok = if kv.live_sequences() == 0 {
            pages <= kv.free_pages()
        } else {
            pages.saturating_add(self.admit_headroom_pages) <= kv.free_pages()
        };
        if !ok {
            self.stats.admit_stalls += 1;
            return false;
        }
        let attached = kv
            .reserve_shared(seq, pid, shared, private)
            .expect("admission check guaranteed room");
        self.stats.seq_admissions += 1;
        if attached {
            self.stats.shared_admissions += 1;
        }
        true
    }

    /// Grow a live sequence's reservation to cover `need_tokens` resident
    /// tokens (paged admission only; worst-case reservations already cover
    /// every reachable residency). Returns false when the wall is full —
    /// the engine preempts a sequence and retries.
    pub fn grow(
        &mut self,
        kv: &mut KvMemoryManager,
        seq: SeqId,
        need_tokens: usize,
    ) -> anyhow::Result<bool> {
        debug_assert!(
            need_tokens <= self.reserve_per_seq,
            "grow past the per-seq bound: {need_tokens} > {}",
            self.reserve_per_seq
        );
        if self.admission == AdmissionPolicy::WorstCase {
            return Ok(true);
        }
        let grown = kv.grow(seq, need_tokens)?;
        if !grown {
            self.stats.grow_stalls += 1;
        }
        Ok(grown)
    }

    /// Adjust a live sequence's reservation to its post-compression
    /// residency (paged admission; no-op for worst-case). A
    /// prefix-sharing sequence cannot shrink in place — compression
    /// rewrites retained KV planes, so the sequence must own its whole
    /// residency first: this is the copy-on-write trigger. The fork can
    /// need net-new pages (the retained set becomes private while the
    /// prefix stays resident for its siblings), so like `grow` it can
    /// stall on the wall: `Ok(false)` means the caller must preempt a
    /// victim and retry. Non-sharing sequences shrink in place and always
    /// return `Ok(true)`.
    pub fn compressed(
        &mut self,
        kv: &mut KvMemoryManager,
        seq: SeqId,
        kept_tokens: usize,
    ) -> anyhow::Result<bool> {
        if self.admission == AdmissionPolicy::WorstCase {
            return Ok(true);
        }
        if kv.seq_prefix(seq).is_some() {
            let forked = kv.fork_to_private(seq, kept_tokens)?;
            if forked {
                self.stats.cow_forks += 1;
            } else {
                self.stats.grow_stalls += 1;
            }
            return Ok(forked);
        }
        kv.shrink(seq, kept_tokens)?;
        Ok(true)
    }

    /// Sequence-level release (continuous engine): frees the reservation
    /// the moment the sequence finishes. Double-release (or releasing a
    /// never-admitted id) is an error — the invariant tests rely on it.
    pub fn release_seq(
        &mut self,
        kv: &mut KvMemoryManager,
        seq: SeqId,
    ) -> anyhow::Result<usize> {
        let tokens = kv.release(seq)?;
        self.stats.seq_releases += 1;
        Ok(tokens)
    }

    /// Preempt a live sequence to resolve a grow stall: release its pages
    /// and count it. The engine requeues the task; per-task RNG makes the
    /// rerun token-identical, so preemption costs decode steps but never
    /// changes outputs.
    pub fn preempt(&mut self, kv: &mut KvMemoryManager, seq: SeqId) -> anyhow::Result<usize> {
        let tokens = self.release_seq(kv, seq)?;
        self.stats.preemptions += 1;
        Ok(tokens)
    }

    /// Release a live sequence because its task was quarantined
    /// (`fault-policy = quarantine`): pages and slot return to the pool
    /// like a preemption, but the task is recorded failed instead of
    /// requeued, and the `quarantined` counter keeps the conservation
    /// ledger balanced (see [`SchedulerStats::quarantined`]).
    pub fn quarantine_seq(
        &mut self,
        kv: &mut KvMemoryManager,
        seq: SeqId,
    ) -> anyhow::Result<usize> {
        let tokens = self.release_seq(kv, seq)?;
        self.stats.quarantined += 1;
        Ok(tokens)
    }

    /// Number of chunks needed for `n` sequences on an idle manager —
    /// the closed-form the throughput benches check against (worst-case
    /// admission at page size 1).
    pub fn predicted_chunks(&self, n: usize, kv_capacity: usize) -> usize {
        let width = self.slots.min(kv_capacity / self.reserve_per_seq.max(1)).max(1);
        n.div_ceil(width)
    }

    /// Effective decode width for a given per-sequence reservation on an
    /// idle token-granular wall of `kv_capacity`.
    fn width_for(&self, per_seq: usize, kv_capacity: usize) -> usize {
        self.slots.min(kv_capacity / per_seq.max(1)).max(1)
    }

    /// Effective width under paged admission at mean residency
    /// `mean_residency` tokens: the width model the paged benches report
    /// against. Paged width is data-dependent (residency changes every
    /// step), so this is an estimate, not a step-exact closed form.
    pub fn width_paged(&self, kv: &KvMemoryManager, mean_residency: usize) -> usize {
        self.slots
            .min(kv.total_pages() / kv.pages_for(mean_residency.max(1)).max(1))
            .max(1)
    }

    /// Decode steps the continuous engine needs for sequences whose
    /// response lengths are `response_lens` (queue order), on an idle
    /// manager of `kv_capacity`, with each sequence reserving `per_seq`
    /// tokens: the list-scheduling makespan of the per-sequence decode
    /// costs over the effective width.
    pub fn predicted_decode_steps_with(
        &self,
        response_lens: &[usize],
        kv_capacity: usize,
        per_seq: usize,
    ) -> usize {
        if response_lens.is_empty() {
            return 0;
        }
        let width = self.width_for(per_seq, kv_capacity).min(response_lens.len());
        let mut busy = vec![0usize; width];
        for &len in response_lens {
            let i = (0..width).min_by_key(|&i| busy[i]).expect("width >= 1");
            busy[i] += len.saturating_sub(1);
        }
        busy.into_iter().max().unwrap_or(0)
    }

    /// Decode steps the continuous engine needs under worst-case
    /// admission (step-exact; see `predicted_decode_steps_with`).
    ///
    /// A sequence generating L tokens occupies its slot for L-1 decode
    /// steps (the first token comes from prefill logits; the last token is
    /// sampled and the slot is recycled before the next decode). Greedy
    /// earliest-free-slot assignment in queue order is exactly what slot
    /// recycling does, so this is step-exact, and the property tests hold
    /// the engine to it.
    pub fn predicted_decode_steps(&self, response_lens: &[usize], kv_capacity: usize) -> usize {
        self.predicted_decode_steps_with(response_lens, kv_capacity, self.reserve_per_seq)
    }

    /// Decode steps the static engine needs for the same queue: each chunk
    /// runs to its slowest member, so the total is Σ over chunks of
    /// (max chunk length - 1).
    pub fn predicted_decode_steps_static(
        &self,
        response_lens: &[usize],
        kv_capacity: usize,
    ) -> usize {
        let width = self.width_for(self.reserve_per_seq, kv_capacity);
        response_lens
            .chunks(width)
            .map(|c| c.iter().max().copied().unwrap_or(0).saturating_sub(1))
            .sum()
    }
}

#[cfg(test)]
#[path = "scheduler_tests.rs"]
mod tests;
