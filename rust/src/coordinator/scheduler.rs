//! Memory-aware rollout scheduler.
//!
//! Packs pending prompts into decode-batch chunks subject to the KV memory
//! wall: every admitted sequence first reserves its worst-case residency
//! with the `KvMemoryManager` (dense: `max_seq`; sparse: `budget+buffer`).
//! The decode artifact is compiled for a fixed slot width R, so a chunk is
//! `min(R, admissible, pending)` sequences wide — the admissible term is
//! exactly where dense rollouts lose throughput (paper §1: "rollout batch
//! sizes must be constrained" to dodge long-tail OOM).

use crate::runtime::Manifest;

use super::kv_manager::KvMemoryManager;

/// One scheduled chunk: which pending items occupy which decode slots.
#[derive(Debug, Clone)]
pub struct Chunk {
    /// Indices into the pending queue, one per occupied slot (slot i of
    /// the decode batch holds pending[task_of_slot[i]]).
    pub items: Vec<usize>,
    /// Reservation per sequence this chunk was admitted with.
    pub reserve_per_seq: usize,
}

/// Scheduling statistics for the utilization benches.
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedulerStats {
    pub chunks: usize,
    pub scheduled_seqs: usize,
    /// Σ over chunks of occupied slots / R (decode-slot utilization).
    pub slot_utilization_sum: f64,
    /// Σ over chunks of reserved KV / capacity at admission time.
    pub kv_utilization_sum: f64,
}

impl SchedulerStats {
    pub fn mean_slot_utilization(&self) -> f64 {
        if self.chunks == 0 {
            0.0
        } else {
            self.slot_utilization_sum / self.chunks as f64
        }
    }

    pub fn mean_kv_utilization(&self) -> f64 {
        if self.chunks == 0 {
            0.0
        } else {
            self.kv_utilization_sum / self.chunks as f64
        }
    }
}

/// Plans chunks over a queue of `n_pending` sequences.
pub struct Scheduler {
    /// Decode slot width (from the manifest).
    pub slots: usize,
    /// Worst-case KV tokens one sequence may hold.
    pub reserve_per_seq: usize,
    pub stats: SchedulerStats,
}

impl Scheduler {
    /// `sparse` selects the reservation bound (the whole memory-wall
    /// story is this one line: capacity-bounded vs length-bounded).
    pub fn new(manifest: &Manifest, sparse: bool) -> Self {
        let reserve = if sparse {
            manifest.shapes.sparse_capacity
        } else {
            manifest.config.max_seq
        };
        Scheduler {
            slots: manifest.shapes.decode_batch,
            reserve_per_seq: reserve,
            stats: SchedulerStats::default(),
        }
    }

    /// Admit the next chunk from `pending` (indices not yet scheduled).
    /// Reserves KV for every admitted sequence; returns None when nothing
    /// can be admitted (caller should drain running chunks first).
    pub fn next_chunk(
        &mut self,
        pending: &mut Vec<usize>,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
    ) -> Option<Chunk> {
        if pending.is_empty() {
            return None;
        }
        let width = self
            .slots
            .min(kv.admissible(self.reserve_per_seq))
            .min(pending.len());
        if width == 0 {
            return None;
        }
        let items: Vec<usize> = pending.drain(..width).collect();
        for (slot, _) in items.iter().enumerate() {
            kv.reserve(seq_id_base + slot as u64, self.reserve_per_seq)
                .expect("admissible() guaranteed room");
        }
        self.stats.chunks += 1;
        self.stats.scheduled_seqs += width;
        self.stats.slot_utilization_sum += width as f64 / self.slots as f64;
        self.stats.kv_utilization_sum += kv.utilization();
        Some(Chunk { items, reserve_per_seq: self.reserve_per_seq })
    }

    /// Release a finished chunk's reservations.
    pub fn finish_chunk(&mut self, chunk: &Chunk, kv: &mut KvMemoryManager, seq_id_base: u64) {
        for slot in 0..chunk.items.len() {
            kv.release(seq_id_base + slot as u64).expect("reservation exists");
        }
    }

    /// Number of chunks needed for `n` sequences on an idle manager —
    /// the closed-form the throughput benches check against.
    pub fn predicted_chunks(&self, n: usize, kv_capacity: usize) -> usize {
        let width = self.slots.min(kv_capacity / self.reserve_per_seq.max(1)).max(1);
        n.div_ceil(width)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    fn fake_manifest(slots: usize, max_seq: usize, sparse_cap: usize) -> (usize, usize, usize) {
        // Scheduler only reads three numbers; tests construct it directly.
        (slots, max_seq, sparse_cap)
    }

    fn mk(slots: usize, reserve: usize) -> Scheduler {
        Scheduler { slots, reserve_per_seq: reserve, stats: SchedulerStats::default() }
    }

    #[test]
    fn dense_is_memory_limited_sparse_is_slot_limited() {
        let (slots, max_seq, sparse_cap) = fake_manifest(16, 208, 48);
        let mut kv = KvMemoryManager::new(2048);
        let mut dense = mk(slots, max_seq);
        let mut pending: Vec<usize> = (0..16).collect();
        let c = dense.next_chunk(&mut pending, &mut kv, 0).unwrap();
        assert_eq!(c.items.len(), 9); // 2048 / 208
        dense.finish_chunk(&c, &mut kv, 0);
        assert_eq!(kv.reserved(), 0);

        let mut sparse = mk(slots, sparse_cap);
        let mut pending: Vec<usize> = (0..64).collect();
        let c = sparse.next_chunk(&mut pending, &mut kv, 100).unwrap();
        assert_eq!(c.items.len(), 16); // slot-limited, not memory-limited
        sparse.finish_chunk(&c, &mut kv, 100);
    }

    #[test]
    fn predicted_chunks_match_actual() {
        propcheck::quick("sched-prediction", |rng, size| {
            let slots = 1 + rng.below(32);
            let reserve = 1 + rng.below(300);
            let cap = reserve + rng.below(4096);
            let n = 1 + size;
            let mut sched = mk(slots, reserve);
            let mut kv = KvMemoryManager::new(cap);
            let mut pending: Vec<usize> = (0..n).collect();
            let mut chunks = 0usize;
            let mut scheduled = 0usize;
            while !pending.is_empty() {
                match sched.next_chunk(&mut pending, &mut kv, 1000) {
                    Some(c) => {
                        chunks += 1;
                        scheduled += c.items.len();
                        // synchronous drain (static batching)
                        sched.finish_chunk(&c, &mut kv, 1000);
                    }
                    None => return Err("deadlock: nothing admissible".into()),
                }
                if chunks > n {
                    return Err("more chunks than sequences".into());
                }
            }
            if scheduled != n {
                return Err(format!("scheduled {scheduled} of {n}"));
            }
            if chunks != sched.predicted_chunks(n, cap) {
                return Err(format!(
                    "chunks {} != predicted {}",
                    chunks,
                    sched.predicted_chunks(n, cap)
                ));
            }
            if kv.reserved() != 0 {
                return Err("kv not fully released".into());
            }
            Ok(())
        });
    }

    #[test]
    fn stats_track_utilization() {
        let mut kv = KvMemoryManager::new(208 * 4);
        let mut s = mk(8, 208);
        let mut pending: Vec<usize> = (0..8).collect();
        let c = s.next_chunk(&mut pending, &mut kv, 0).unwrap();
        assert_eq!(c.items.len(), 4);
        assert!((s.stats.mean_slot_utilization() - 0.5).abs() < 1e-9);
        assert!((s.stats.mean_kv_utilization() - 1.0).abs() < 1e-9);
    }
}
