//! Memory-aware rollout scheduler.
//!
//! Packs pending prompts into the decode batch subject to the KV memory
//! wall. Two *admission policies* decide what a sequence is charged
//! (`config::AdmissionPolicy`):
//!
//! * **Worst-case** (seed behavior, default): every admitted sequence
//!   reserves its worst-case residency up front (dense: `max_seq`; sparse:
//!   `budget+buffer`). Admission can never fail mid-decode, but width is
//!   `capacity / worst_case` — exactly where dense rollouts lose
//!   throughput (paper §1: "rollout batch sizes must be constrained" to
//!   dodge long-tail OOM).
//! * **Paged**: a sequence is admitted with only the pages its prompt
//!   needs, `grow`s page-by-page as decode writes land, and shrinks to the
//!   compressed residency after each compression event (`compressed`).
//!   Width tracks *actual* residency — the admissible-batch gain the paper
//!   attributes to sparse caches applies to both modes. The cost: a `grow`
//!   can hit the wall mid-decode; the continuous engine resolves it by
//!   preempting the lowest-progress sequence (`preempt`) and requeueing
//!   it, so the wall is never breached and a drain is always reachable.
//!
//! Two admission granularities serve the two rollout engines:
//!
//! * **Chunk-level** (`next_chunk` / `finish_chunk`, static engine): a
//!   whole chunk reserves together and releases together when the slowest
//!   sequence in it finishes. Under paged admission the chunk cannot be
//!   preempted, so each member reserves its *predicted* residency
//!   (`min(prompt + max_response, worst_case)`, page-rounded) — still a
//!   safe bound, but per-sequence-tight, so chunks are sized by predicted
//!   paged residency instead of the global worst case.
//! * **Sequence-level** (`try_admit` / `grow` / `release_seq`, continuous
//!   engine): each sequence reserves on admission and releases the moment
//!   it finishes, letting the engine refill the freed slot immediately.
//!   The closed-form `predicted_decode_steps` models the worst-case
//!   schedule (greedy earliest-free-slot, queue order) step-exactly; under
//!   paged admission the effective width is data-dependent, so the closed
//!   forms bound it via `predicted_decode_steps_with` (see `width_paged`).

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::config::{AdmissionOrder, AdmissionPolicy, PrefixSharing};
use crate::runtime::Manifest;

use super::kv_manager::{KvMemoryManager, SeqId};

/// The dynamic engines' pending-task queue with an order-aware pop.
///
/// Fifo keeps a plain deque. Shortest-first keeps a sorted index — a
/// `BTreeSet` keyed by `(cost, stamp)` — replacing the old
/// scan-the-whole-queue-per-pick (O(n²) over a full drain; the PR-4
/// follow-up). Stamps encode deque order: `push_back` stamps increase,
/// `push_front` stamps decrease, so the set's minimum `(cost, stamp)` is
/// exactly the FIRST queue element with minimal cost — the stable
/// first-min tie-break `Scheduler::pick_next` specifies. `pick_next`
/// stays as the executable reference semantics; the propcheck replays
/// random push-front/pop traffic against it to pin the tie-break.
///
/// Costs are per task position and fixed for the queue's lifetime
/// (`Scheduler::admission_cost` of every task, computed once per
/// rollout), so requeued (preempted) tasks re-enter with their original
/// cost — only their stamp (queue position) changes.
#[derive(Debug)]
pub struct AdmissionQueue {
    order: AdmissionOrder,
    cost: Vec<usize>,
    fifo: VecDeque<usize>,
    sorted: BTreeSet<(usize, i64, usize)>,
    front_stamp: i64,
    back_stamp: i64,
}

impl AdmissionQueue {
    /// Build a queue holding task positions `0..cost.len()` in order,
    /// popped according to `order` over the per-position `cost` vector.
    pub fn new(order: AdmissionOrder, cost: Vec<usize>) -> AdmissionQueue {
        let n = cost.len();
        let mut q = AdmissionQueue {
            order,
            cost,
            fifo: VecDeque::with_capacity(n),
            sorted: BTreeSet::new(),
            front_stamp: -1,
            back_stamp: 0,
        };
        for pos in 0..n {
            q.push_back(pos);
        }
        q
    }

    pub fn len(&self) -> usize {
        match self.order {
            AdmissionOrder::Fifo => self.fifo.len(),
            AdmissionOrder::ShortestFirst => self.sorted.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn cost_of(&self, pos: usize) -> usize {
        self.cost.get(pos).copied().unwrap_or(usize::MAX)
    }

    fn push_back(&mut self, pos: usize) {
        match self.order {
            AdmissionOrder::Fifo => self.fifo.push_back(pos),
            AdmissionOrder::ShortestFirst => {
                let stamp = self.back_stamp;
                self.back_stamp += 1;
                self.sorted.insert((self.cost_of(pos), stamp, pos));
            }
        }
    }

    /// Requeue a task at the queue head (preemption path): among equal
    /// costs it now wins the next pick, exactly like the old
    /// `VecDeque::push_front` + first-min scan.
    pub fn push_front(&mut self, pos: usize) {
        match self.order {
            AdmissionOrder::Fifo => self.fifo.push_front(pos),
            AdmissionOrder::ShortestFirst => {
                let stamp = self.front_stamp;
                self.front_stamp -= 1;
                self.sorted.insert((self.cost_of(pos), stamp, pos));
            }
        }
    }

    /// The task position the engine should try to admit next (`None` iff
    /// empty); `pop` removes exactly this element.
    pub fn peek(&self) -> Option<usize> {
        match self.order {
            AdmissionOrder::Fifo => self.fifo.front().copied(),
            AdmissionOrder::ShortestFirst => self.sorted.first().map(|&(_, _, pos)| pos),
        }
    }

    /// Remove and return the element `peek` reported.
    pub fn pop(&mut self) -> Option<usize> {
        match self.order {
            AdmissionOrder::Fifo => self.fifo.pop_front(),
            AdmissionOrder::ShortestFirst => self.sorted.pop_first().map(|(_, _, pos)| pos),
        }
    }
}

/// One scheduled chunk: which pending items occupy which decode slots.
#[derive(Debug, Clone)]
pub struct Chunk {
    /// Indices into the pending queue, one per occupied slot (slot i of
    /// the decode batch holds pending[task_of_slot[i]]).
    pub items: Vec<usize>,
    /// Worst-case reservation bound the chunk was admitted under (paged
    /// chunks reserve per-member predicted residency instead).
    pub reserve_per_seq: usize,
}

/// Scheduling statistics for the utilization benches.
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedulerStats {
    pub chunks: usize,
    pub scheduled_seqs: usize,
    /// Σ over chunks of occupied slots / R (decode-slot utilization).
    pub slot_utilization_sum: f64,
    /// Σ over chunks of reserved KV / capacity at admission time.
    pub kv_utilization_sum: f64,
    /// Sequence-level admissions (continuous engine).
    pub seq_admissions: usize,
    /// Sequence-level releases (continuous engine; includes preemptions).
    pub seq_releases: usize,
    /// Admission attempts refused by the memory wall (continuous engine:
    /// a freed slot had to idle because no KV could be reserved).
    pub admit_stalls: usize,
    /// Mid-decode grow attempts refused by the wall (paged admission;
    /// includes denied copy-on-write forks under prefix sharing).
    pub grow_stalls: usize,
    /// Sequences preempted and requeued to resolve a grow stall.
    pub preemptions: usize,
    /// Admissions that attached to an already-resident shared prompt
    /// prefix instead of paying for it (prefix sharing).
    pub shared_admissions: usize,
    /// Copy-on-write forks: sharers detached from their prefix at their
    /// first compression event (prefix sharing).
    pub cow_forks: usize,
}

impl SchedulerStats {
    pub fn mean_slot_utilization(&self) -> f64 {
        if self.chunks == 0 {
            0.0
        } else {
            self.slot_utilization_sum / self.chunks as f64
        }
    }

    pub fn mean_kv_utilization(&self) -> f64 {
        if self.chunks == 0 {
            0.0
        } else {
            self.kv_utilization_sum / self.chunks as f64
        }
    }

    /// Sequences currently admitted and not yet released.
    pub fn live_seqs(&self) -> usize {
        self.seq_admissions - self.seq_releases
    }
}

/// Plans admissions over a queue of pending sequences.
pub struct Scheduler {
    /// Decode slot width (from the manifest).
    pub slots: usize,
    /// Worst-case KV tokens one sequence may hold (dense: `max_seq`;
    /// sparse: `budget+buffer`). Under paged admission this is the growth
    /// *ceiling*, not the admission charge.
    pub reserve_per_seq: usize,
    /// What a sequence is charged at admission (see module docs).
    pub admission: AdmissionPolicy,
    /// Free pages a paged admission must leave behind while other
    /// sequences are live (`kv-admit-headroom-pages`; default 1 — the
    /// original hard-coded behavior). Admitting flush against the wall
    /// (headroom 0) guarantees the next grow stalls and the newcomer is
    /// immediately preempted — a pure admit/preempt thrash cycle under
    /// pressure; larger headroom trades admitted width for fewer
    /// preemptions. Ignored by worst-case admission, and bypassed when
    /// the pool is empty (progress guarantee).
    pub admit_headroom_pages: usize,
    /// Order pending tasks are admitted in (`admission-order`): `fifo`
    /// (seed behavior — the queue head is the only candidate) or
    /// `shortest-first` (makespan-aware — smallest predicted residency
    /// first, so a big task never head-of-line-blocks a small admissible
    /// one). Pure scheduling: per-task RNG keeps tokens order-invariant.
    pub order: AdmissionOrder,
    /// Prompt-prefix KV sharing (`prefix-sharing`): `Group` lets
    /// sequences with identical prompts (a GRPO group / eval's K samples)
    /// share their page-aligned prompt prefix through the refcounted
    /// pool, charging it once. Accounting only changes under paged
    /// admission (worst-case prices per sequence by definition). Default
    /// off — the seed accounting, bit-exact.
    pub sharing: PrefixSharing,
    pub stats: SchedulerStats,
    /// Prompt identity -> prefix id for the refcounted pool. Keyed by the
    /// exact prompt token run; ids are stable for the scheduler's
    /// lifetime, and a dead prefix (all sharers released) is simply
    /// re-charged fresh on its next use (`shared_admit_pages` checks
    /// residency, not this registry).
    prefix_ids: BTreeMap<Vec<i32>, u64>,
    next_prefix_id: u64,
}

impl Scheduler {
    /// `sparse` selects the reservation bound (the whole memory-wall
    /// story is this one line: capacity-bounded vs length-bounded).
    /// Defaults to worst-case admission — the seed behavior.
    pub fn new(manifest: &Manifest, sparse: bool) -> Self {
        let reserve = if sparse {
            manifest.shapes.sparse_capacity
        } else {
            manifest.config.max_seq
        };
        Self::worst_case(manifest.shapes.decode_batch, reserve)
    }

    /// Bare worst-case scheduler (tests/benches construct these directly).
    pub fn worst_case(slots: usize, reserve_per_seq: usize) -> Self {
        Scheduler {
            slots,
            reserve_per_seq,
            admission: AdmissionPolicy::WorstCase,
            admit_headroom_pages: 1,
            order: AdmissionOrder::Fifo,
            sharing: PrefixSharing::Off,
            stats: SchedulerStats::default(),
            prefix_ids: BTreeMap::new(),
            next_prefix_id: 0,
        }
    }

    /// Select the admission policy (builder style).
    pub fn with_admission(mut self, admission: AdmissionPolicy) -> Self {
        self.admission = admission;
        self
    }

    /// Set the paged-admission headroom (builder style; see
    /// `admit_headroom_pages`).
    pub fn with_headroom(mut self, pages: usize) -> Self {
        self.admit_headroom_pages = pages;
        self
    }

    /// Select the admission order (builder style; see `order`).
    pub fn with_order(mut self, order: AdmissionOrder) -> Self {
        self.order = order;
        self
    }

    /// Select prompt-prefix sharing (builder style; see `sharing`).
    pub fn with_sharing(mut self, sharing: PrefixSharing) -> Self {
        self.sharing = sharing;
        self
    }

    /// Predicted worst-case residency of one task: its cache never holds
    /// more than prompt + `max_response` generated tokens + one trailing
    /// write, nor more than the per-seq bound. THE reservation oracle:
    /// static paged chunk sizing reads this clamped form.
    pub fn predicted_residency(&self, prompt_tokens: usize, max_response: usize) -> usize {
        self.admission_cost(prompt_tokens, max_response).min(self.reserve_per_seq)
    }

    /// The shortest-first ORDERING key: the unclamped residency
    /// prediction. Deliberately not capped at `reserve_per_seq` — two
    /// tasks both clamped to the bound can still differ wildly in their
    /// paged admission charge (prompt pages), and ordering by the
    /// unclamped value breaks those ties toward the cheaper prompt, so a
    /// cap-tied giant cannot head-of-line-block an admissible smaller
    /// task. On unclamped values it orders identically to
    /// `predicted_residency`. The engines and the equivalence tests'
    /// order replays all read this one formula.
    pub fn admission_cost(&self, prompt_tokens: usize, max_response: usize) -> usize {
        prompt_tokens + max_response + 1
    }

    /// Which queue element the engine should try to admit next, as an
    /// index into `queue` (`None` iff empty). Fifo: the head.
    /// Shortest-first: the first element with the smallest admission
    /// cost (`cost[task]`, from `admission_cost`; stable — ties keep
    /// queue order, so uniform-cost queues degrade to exact fifo
    /// behavior).
    ///
    /// This linear scan is the executable REFERENCE semantics. The
    /// production engines pop through [`AdmissionQueue`], whose sorted
    /// index gives the same stable first-min order in O(log n) per
    /// operation — the propcheck below replays random queue traffic
    /// through both and requires identical pick sequences.
    pub fn pick_next(&self, queue: &VecDeque<usize>, cost: &[usize]) -> Option<usize> {
        match self.order {
            AdmissionOrder::Fifo => {
                if queue.is_empty() {
                    None
                } else {
                    Some(0)
                }
            }
            AdmissionOrder::ShortestFirst => (0..queue.len())
                .min_by_key(|&qi| cost.get(queue[qi]).copied().unwrap_or(usize::MAX)),
        }
    }

    /// Tokens a fresh sequence with `prompt_tokens` of prompt is charged
    /// at admission. Worst-case: the full bound. Paged: the prompt plus
    /// the first decode write (page-rounded by the manager).
    pub fn admit_reserve(&self, prompt_tokens: usize) -> usize {
        match self.admission {
            AdmissionPolicy::WorstCase => self.reserve_per_seq,
            AdmissionPolicy::Paged => (prompt_tokens + 1).min(self.reserve_per_seq),
        }
    }

    /// Admit the next chunk from `pending` (indices not yet scheduled).
    /// Reserves KV for every admitted sequence; returns None when nothing
    /// can be admitted (caller should drain running chunks first).
    ///
    /// `residency[i]` is the predicted worst-case residency of pending
    /// item value `i` (task position) — `predicted_residency`, i.e.
    /// `min(prompt + max_response + 1, reserve_per_seq)`. Only paged
    /// admission reads it; worst-case callers may pass `&[]`.
    pub fn next_chunk(
        &mut self,
        pending: &mut Vec<usize>,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
        residency: &[usize],
    ) -> Option<Chunk> {
        if pending.is_empty() {
            return None;
        }
        let member = |item: usize| -> usize {
            residency
                .get(item)
                .copied()
                .unwrap_or(self.reserve_per_seq)
                .min(self.reserve_per_seq)
        };
        let width = match self.admission {
            AdmissionPolicy::WorstCase => self
                .slots
                .min(kv.admissible(self.reserve_per_seq))
                .min(pending.len()),
            AdmissionPolicy::Paged => {
                // greedy prefix fill by predicted per-member residency
                let mut free = kv.free_pages();
                let mut w = 0usize;
                for &item in pending.iter().take(self.slots) {
                    let pages = kv.pages_for(member(item));
                    if pages > free {
                        break;
                    }
                    free -= pages;
                    w += 1;
                }
                w
            }
        };
        if width == 0 {
            return None;
        }
        let items: Vec<usize> = pending.drain(..width).collect();
        for (slot, &item) in items.iter().enumerate() {
            let reserve = match self.admission {
                AdmissionPolicy::WorstCase => self.reserve_per_seq,
                AdmissionPolicy::Paged => member(item),
            };
            kv.reserve(seq_id_base + slot as u64, reserve)
                .expect("admission width guaranteed room");
        }
        self.stats.chunks += 1;
        self.stats.scheduled_seqs += width;
        self.stats.slot_utilization_sum += width as f64 / self.slots as f64;
        self.stats.kv_utilization_sum += kv.utilization();
        Some(Chunk { items, reserve_per_seq: self.reserve_per_seq })
    }

    /// Release a finished chunk's reservations.
    pub fn finish_chunk(&mut self, chunk: &Chunk, kv: &mut KvMemoryManager, seq_id_base: u64) {
        for slot in 0..chunk.items.len() {
            kv.release(seq_id_base + slot as u64).expect("reservation exists");
        }
    }

    /// Sequence-level admission (continuous engine): reserve this
    /// sequence's admission charge (worst-case bound, or prompt pages when
    /// paged), or refuse without side effects beyond the stall counter
    /// when the wall is full. Refusal is not an error — the engine keeps
    /// decoding and retries after the next release.
    ///
    /// Paged admission keeps `admit_headroom_pages` pages of growth
    /// headroom whenever other sequences are live (default 1): admitting
    /// flush against the wall guarantees the next grow stalls and the
    /// newcomer (lowest progress) is immediately preempted — a pure
    /// admit/preempt thrash cycle. With an empty pool the full pool is
    /// usable (progress guarantee).
    pub fn try_admit(
        &mut self,
        kv: &mut KvMemoryManager,
        seq: SeqId,
        prompt_tokens: usize,
    ) -> bool {
        let want = self.admit_reserve(prompt_tokens);
        let ok = match self.admission {
            AdmissionPolicy::WorstCase => kv.admissible(want) > 0,
            AdmissionPolicy::Paged => {
                let pages = kv.pages_for(want);
                if kv.live_sequences() == 0 {
                    pages <= kv.free_pages()
                } else {
                    pages.saturating_add(self.admit_headroom_pages) <= kv.free_pages()
                }
            }
        };
        if !ok {
            self.stats.admit_stalls += 1;
            return false;
        }
        kv.reserve(seq, want).expect("admission check guaranteed room");
        self.stats.seq_admissions += 1;
        true
    }

    /// Prompt-aware sequence admission: like `try_admit`, but under
    /// `prefix-sharing = group` + paged admission the sequence shares its
    /// page-aligned prompt prefix through the refcounted pool. The FIRST
    /// sequence of a prompt charges exactly what `try_admit` would (the
    /// prefix is page-aligned, so `pages(prefix) + pages(private) ==
    /// pages(total)`); siblings attach to the resident prefix and charge
    /// only their private pages — which is where G-way groups get their
    /// admission-width win. Falls back to `try_admit` whenever sharing is
    /// off, admission is worst-case, or the prompt is too short to span a
    /// page.
    pub fn try_admit_prompt(
        &mut self,
        kv: &mut KvMemoryManager,
        seq: SeqId,
        prompt: &[i32],
    ) -> bool {
        let want = self.admit_reserve(prompt.len());
        let page = kv.page_tokens();
        let shared = (prompt.len() / page) * page;
        if !self.sharing.is_group()
            || self.admission != AdmissionPolicy::Paged
            || shared == 0
            || want <= shared
        {
            return self.try_admit(kv, seq, prompt.len());
        }
        let pid = match self.prefix_ids.get(prompt) {
            Some(&pid) => pid,
            None => {
                let pid = self.next_prefix_id;
                self.next_prefix_id += 1;
                self.prefix_ids.insert(prompt.to_vec(), pid);
                pid
            }
        };
        let private = want - shared;
        let pages = kv.shared_admit_pages(pid, shared, private);
        let ok = if kv.live_sequences() == 0 {
            pages <= kv.free_pages()
        } else {
            pages.saturating_add(self.admit_headroom_pages) <= kv.free_pages()
        };
        if !ok {
            self.stats.admit_stalls += 1;
            return false;
        }
        let attached = kv
            .reserve_shared(seq, pid, shared, private)
            .expect("admission check guaranteed room");
        self.stats.seq_admissions += 1;
        if attached {
            self.stats.shared_admissions += 1;
        }
        true
    }

    /// Grow a live sequence's reservation to cover `need_tokens` resident
    /// tokens (paged admission only; worst-case reservations already cover
    /// every reachable residency). Returns false when the wall is full —
    /// the engine preempts a sequence and retries.
    pub fn grow(
        &mut self,
        kv: &mut KvMemoryManager,
        seq: SeqId,
        need_tokens: usize,
    ) -> anyhow::Result<bool> {
        debug_assert!(
            need_tokens <= self.reserve_per_seq,
            "grow past the per-seq bound: {need_tokens} > {}",
            self.reserve_per_seq
        );
        if self.admission == AdmissionPolicy::WorstCase {
            return Ok(true);
        }
        let grown = kv.grow(seq, need_tokens)?;
        if !grown {
            self.stats.grow_stalls += 1;
        }
        Ok(grown)
    }

    /// Adjust a live sequence's reservation to its post-compression
    /// residency (paged admission; no-op for worst-case). A
    /// prefix-sharing sequence cannot shrink in place — compression
    /// rewrites retained KV planes, so the sequence must own its whole
    /// residency first: this is the copy-on-write trigger. The fork can
    /// need net-new pages (the retained set becomes private while the
    /// prefix stays resident for its siblings), so like `grow` it can
    /// stall on the wall: `Ok(false)` means the caller must preempt a
    /// victim and retry. Non-sharing sequences shrink in place and always
    /// return `Ok(true)`.
    pub fn compressed(
        &mut self,
        kv: &mut KvMemoryManager,
        seq: SeqId,
        kept_tokens: usize,
    ) -> anyhow::Result<bool> {
        if self.admission == AdmissionPolicy::WorstCase {
            return Ok(true);
        }
        if kv.seq_prefix(seq).is_some() {
            let forked = kv.fork_to_private(seq, kept_tokens)?;
            if forked {
                self.stats.cow_forks += 1;
            } else {
                self.stats.grow_stalls += 1;
            }
            return Ok(forked);
        }
        kv.shrink(seq, kept_tokens)?;
        Ok(true)
    }

    /// Sequence-level release (continuous engine): frees the reservation
    /// the moment the sequence finishes. Double-release (or releasing a
    /// never-admitted id) is an error — the invariant tests rely on it.
    pub fn release_seq(
        &mut self,
        kv: &mut KvMemoryManager,
        seq: SeqId,
    ) -> anyhow::Result<usize> {
        let tokens = kv.release(seq)?;
        self.stats.seq_releases += 1;
        Ok(tokens)
    }

    /// Preempt a live sequence to resolve a grow stall: release its pages
    /// and count it. The engine requeues the task; per-task RNG makes the
    /// rerun token-identical, so preemption costs decode steps but never
    /// changes outputs.
    pub fn preempt(&mut self, kv: &mut KvMemoryManager, seq: SeqId) -> anyhow::Result<usize> {
        let tokens = self.release_seq(kv, seq)?;
        self.stats.preemptions += 1;
        Ok(tokens)
    }

    /// Number of chunks needed for `n` sequences on an idle manager —
    /// the closed-form the throughput benches check against (worst-case
    /// admission at page size 1).
    pub fn predicted_chunks(&self, n: usize, kv_capacity: usize) -> usize {
        let width = self.slots.min(kv_capacity / self.reserve_per_seq.max(1)).max(1);
        n.div_ceil(width)
    }

    /// Effective decode width for a given per-sequence reservation on an
    /// idle token-granular wall of `kv_capacity`.
    fn width_for(&self, per_seq: usize, kv_capacity: usize) -> usize {
        self.slots.min(kv_capacity / per_seq.max(1)).max(1)
    }

    /// Effective width under paged admission at mean residency
    /// `mean_residency` tokens: the width model the paged benches report
    /// against. Paged width is data-dependent (residency changes every
    /// step), so this is an estimate, not a step-exact closed form.
    pub fn width_paged(&self, kv: &KvMemoryManager, mean_residency: usize) -> usize {
        self.slots
            .min(kv.total_pages() / kv.pages_for(mean_residency.max(1)).max(1))
            .max(1)
    }

    /// Decode steps the continuous engine needs for sequences whose
    /// response lengths are `response_lens` (queue order), on an idle
    /// manager of `kv_capacity`, with each sequence reserving `per_seq`
    /// tokens: the list-scheduling makespan of the per-sequence decode
    /// costs over the effective width.
    pub fn predicted_decode_steps_with(
        &self,
        response_lens: &[usize],
        kv_capacity: usize,
        per_seq: usize,
    ) -> usize {
        if response_lens.is_empty() {
            return 0;
        }
        let width = self.width_for(per_seq, kv_capacity).min(response_lens.len());
        let mut busy = vec![0usize; width];
        for &len in response_lens {
            let i = (0..width).min_by_key(|&i| busy[i]).expect("width >= 1");
            busy[i] += len.saturating_sub(1);
        }
        busy.into_iter().max().unwrap_or(0)
    }

    /// Decode steps the continuous engine needs under worst-case
    /// admission (step-exact; see `predicted_decode_steps_with`).
    ///
    /// A sequence generating L tokens occupies its slot for L-1 decode
    /// steps (the first token comes from prefill logits; the last token is
    /// sampled and the slot is recycled before the next decode). Greedy
    /// earliest-free-slot assignment in queue order is exactly what slot
    /// recycling does, so this is step-exact, and the property tests hold
    /// the engine to it.
    pub fn predicted_decode_steps(&self, response_lens: &[usize], kv_capacity: usize) -> usize {
        self.predicted_decode_steps_with(response_lens, kv_capacity, self.reserve_per_seq)
    }

    /// Decode steps the static engine needs for the same queue: each chunk
    /// runs to its slowest member, so the total is Σ over chunks of
    /// (max chunk length - 1).
    pub fn predicted_decode_steps_static(
        &self,
        response_lens: &[usize],
        kv_capacity: usize,
    ) -> usize {
        let width = self.width_for(self.reserve_per_seq, kv_capacity);
        response_lens
            .chunks(width)
            .map(|c| c.iter().max().copied().unwrap_or(0).saturating_sub(1))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    fn fake_manifest(slots: usize, max_seq: usize, sparse_cap: usize) -> (usize, usize, usize) {
        // Scheduler only reads three numbers; tests construct it directly.
        (slots, max_seq, sparse_cap)
    }

    fn mk(slots: usize, reserve: usize) -> Scheduler {
        Scheduler::worst_case(slots, reserve)
    }

    #[test]
    fn dense_is_memory_limited_sparse_is_slot_limited() {
        let (slots, max_seq, sparse_cap) = fake_manifest(16, 208, 48);
        let mut kv = KvMemoryManager::new(2048);
        let mut dense = mk(slots, max_seq);
        let mut pending: Vec<usize> = (0..16).collect();
        let c = dense.next_chunk(&mut pending, &mut kv, 0, &[]).unwrap();
        assert_eq!(c.items.len(), 9); // 2048 / 208
        dense.finish_chunk(&c, &mut kv, 0);
        assert_eq!(kv.reserved(), 0);

        let mut sparse = mk(slots, sparse_cap);
        let mut pending: Vec<usize> = (0..64).collect();
        let c = sparse.next_chunk(&mut pending, &mut kv, 100, &[]).unwrap();
        assert_eq!(c.items.len(), 16); // slot-limited, not memory-limited
        sparse.finish_chunk(&c, &mut kv, 100);
    }

    #[test]
    fn paged_chunks_admit_by_predicted_residency() {
        // worst case 160/seq on a 480 wall admits 3; predicted residencies
        // of 80 admit 6 (slot-capped at 8)
        let mut kv = KvMemoryManager::with_pages(480, 16);
        let mut s = mk(8, 160).with_admission(AdmissionPolicy::Paged);
        let residency = vec![80usize; 12];
        let mut pending: Vec<usize> = (0..12).collect();
        let c = s.next_chunk(&mut pending, &mut kv, 0, &residency).unwrap();
        assert_eq!(c.items.len(), 6);
        assert_eq!(kv.reserved(), 6 * 80);
        kv.check_invariants().unwrap();
        s.finish_chunk(&c, &mut kv, 0);
        assert_eq!(kv.reserved(), 0);

        // mixed residencies: greedy prefix fill stops at the wall
        let residency = vec![200usize, 200, 200, 200];
        let mut pending: Vec<usize> = (0..4).collect();
        let c = s.next_chunk(&mut pending, &mut kv, 0, &residency).unwrap();
        // 200 tokens = 13 pages; 30 pages in pool -> 2 fit
        assert_eq!(c.items.len(), 2);
        s.finish_chunk(&c, &mut kv, 0);
    }

    #[test]
    fn predicted_chunks_match_actual() {
        propcheck::quick("sched-prediction", |rng, size| {
            let slots = 1 + rng.below(32);
            let reserve = 1 + rng.below(300);
            let cap = reserve + rng.below(4096);
            let n = 1 + size;
            let mut sched = mk(slots, reserve);
            let mut kv = KvMemoryManager::new(cap);
            let mut pending: Vec<usize> = (0..n).collect();
            let mut chunks = 0usize;
            let mut scheduled = 0usize;
            while !pending.is_empty() {
                match sched.next_chunk(&mut pending, &mut kv, 1000, &[]) {
                    Some(c) => {
                        chunks += 1;
                        scheduled += c.items.len();
                        // synchronous drain (static batching)
                        sched.finish_chunk(&c, &mut kv, 1000);
                    }
                    None => return Err("deadlock: nothing admissible".into()),
                }
                if chunks > n {
                    return Err("more chunks than sequences".into());
                }
            }
            if scheduled != n {
                return Err(format!("scheduled {scheduled} of {n}"));
            }
            if chunks != sched.predicted_chunks(n, cap) {
                return Err(format!(
                    "chunks {} != predicted {}",
                    chunks,
                    sched.predicted_chunks(n, cap)
                ));
            }
            if kv.reserved() != 0 {
                return Err("kv not fully released".into());
            }
            Ok(())
        });
    }

    #[test]
    fn stats_track_utilization() {
        let mut kv = KvMemoryManager::new(208 * 4);
        let mut s = mk(8, 208);
        let mut pending: Vec<usize> = (0..8).collect();
        let c = s.next_chunk(&mut pending, &mut kv, 0, &[]).unwrap();
        assert_eq!(c.items.len(), 4);
        assert!((s.stats.mean_slot_utilization() - 0.5).abs() < 1e-9);
        assert!((s.stats.mean_kv_utilization() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn seq_admission_respects_wall_and_counts_stalls() {
        let mut kv = KvMemoryManager::new(100);
        let mut s = mk(8, 40);
        assert!(s.try_admit(&mut kv, 1, 10));
        assert!(s.try_admit(&mut kv, 2, 10));
        // 80 of 100 reserved: a third does not fit
        assert!(!s.try_admit(&mut kv, 3, 10));
        assert_eq!(s.stats.admit_stalls, 1);
        assert_eq!(s.stats.live_seqs(), 2);
        assert_eq!(s.release_seq(&mut kv, 1).unwrap(), 40);
        assert!(s.try_admit(&mut kv, 3, 10));
        assert_eq!(s.stats.seq_admissions, 3);
    }

    #[test]
    fn paged_admission_charges_prompt_and_grows() {
        let mut kv = KvMemoryManager::with_pages(100, 10);
        let mut s = mk(8, 40).with_admission(AdmissionPolicy::Paged);
        // worst-case would admit 2 (40 each); paged admits 11-token
        // prompts (2 pages each) — 4 of them, keeping one page of growth
        // headroom once sequences are live
        for id in 1..=4 {
            assert!(s.try_admit(&mut kv, id, 10), "seq {id} refused");
        }
        assert_eq!(kv.used_pages(), 8);
        // 2 pages free but 2 needed + headroom: refused
        assert!(!s.try_admit(&mut kv, 5, 10));
        assert_eq!(s.stats.admit_stalls, 1);
        // growth can consume the headroom page by page
        assert!(s.grow(&mut kv, 1, 21).unwrap());
        assert!(s.grow(&mut kv, 2, 21).unwrap());
        assert_eq!(kv.free_pages(), 0);
        // pool exhausted: further growth stalls
        assert!(!s.grow(&mut kv, 3, 21).unwrap());
        assert_eq!(s.stats.grow_stalls, 1);
        // preempting a sequence frees pages for the grower
        assert_eq!(s.preempt(&mut kv, 4).unwrap(), 11);
        assert_eq!(s.stats.preemptions, 1);
        assert!(s.grow(&mut kv, 3, 21).unwrap());
        // compression shrink releases pages again
        assert!(s.compressed(&mut kv, 1, 5).unwrap());
        assert_eq!(kv.free_pages(), 3);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admit_headroom_gates_paged_admission() {
        // pool of 10 pages; 10-token prompts charge 11 tokens = 2 pages
        let mk_kv = || KvMemoryManager::with_pages(100, 10);
        // headroom 0: admissions pack flush against the wall (5 fit)
        let mut kv = mk_kv();
        let mut s0 = mk(8, 40).with_admission(AdmissionPolicy::Paged).with_headroom(0);
        for id in 1..=5 {
            assert!(s0.try_admit(&mut kv, id, 10), "seq {id} refused at headroom 0");
        }
        assert_eq!(kv.free_pages(), 0);
        // headroom 4: every admission must leave 4 free pages -> 3 fit
        let mut kv = mk_kv();
        let mut s4 = mk(8, 40).with_admission(AdmissionPolicy::Paged).with_headroom(4);
        for id in 1..=3 {
            assert!(s4.try_admit(&mut kv, id, 10), "seq {id} refused at headroom 4");
        }
        assert!(!s4.try_admit(&mut kv, 4, 10));
        assert_eq!(kv.free_pages(), 4);
        // empty-pool bypass: even huge headroom admits a first sequence
        // (progress guarantee), then gates the second
        let mut kv = mk_kv();
        let mut sb = mk(8, 40).with_admission(AdmissionPolicy::Paged).with_headroom(100);
        assert!(sb.try_admit(&mut kv, 1, 10));
        assert!(!sb.try_admit(&mut kv, 2, 10));
        // the default reproduces the original one-page rule
        assert_eq!(mk(8, 40).admit_headroom_pages, 1);
    }

    #[test]
    fn worst_case_grow_and_compressed_are_no_ops() {
        let mut kv = KvMemoryManager::new(100);
        let mut s = mk(4, 40);
        assert!(s.try_admit(&mut kv, 1, 10));
        assert_eq!(kv.reserved(), 40);
        assert!(s.grow(&mut kv, 1, 39).unwrap());
        assert!(s.compressed(&mut kv, 1, 5).unwrap());
        assert_eq!(kv.reserved(), 40, "worst-case reservation must not move");
        assert_eq!(s.stats.grow_stalls, 0);
    }

    #[test]
    fn double_release_is_an_error() {
        let mut kv = KvMemoryManager::new(100);
        let mut s = mk(4, 10);
        assert!(s.try_admit(&mut kv, 7, 10));
        assert!(s.release_seq(&mut kv, 7).is_ok());
        assert!(s.release_seq(&mut kv, 7).is_err(), "double release must fail");
        assert!(s.release_seq(&mut kv, 99).is_err(), "unknown id must fail");
        assert_eq!(s.stats.seq_releases, 1);
    }

    #[test]
    fn prop_seq_admission_never_deadlocks_or_leaks() {
        // Random interleavings of per-sequence admit/grow/release/preempt
        // under BOTH admission policies: admission must succeed iff the
        // wall has room for the policy's charge, reservations must
        // conserve (pages and tokens), and a full drain must always be
        // reachable (no deadlock).
        propcheck::quick("seq-admit-release", |rng, size| {
            let paged = rng.chance(0.5);
            let page = if paged { 1 + rng.below(8) } else { 1 };
            let reserve = 1 + rng.below(50);
            let cap = reserve * (1 + rng.below(8)) + rng.below(reserve);
            let mut s = mk(1 + rng.below(16), reserve);
            if paged {
                s = s.with_admission(AdmissionPolicy::Paged);
            }
            let mut kv = KvMemoryManager::with_pages(cap, page);
            // (id, reserved tokens)
            let mut live: Vec<(SeqId, usize)> = vec![];
            let mut next_id = 0u64;
            for _ in 0..(20 + size) {
                let op = if live.is_empty() { 0 } else { rng.below(4) };
                match op {
                    0 | 3 => {
                        next_id += 1;
                        let prompt = rng.below(reserve.max(1));
                        let want = s.admit_reserve(prompt);
                        // paged keeps one page of growth headroom while
                        // anything is live; worst-case fills the wall
                        let fits = if paged && kv.live_sequences() > 0 {
                            kv.pages_for(want) < kv.free_pages()
                        } else {
                            kv.pages_for(want) <= kv.free_pages()
                        };
                        let admitted = s.try_admit(&mut kv, next_id, prompt);
                        if admitted != fits {
                            return Err(format!(
                                "admit said {admitted}, wall said fits={fits} \
                                 (reserved {} of {cap})",
                                kv.reserved()
                            ));
                        }
                        if admitted {
                            live.push((next_id, want));
                        }
                    }
                    1 => {
                        // grow a random live sequence toward the bound
                        let k = rng.below(live.len());
                        let (id, cur) = live[k];
                        let target = (cur + 1 + rng.below(page * 2 + 1)).min(reserve);
                        let grown = s.grow(&mut kv, id, target).map_err(|e| e.to_string())?;
                        if grown {
                            live[k].1 = live[k].1.max(target);
                        } else if !paged {
                            return Err("worst-case grow stalled".into());
                        }
                    }
                    _ => {
                        let k = rng.below(live.len());
                        let (id, toks) = live.swap_remove(k);
                        let freed = if rng.chance(0.3) {
                            s.preempt(&mut kv, id).map_err(|e| e.to_string())?
                        } else {
                            s.release_seq(&mut kv, id).map_err(|e| e.to_string())?
                        };
                        if freed != toks {
                            return Err(format!("released {freed}, expected {toks}"));
                        }
                        // releasing twice must fail, not corrupt the pool
                        if s.release_seq(&mut kv, id).is_ok() {
                            return Err("double release accepted".into());
                        }
                    }
                }
                let expect: usize = live.iter().map(|(_, t)| t).sum();
                if kv.reserved() != expect {
                    return Err(format!("reservation leak: {} != {expect}", kv.reserved()));
                }
                if s.stats.live_seqs() != live.len() {
                    return Err("live_seqs out of sync".into());
                }
                kv.check_invariants().map_err(|e| e.to_string())?;
            }
            // no deadlock: a full drain + one admission always works
            for (id, _) in live.drain(..) {
                s.release_seq(&mut kv, id).map_err(|e| e.to_string())?;
            }
            if !s.try_admit(&mut kv, u64::MAX, 0) {
                return Err("empty wall refused admission".into());
            }
            Ok(())
        });
    }

    #[test]
    fn shared_admission_charges_prefix_once() {
        // page 4; 10-token prompts share an 8-token page-aligned prefix
        let mut kv = KvMemoryManager::with_pages(100, 4); // 25 pages
        let mut s = mk(8, 40)
            .with_admission(AdmissionPolicy::Paged)
            .with_sharing(PrefixSharing::Group);
        let prompt: Vec<i32> = (0..10).collect();
        // first sharer charges exactly the unshared admission: 11 tokens
        // = 8 prefix (2 pages) + 3 private (1 page)
        assert!(s.try_admit_prompt(&mut kv, 1, &prompt));
        assert_eq!(kv.used_pages(), 3);
        assert_eq!(s.stats.shared_admissions, 0);
        // siblings charge only their private page
        assert!(s.try_admit_prompt(&mut kv, 2, &prompt));
        assert!(s.try_admit_prompt(&mut kv, 3, &prompt));
        assert_eq!(kv.used_pages(), 5);
        assert_eq!(s.stats.shared_admissions, 2);
        assert_eq!(s.stats.seq_admissions, 3);
        // a different prompt gets its own prefix
        let other: Vec<i32> = (100..110).collect();
        assert!(s.try_admit_prompt(&mut kv, 4, &other));
        assert_eq!(kv.used_pages(), 8);
        assert_eq!(kv.live_prefixes(), 2);
        kv.check_invariants().unwrap();
        // releases drop the prefix with its last sharer
        for id in 1..=3 {
            s.release_seq(&mut kv, id).unwrap();
        }
        assert_eq!(kv.live_prefixes(), 1);
        s.release_seq(&mut kv, 4).unwrap();
        assert_eq!(kv.used_pages(), 0);
        // a drained prefix is simply re-charged fresh on its next use
        assert!(s.try_admit_prompt(&mut kv, 5, &prompt));
        assert_eq!(kv.used_pages(), 3);
        assert!(s.try_admit_prompt(&mut kv, 6, &prompt));
        assert_eq!(s.stats.shared_admissions, 3);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn sharing_off_or_worst_case_falls_back_to_plain_admission() {
        let prompt: Vec<i32> = (0..10).collect();
        // sharing off: try_admit_prompt IS try_admit
        let mut kv = KvMemoryManager::with_pages(100, 4);
        let mut s = mk(8, 40).with_admission(AdmissionPolicy::Paged);
        assert!(s.try_admit_prompt(&mut kv, 1, &prompt));
        assert!(s.try_admit_prompt(&mut kv, 2, &prompt));
        assert_eq!(kv.live_prefixes(), 0);
        assert_eq!(kv.used_pages(), 6, "both sequences pay full freight");
        // worst-case admission prices per sequence even with sharing on
        let mut kv = KvMemoryManager::new(100);
        let mut w = mk(8, 40).with_sharing(PrefixSharing::Group);
        assert!(w.try_admit_prompt(&mut kv, 1, &prompt));
        assert!(w.try_admit_prompt(&mut kv, 2, &prompt));
        assert_eq!(kv.live_prefixes(), 0);
        assert_eq!(kv.reserved(), 80);
        // sub-page prompts have no page-aligned prefix to share
        let mut kv = KvMemoryManager::with_pages(160, 16);
        let mut t = mk(8, 40)
            .with_admission(AdmissionPolicy::Paged)
            .with_sharing(PrefixSharing::Group);
        assert!(t.try_admit_prompt(&mut kv, 1, &prompt));
        assert_eq!(kv.live_prefixes(), 0);
    }

    #[test]
    fn compressed_forks_sharers_and_shrinks_loners() {
        let mut kv = KvMemoryManager::with_pages(100, 4); // 25 pages
        let mut s = mk(8, 40)
            .with_admission(AdmissionPolicy::Paged)
            .with_sharing(PrefixSharing::Group);
        let prompt: Vec<i32> = (0..10).collect();
        assert!(s.try_admit_prompt(&mut kv, 1, &prompt));
        assert!(s.try_admit_prompt(&mut kv, 2, &prompt));
        // compression on a sharer is a CoW fork to a private residency
        assert!(s.compressed(&mut kv, 1, 6).unwrap());
        assert_eq!(s.stats.cow_forks, 1);
        assert_eq!(kv.seq_prefix(1), None);
        assert_eq!(kv.prefix_refs(0), 1, "sibling still reads the prefix");
        kv.check_invariants().unwrap();
        // …after which compression shrinks in place like any loner
        assert!(s.compressed(&mut kv, 1, 4).unwrap());
        assert_eq!(s.stats.cow_forks, 1);
        kv.check_invariants().unwrap();
        // a fork that cannot fit reports a grow stall, not an error
        let mut kv = KvMemoryManager::with_pages(20, 4); // 5 pages
        let mut s = mk(8, 40)
            .with_admission(AdmissionPolicy::Paged)
            .with_sharing(PrefixSharing::Group);
        assert!(s.try_admit_prompt(&mut kv, 1, &prompt)); // 3 pages
        assert!(s.try_admit_prompt(&mut kv, 2, &prompt)); // +1 page
        // forking seq 2 to 16 tokens needs 4 pages; 1 free + 1 own = 2
        assert!(!s.compressed(&mut kv, 2, 16).unwrap());
        assert_eq!(s.stats.grow_stalls, 1);
        assert_eq!(s.stats.cow_forks, 0);
        assert_eq!(kv.seq_prefix(2), Some(0), "denied fork left state alone");
        kv.check_invariants().unwrap();
    }

    #[test]
    fn predicted_decode_steps_closed_forms() {
        // width 2, queue costs (len-1) = [4, 1, 1, 1]:
        // slot recycling packs the three short ones behind each other
        let s = mk(2, 10);
        assert_eq!(s.predicted_decode_steps(&[5, 2, 2, 2], 1000), 4);
        // static chunks [5,2],[2,2]: (5-1) + (2-1)
        assert_eq!(s.predicted_decode_steps_static(&[5, 2, 2, 2], 1000), 5);
        // KV-limited to width 1: both degenerate to the serial sum
        assert_eq!(s.predicted_decode_steps(&[5, 2, 2, 2], 10), 7);
        assert_eq!(s.predicted_decode_steps_static(&[5, 2, 2, 2], 10), 7);
        // uniform lengths: continuous gains nothing
        assert_eq!(
            s.predicted_decode_steps(&[4, 4, 4, 4], 1000),
            s.predicted_decode_steps_static(&[4, 4, 4, 4], 1000)
        );
        // single-token sequences cost zero decode steps
        assert_eq!(s.predicted_decode_steps(&[1, 1, 1], 1000), 0);
        assert_eq!(s.predicted_decode_steps(&[], 1000), 0);
        // the width model: a tighter per-seq reservation widens the batch
        let wide = mk(8, 100);
        assert!(
            wide.predicted_decode_steps_with(&[9; 16], 300, 30)
                < wide.predicted_decode_steps_with(&[9; 16], 300, 100)
        );
    }

    #[test]
    fn pick_next_orders_by_admission_cost() {
        let fifo = mk(4, 100);
        let sjf = mk(4, 100).with_order(AdmissionOrder::ShortestFirst);
        // cost indexed by TASK position; queue holds task positions
        let cost = vec![80usize, 20, 50, 20];
        let queue: VecDeque<usize> = vec![0, 1, 2, 3].into();
        assert_eq!(fifo.pick_next(&queue, &cost), Some(0));
        // shortest-first: task 1 (cost 20) wins; the tie with task 3
        // breaks toward the earlier queue position (stable)
        assert_eq!(sjf.pick_next(&queue, &cost), Some(1));
        let queue: VecDeque<usize> = vec![3, 0, 1].into();
        assert_eq!(sjf.pick_next(&queue, &cost), Some(0), "task 3 at qi 0");
        let empty: VecDeque<usize> = VecDeque::new();
        assert_eq!(fifo.pick_next(&empty, &cost), None);
        assert_eq!(sjf.pick_next(&empty, &cost), None);
        // reservation oracle caps at the per-seq bound; the ordering key
        // does not, so cap-tied tasks still order by prompt size
        assert_eq!(sjf.predicted_residency(10, 20), 31);
        assert_eq!(sjf.predicted_residency(90, 20), 100);
        assert_eq!(sjf.admission_cost(10, 20), 31);
        assert_eq!(sjf.admission_cost(90, 20), 111);
        assert!(sjf.admission_cost(80, 20) < sjf.admission_cost(90, 20));
    }

    /// The reference pop: `pick_next` over a plain deque (the pre-index
    /// semantics the sorted AdmissionQueue must reproduce exactly).
    fn reference_pop(sched: &Scheduler, q: &mut VecDeque<usize>, cost: &[usize]) -> Option<usize> {
        let qi = sched.pick_next(q, cost)?;
        let pos = q[qi];
        q.remove(qi);
        Some(pos)
    }

    #[test]
    fn admission_queue_pins_stable_first_min_tie_break() {
        // costs by task position: three cost-3 ties (tasks 1, 2, 3)
        let cost = vec![5usize, 3, 3, 3, 5, 1];
        let mut q = AdmissionQueue::new(AdmissionOrder::ShortestFirst, cost.clone());
        assert_eq!(q.len(), 6);
        // global min first, then the tie group in queue order
        assert_eq!(q.peek(), Some(5));
        assert_eq!(q.pop(), Some(5));
        assert_eq!(q.pop(), Some(1), "first of the cost-3 tie group");
        // a preempted task requeued at the head wins its tie group again
        q.push_front(1);
        assert_eq!(q.pop(), Some(1), "push_front must win equal-cost ties");
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), Some(0), "cost-5 ties keep original queue order");
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());

        // fifo mode ignores costs entirely
        let mut f = AdmissionQueue::new(AdmissionOrder::Fifo, cost);
        f.push_front(4);
        assert_eq!(f.pop(), Some(4));
        assert_eq!(f.pop(), Some(0));
        assert_eq!(f.pop(), Some(1));
    }

    #[test]
    fn prop_admission_queue_matches_pick_next_reference() {
        // Random pop / push_front traffic (the only operations the
        // engines perform) over heavily tied cost vectors: the sorted
        // index must emit exactly the reference scan's pick sequence, in
        // both admission orders.
        propcheck::quick("admission-queue-oracle", |rng, size| {
            let n = 1 + rng.below(4 + size);
            // few distinct costs -> many ties -> the tie-break is what's
            // actually under test
            let cost: Vec<usize> = (0..n).map(|_| rng.below(4)).collect();
            for order in [AdmissionOrder::Fifo, AdmissionOrder::ShortestFirst] {
                let sched = mk(4, 100).with_order(order);
                let mut q = AdmissionQueue::new(order, cost.clone());
                let mut reference: VecDeque<usize> = (0..n).collect();
                let mut popped: Vec<usize> = Vec::new();
                for _ in 0..(2 * n + 10) {
                    if !popped.is_empty() && rng.chance(0.3) {
                        // requeue a random previously-popped task (the
                        // preemption path)
                        let pos = popped.swap_remove(rng.below(popped.len()));
                        q.push_front(pos);
                        reference.push_front(pos);
                    } else {
                        let got = q.pop();
                        let want = reference_pop(&sched, &mut reference, &cost);
                        if got != want {
                            return Err(format!(
                                "{}: index popped {got:?}, reference {want:?} (cost {cost:?})",
                                order.label()
                            ));
                        }
                        if let Some(pos) = got {
                            popped.push(pos);
                        }
                    }
                    if q.len() != reference.len() {
                        return Err(format!(
                            "len diverged: index {} vs reference {}",
                            q.len(),
                            reference.len()
                        ));
                    }
                }
                // full drain must also agree
                while let Some(want) = reference_pop(&sched, &mut reference, &cost) {
                    if q.pop() != Some(want) {
                        return Err("drain order diverged".into());
                    }
                }
                if q.pop().is_some() {
                    return Err("index longer than reference".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn width_paged_tracks_mean_residency() {
        let s = mk(8, 160);
        let kv = KvMemoryManager::with_pages(480, 16);
        // worst case: 480/160 = 3 wide; paged at mean residency 80: 6 wide
        assert_eq!(s.width_paged(&kv, 160), 3);
        assert_eq!(s.width_paged(&kv, 80), 6);
        assert_eq!(s.width_paged(&kv, 10), 8, "slot-capped");
    }

    #[test]
    fn continuous_never_worse_than_static_prediction() {
        propcheck::quick("continuous-leq-static", |rng, size| {
            let s = mk(1 + rng.below(8), 1 + rng.below(64));
            let cap = 1 + rng.below(512);
            let lens: Vec<usize> = (0..1 + size).map(|_| 1 + rng.below(40)).collect();
            let c = s.predicted_decode_steps(&lens, cap);
            let st = s.predicted_decode_steps_static(&lens, cap);
            if c > st {
                return Err(format!("continuous {c} > static {st} for {lens:?}"));
            }
            Ok(())
        });
    }
}
