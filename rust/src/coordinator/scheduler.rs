//! Memory-aware rollout scheduler.
//!
//! Packs pending prompts into the decode batch subject to the KV memory
//! wall: every admitted sequence first reserves its worst-case residency
//! with the `KvMemoryManager` (dense: `max_seq`; sparse: `budget+buffer`).
//! The decode artifact is compiled for a fixed slot width R, so admission
//! is bounded by `min(R, admissible, pending)` — the admissible term is
//! exactly where dense rollouts lose throughput (paper §1: "rollout batch
//! sizes must be constrained" to dodge long-tail OOM).
//!
//! Two admission granularities serve the two rollout engines:
//!
//! * **Chunk-level** (`next_chunk` / `finish_chunk`, static engine): a
//!   whole chunk reserves together and releases together when the slowest
//!   sequence in it finishes. Simple, but every early finisher's KV stays
//!   reserved (and its decode slot idles) until the chunk drains.
//! * **Sequence-level** (`try_admit` / `release_seq`, continuous engine):
//!   each sequence reserves on admission and releases the moment it
//!   finishes, letting the engine refill the freed slot immediately. The
//!   closed-form `predicted_decode_steps` models the resulting schedule
//!   (greedy earliest-free-slot, queue order) so benches and property
//!   tests can check the engine step-for-step.

use crate::runtime::Manifest;

use super::kv_manager::{KvMemoryManager, SeqId};

/// One scheduled chunk: which pending items occupy which decode slots.
#[derive(Debug, Clone)]
pub struct Chunk {
    /// Indices into the pending queue, one per occupied slot (slot i of
    /// the decode batch holds pending[task_of_slot[i]]).
    pub items: Vec<usize>,
    /// Reservation per sequence this chunk was admitted with.
    pub reserve_per_seq: usize,
}

/// Scheduling statistics for the utilization benches.
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedulerStats {
    pub chunks: usize,
    pub scheduled_seqs: usize,
    /// Σ over chunks of occupied slots / R (decode-slot utilization).
    pub slot_utilization_sum: f64,
    /// Σ over chunks of reserved KV / capacity at admission time.
    pub kv_utilization_sum: f64,
    /// Sequence-level admissions (continuous engine).
    pub seq_admissions: usize,
    /// Sequence-level releases (continuous engine).
    pub seq_releases: usize,
    /// Admission attempts refused by the memory wall (continuous engine:
    /// a freed slot had to idle because no KV could be reserved).
    pub admit_stalls: usize,
}

impl SchedulerStats {
    pub fn mean_slot_utilization(&self) -> f64 {
        if self.chunks == 0 {
            0.0
        } else {
            self.slot_utilization_sum / self.chunks as f64
        }
    }

    pub fn mean_kv_utilization(&self) -> f64 {
        if self.chunks == 0 {
            0.0
        } else {
            self.kv_utilization_sum / self.chunks as f64
        }
    }

    /// Sequences currently admitted and not yet released.
    pub fn live_seqs(&self) -> usize {
        self.seq_admissions - self.seq_releases
    }
}

/// Plans admissions over a queue of pending sequences.
pub struct Scheduler {
    /// Decode slot width (from the manifest).
    pub slots: usize,
    /// Worst-case KV tokens one sequence may hold.
    pub reserve_per_seq: usize,
    pub stats: SchedulerStats,
}

impl Scheduler {
    /// `sparse` selects the reservation bound (the whole memory-wall
    /// story is this one line: capacity-bounded vs length-bounded).
    pub fn new(manifest: &Manifest, sparse: bool) -> Self {
        let reserve = if sparse {
            manifest.shapes.sparse_capacity
        } else {
            manifest.config.max_seq
        };
        Scheduler {
            slots: manifest.shapes.decode_batch,
            reserve_per_seq: reserve,
            stats: SchedulerStats::default(),
        }
    }

    /// Admit the next chunk from `pending` (indices not yet scheduled).
    /// Reserves KV for every admitted sequence; returns None when nothing
    /// can be admitted (caller should drain running chunks first).
    pub fn next_chunk(
        &mut self,
        pending: &mut Vec<usize>,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
    ) -> Option<Chunk> {
        if pending.is_empty() {
            return None;
        }
        let width = self
            .slots
            .min(kv.admissible(self.reserve_per_seq))
            .min(pending.len());
        if width == 0 {
            return None;
        }
        let items: Vec<usize> = pending.drain(..width).collect();
        for (slot, _) in items.iter().enumerate() {
            kv.reserve(seq_id_base + slot as u64, self.reserve_per_seq)
                .expect("admissible() guaranteed room");
        }
        self.stats.chunks += 1;
        self.stats.scheduled_seqs += width;
        self.stats.slot_utilization_sum += width as f64 / self.slots as f64;
        self.stats.kv_utilization_sum += kv.utilization();
        Some(Chunk { items, reserve_per_seq: self.reserve_per_seq })
    }

    /// Release a finished chunk's reservations.
    pub fn finish_chunk(&mut self, chunk: &Chunk, kv: &mut KvMemoryManager, seq_id_base: u64) {
        for slot in 0..chunk.items.len() {
            kv.release(seq_id_base + slot as u64).expect("reservation exists");
        }
    }

    /// Sequence-level admission (continuous engine): reserve this
    /// sequence's worst-case KV, or refuse without side effects beyond the
    /// stall counter when the wall is full. Refusal is not an error — the
    /// engine keeps decoding and retries after the next release.
    pub fn try_admit(&mut self, kv: &mut KvMemoryManager, seq: SeqId) -> bool {
        if kv.admissible(self.reserve_per_seq) == 0 {
            self.stats.admit_stalls += 1;
            return false;
        }
        kv.reserve(seq, self.reserve_per_seq)
            .expect("admissible() guaranteed room");
        self.stats.seq_admissions += 1;
        true
    }

    /// Sequence-level release (continuous engine): frees the reservation
    /// the moment the sequence finishes. Double-release (or releasing a
    /// never-admitted id) is an error — the invariant tests rely on it.
    pub fn release_seq(
        &mut self,
        kv: &mut KvMemoryManager,
        seq: SeqId,
    ) -> anyhow::Result<usize> {
        let tokens = kv.release(seq)?;
        self.stats.seq_releases += 1;
        Ok(tokens)
    }

    /// Number of chunks needed for `n` sequences on an idle manager —
    /// the closed-form the throughput benches check against.
    pub fn predicted_chunks(&self, n: usize, kv_capacity: usize) -> usize {
        let width = self.slots.min(kv_capacity / self.reserve_per_seq.max(1)).max(1);
        n.div_ceil(width)
    }

    /// Decode steps the continuous engine needs for sequences whose
    /// response lengths are `response_lens` (queue order), on an idle
    /// manager of `kv_capacity`: the list-scheduling makespan of the
    /// per-sequence decode costs over the effective width.
    ///
    /// A sequence generating L tokens occupies its slot for L-1 decode
    /// steps (the first token comes from prefill logits; the last token is
    /// sampled and the slot is recycled before the next decode). Greedy
    /// earliest-free-slot assignment in queue order is exactly what slot
    /// recycling does, so this is step-exact, and the property tests hold
    /// the engine to it.
    pub fn predicted_decode_steps(&self, response_lens: &[usize], kv_capacity: usize) -> usize {
        if response_lens.is_empty() {
            return 0;
        }
        let width = self
            .slots
            .min(kv_capacity / self.reserve_per_seq.max(1))
            .max(1)
            .min(response_lens.len());
        let mut busy = vec![0usize; width];
        for &len in response_lens {
            let i = (0..width).min_by_key(|&i| busy[i]).expect("width >= 1");
            busy[i] += len.saturating_sub(1);
        }
        busy.into_iter().max().unwrap_or(0)
    }

    /// Decode steps the static engine needs for the same queue: each chunk
    /// runs to its slowest member, so the total is Σ over chunks of
    /// (max chunk length - 1).
    pub fn predicted_decode_steps_static(
        &self,
        response_lens: &[usize],
        kv_capacity: usize,
    ) -> usize {
        let width = self
            .slots
            .min(kv_capacity / self.reserve_per_seq.max(1))
            .max(1);
        response_lens
            .chunks(width)
            .map(|c| c.iter().max().copied().unwrap_or(0).saturating_sub(1))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    fn fake_manifest(slots: usize, max_seq: usize, sparse_cap: usize) -> (usize, usize, usize) {
        // Scheduler only reads three numbers; tests construct it directly.
        (slots, max_seq, sparse_cap)
    }

    fn mk(slots: usize, reserve: usize) -> Scheduler {
        Scheduler { slots, reserve_per_seq: reserve, stats: SchedulerStats::default() }
    }

    #[test]
    fn dense_is_memory_limited_sparse_is_slot_limited() {
        let (slots, max_seq, sparse_cap) = fake_manifest(16, 208, 48);
        let mut kv = KvMemoryManager::new(2048);
        let mut dense = mk(slots, max_seq);
        let mut pending: Vec<usize> = (0..16).collect();
        let c = dense.next_chunk(&mut pending, &mut kv, 0).unwrap();
        assert_eq!(c.items.len(), 9); // 2048 / 208
        dense.finish_chunk(&c, &mut kv, 0);
        assert_eq!(kv.reserved(), 0);

        let mut sparse = mk(slots, sparse_cap);
        let mut pending: Vec<usize> = (0..64).collect();
        let c = sparse.next_chunk(&mut pending, &mut kv, 100).unwrap();
        assert_eq!(c.items.len(), 16); // slot-limited, not memory-limited
        sparse.finish_chunk(&c, &mut kv, 100);
    }

    #[test]
    fn predicted_chunks_match_actual() {
        propcheck::quick("sched-prediction", |rng, size| {
            let slots = 1 + rng.below(32);
            let reserve = 1 + rng.below(300);
            let cap = reserve + rng.below(4096);
            let n = 1 + size;
            let mut sched = mk(slots, reserve);
            let mut kv = KvMemoryManager::new(cap);
            let mut pending: Vec<usize> = (0..n).collect();
            let mut chunks = 0usize;
            let mut scheduled = 0usize;
            while !pending.is_empty() {
                match sched.next_chunk(&mut pending, &mut kv, 1000) {
                    Some(c) => {
                        chunks += 1;
                        scheduled += c.items.len();
                        // synchronous drain (static batching)
                        sched.finish_chunk(&c, &mut kv, 1000);
                    }
                    None => return Err("deadlock: nothing admissible".into()),
                }
                if chunks > n {
                    return Err("more chunks than sequences".into());
                }
            }
            if scheduled != n {
                return Err(format!("scheduled {scheduled} of {n}"));
            }
            if chunks != sched.predicted_chunks(n, cap) {
                return Err(format!(
                    "chunks {} != predicted {}",
                    chunks,
                    sched.predicted_chunks(n, cap)
                ));
            }
            if kv.reserved() != 0 {
                return Err("kv not fully released".into());
            }
            Ok(())
        });
    }

    #[test]
    fn stats_track_utilization() {
        let mut kv = KvMemoryManager::new(208 * 4);
        let mut s = mk(8, 208);
        let mut pending: Vec<usize> = (0..8).collect();
        let c = s.next_chunk(&mut pending, &mut kv, 0).unwrap();
        assert_eq!(c.items.len(), 4);
        assert!((s.stats.mean_slot_utilization() - 0.5).abs() < 1e-9);
        assert!((s.stats.mean_kv_utilization() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn seq_admission_respects_wall_and_counts_stalls() {
        let mut kv = KvMemoryManager::new(100);
        let mut s = mk(8, 40);
        assert!(s.try_admit(&mut kv, 1));
        assert!(s.try_admit(&mut kv, 2));
        // 80 of 100 reserved: a third does not fit
        assert!(!s.try_admit(&mut kv, 3));
        assert_eq!(s.stats.admit_stalls, 1);
        assert_eq!(s.stats.live_seqs(), 2);
        assert_eq!(s.release_seq(&mut kv, 1).unwrap(), 40);
        assert!(s.try_admit(&mut kv, 3));
        assert_eq!(s.stats.seq_admissions, 3);
    }

    #[test]
    fn double_release_is_an_error() {
        let mut kv = KvMemoryManager::new(100);
        let mut s = mk(4, 10);
        assert!(s.try_admit(&mut kv, 7));
        assert!(s.release_seq(&mut kv, 7).is_ok());
        assert!(s.release_seq(&mut kv, 7).is_err(), "double release must fail");
        assert!(s.release_seq(&mut kv, 99).is_err(), "unknown id must fail");
        assert_eq!(s.stats.seq_releases, 1);
    }

    #[test]
    fn prop_seq_admission_never_deadlocks_or_leaks() {
        // Random interleavings of per-sequence admit/release: admission
        // must succeed iff the wall has room, reservations must conserve,
        // and a full drain must always be reachable (no deadlock).
        propcheck::quick("seq-admit-release", |rng, size| {
            let reserve = 1 + rng.below(50);
            let cap = reserve * (1 + rng.below(8)) + rng.below(reserve);
            let mut s = mk(1 + rng.below(16), reserve);
            let mut kv = KvMemoryManager::new(cap);
            let mut live: Vec<SeqId> = vec![];
            let mut next_id = 0u64;
            for _ in 0..(20 + size) {
                if rng.chance(0.55) || live.is_empty() {
                    next_id += 1;
                    let fits = kv.available() >= reserve;
                    let admitted = s.try_admit(&mut kv, next_id);
                    if admitted != fits {
                        return Err(format!(
                            "admit said {admitted}, wall said fits={fits} \
                             (reserved {} of {cap})",
                            kv.reserved()
                        ));
                    }
                    if admitted {
                        live.push(next_id);
                    }
                } else {
                    let k = rng.below(live.len());
                    let id = live.swap_remove(k);
                    s.release_seq(&mut kv, id).map_err(|e| e.to_string())?;
                    // releasing twice must fail, not corrupt the pool
                    if s.release_seq(&mut kv, id).is_ok() {
                        return Err("double release accepted".into());
                    }
                }
                if kv.reserved() != live.len() * reserve {
                    return Err("reservation leak".into());
                }
                kv.check_invariants().map_err(|e| e.to_string())?;
            }
            // no deadlock: a full drain + one admission always works
            for id in live.drain(..) {
                s.release_seq(&mut kv, id).map_err(|e| e.to_string())?;
            }
            if !s.try_admit(&mut kv, u64::MAX) {
                return Err("empty wall refused admission".into());
            }
            Ok(())
        });
    }

    #[test]
    fn predicted_decode_steps_closed_forms() {
        // width 2, queue costs (len-1) = [4, 1, 1, 1]:
        // slot recycling packs the three short ones behind each other
        let s = mk(2, 10);
        assert_eq!(s.predicted_decode_steps(&[5, 2, 2, 2], 1000), 4);
        // static chunks [5,2],[2,2]: (5-1) + (2-1)
        assert_eq!(s.predicted_decode_steps_static(&[5, 2, 2, 2], 1000), 5);
        // KV-limited to width 1: both degenerate to the serial sum
        assert_eq!(s.predicted_decode_steps(&[5, 2, 2, 2], 10), 7);
        assert_eq!(s.predicted_decode_steps_static(&[5, 2, 2, 2], 10), 7);
        // uniform lengths: continuous gains nothing
        assert_eq!(
            s.predicted_decode_steps(&[4, 4, 4, 4], 1000),
            s.predicted_decode_steps_static(&[4, 4, 4, 4], 1000)
        );
        // single-token sequences cost zero decode steps
        assert_eq!(s.predicted_decode_steps(&[1, 1, 1], 1000), 0);
        assert_eq!(s.predicted_decode_steps(&[], 1000), 0);
    }

    #[test]
    fn continuous_never_worse_than_static_prediction() {
        propcheck::quick("continuous-leq-static", |rng, size| {
            let s = mk(1 + rng.below(8), 1 + rng.below(64));
            let cap = 1 + rng.below(512);
            let lens: Vec<usize> = (0..1 + size).map(|_| 1 + rng.below(40)).collect();
            let c = s.predicted_decode_steps(&lens, cap);
            let st = s.predicted_decode_steps_static(&lens, cap);
            if c > st {
                return Err(format!("continuous {c} > static {st} for {lens:?}"));
            }
            Ok(())
        });
    }
}
