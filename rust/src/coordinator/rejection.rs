//! Sparsity-Aware Rejection Sampling (paper §4.2, Eq. 5-6).
//!
//! Per-token sparsity consistency ratio
//!     ξ_t = π_old(o_t | x, o_<t) / π_sparse(o_t | x, o_<t)
//! computed from the dense teacher-forcing log-probs (score artifact) and
//! the sampler log-probs recorded during the sparse rollout. A trajectory
//! is rejected (M^RS = 0) iff any generated token has ξ_t < ε: a single
//! support-mismatch token (a hallucination the dense policy would never
//! produce) invalidates the whole chain of thought.

/// Per-sequence rejection verdict + diagnostics.
#[derive(Debug, Clone)]
pub struct RejectionVerdict {
    /// M^RS ∈ {0, 1} (Eq. 6).
    pub accept: bool,
    /// min_t ξ_t over the response.
    pub min_xi: f64,
    /// Index (within the response) of the offending token, if rejected.
    pub first_bad: Option<usize>,
}

/// Compute ξ_t for one response.
///
/// `logp_old[t]` and `logp_sparse[t]` are log-probs of the *same* sampled
/// token o_t under the dense old policy and the sparse sampler policy.
pub fn xi_ratios(logp_old: &[f32], logp_sparse: &[f32]) -> Vec<f64> {
    debug_assert_eq!(logp_old.len(), logp_sparse.len());
    logp_old
        .iter()
        .zip(logp_sparse.iter())
        .map(|(&o, &s)| ((o as f64) - (s as f64)).exp())
        .collect()
}

/// Sequence-level rejection weight M^RS (Eq. 6).
///
/// A non-finite ξ_t (NaN from a non-finite log-prob upstream, or ±inf
/// from a degenerate difference) is treated as a support mismatch and
/// rejects the trajectory. NaN in particular compares false against every
/// threshold, so an unguarded `x < eps` used to silently *accept* exactly
/// the trajectories whose correction math had already broken down.
pub fn verdict(xi: &[f64], eps: f64) -> RejectionVerdict {
    let mut min_xi = f64::INFINITY;
    let mut first_bad = None;
    for (t, &x) in xi.iter().enumerate() {
        if x.is_finite() && x < min_xi {
            min_xi = x;
        }
        if (!x.is_finite() || x < eps) && first_bad.is_none() {
            first_bad = Some(t);
        }
    }
    if xi.is_empty() {
        min_xi = 1.0;
    } else if min_xi == f64::INFINITY {
        // no finite ratio at all: total support failure
        min_xi = 0.0;
    }
    RejectionVerdict { accept: first_bad.is_none(), min_xi, first_bad }
}

/// Batch statistics of the filter (Fig. 5: rejection-rate dynamics).
#[derive(Debug, Clone, Copy, Default)]
pub struct RejectionStats {
    pub total: usize,
    pub rejected: usize,
}

impl RejectionStats {
    pub fn rate(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.rejected as f64 / self.total as f64
        }
    }

    pub fn record(&mut self, v: &RejectionVerdict) {
        self.total += 1;
        if !v.accept {
            self.rejected += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn consistent_tokens_accepted() {
        // ξ ≈ 1 everywhere
        let xi = xi_ratios(&[-1.0, -2.0, -0.5], &[-1.0, -2.0, -0.5]);
        let v = verdict(&xi, 1e-4);
        assert!(v.accept);
        assert!((v.min_xi - 1.0).abs() < 1e-9);
    }

    #[test]
    fn single_support_mismatch_rejects() {
        // token 1: dense says -15 nats, sparse sampled it at -1 -> ξ ~ 8e-7
        let xi = xi_ratios(&[-1.0, -15.0, -0.5], &[-1.0, -1.0, -0.5]);
        let v = verdict(&xi, 1e-4);
        assert!(!v.accept);
        assert_eq!(v.first_bad, Some(1));
    }

    #[test]
    fn empty_response_accepted() {
        let v = verdict(&[], 1e-4);
        assert!(v.accept);
    }

    #[test]
    fn non_finite_xi_is_a_support_mismatch() {
        // regression: NaN compares false against eps AND min_xi, so a NaN
        // ξ used to be accepted with min_xi untouched
        let v = verdict(&[1.0, f64::NAN, 0.9], 1e-4);
        assert!(!v.accept, "NaN ξ must reject");
        assert_eq!(v.first_bad, Some(1));
        assert!((v.min_xi - 0.9).abs() < 1e-12, "min over finite entries");

        let v = verdict(&[f64::INFINITY, 1.0], 1e-4);
        assert!(!v.accept, "infinite ξ must reject");
        assert_eq!(v.first_bad, Some(0));

        // all non-finite: reject with a well-defined (zero-support) min
        let v = verdict(&[f64::NAN, f64::NAN], 1e-4);
        assert!(!v.accept);
        assert_eq!(v.min_xi, 0.0);
        assert!(!v.min_xi.is_nan());

        // a NaN log-prob pair produces NaN ξ end to end
        let xi = xi_ratios(&[f32::NAN, -1.0], &[-1.0, -1.0]);
        assert!(xi[0].is_nan());
        assert!(!verdict(&xi, 1e-4).accept);
    }

    #[test]
    fn prop_rejection_iff_min_below_eps() {
        propcheck::quick("rejection-iff", |rng, size| {
            let n = 1 + size % 60;
            let logp_sparse: Vec<f32> = (0..n).map(|_| -(rng.next_f32() * 5.0)).collect();
            let logp_old: Vec<f32> = logp_sparse
                .iter()
                .map(|&s| s + (rng.next_f32() - 0.6) * 12.0)
                .collect();
            let eps = 1e-4;
            let xi = xi_ratios(&logp_old, &logp_sparse);
            let v = verdict(&xi, eps);
            let has_bad = xi.iter().any(|&x| x < eps);
            if v.accept == has_bad {
                return Err(format!("accept={} but has_bad={}", v.accept, has_bad));
            }
            if (v.min_xi - xi.iter().cloned().fold(f64::INFINITY, f64::min)).abs() > 1e-12 {
                return Err("min_xi mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn stats_rate() {
        let mut s = RejectionStats::default();
        s.record(&verdict(&[1.0], 1e-4));
        s.record(&verdict(&[1e-6], 1e-4));
        s.record(&verdict(&[0.9], 1e-4));
        assert!((s.rate() - 1.0 / 3.0).abs() < 1e-9);
    }
}
