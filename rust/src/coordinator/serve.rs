//! Streaming serving front-end: SLO-aware admission over the session
//! rollout API.
//!
//! The serve loop is a long-lived, round-based service over the existing
//! engine stack. Requests arrive on a deterministic virtual-clock trace
//! (`ServeRequest { task, arrival, deadline, priority }`); each round the
//! server pulls the due arrivals, runs the admission controller, and
//! dispatches the admitted batch through the exact rollout shells the
//! trainer uses (`RolloutCtx` + the engine entry points — static,
//! continuous, or pipelined, chunked prefill and all). Per-request tokens
//! stream out of the decode core through a [`StreamHub`], stamped with
//! the engine's virtual clock, which is what makes TTFT / inter-token /
//! end-to-end latency hermetically assertable on the mock backend.
//!
//! Admission (`serve-admission` knob):
//!
//! * `slo`  — the modeled-makespan oracle as an admission controller: a
//!   request is admitted iff its predicted cost
//!   ([`Scheduler::predicted_cost_ticks`], the same
//!   residency × admission-cost product the fleet router load-balances
//!   by) fits before its deadline; otherwise it is shed immediately with
//!   a reject-with-estimate ([`ServeOutcome::Shed`] carries the modeled
//!   completion tick the client would have seen). Under overload the
//!   queue therefore never collapses — late work is refused up front
//!   instead of rotting in the queue and dragging every later request
//!   past its own deadline. Dispatch order within a round is
//!   [`Scheduler::pick_next_deadline`] (EDF, cost tie-break).
//! * `fifo` — the no-controller baseline: everything is admitted in
//!   arrival order and the tail latency lands where it lands. Kept as
//!   the comparison arm for the serving bench.
//!
//! Tokens are serve-invariant: per-task RNG keys off (seed, request
//! index), so an admitted request streams exactly the tokens a
//! closed-batch rollout of the same trace would produce — round
//! composition, admission policy, and shedding change latency only.

use std::collections::VecDeque;
use std::sync::mpsc::Receiver;

use anyhow::{bail, Result};

use crate::config::{EngineKind, ServeConfig};
use crate::data::task::Task;

use super::backend::RolloutBackend;
use super::engine::{
    LatencyHistogram, RolloutCtx, RolloutPolicy, RolloutStats, StreamHub, TokenEvent,
};
use super::kv_manager::KvMemoryManager;
use super::scheduler::Scheduler;

/// One serving request: a task plus its arrival and service-level terms,
/// all in virtual-clock ticks (the mock cost model's unit; zero-cost on
/// real backends, where the trace degenerates to batch order).
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub task: Task,
    /// Virtual tick the request becomes visible to the server.
    pub arrival_tick: u64,
    /// Absolute completion deadline (`u64::MAX` = no SLO — never shed).
    pub deadline_tick: u64,
    /// Dispatch priority: higher dispatches first among equal deadlines
    /// and costs (the serve queue is priority-ordered before the
    /// deadline picker's stable queue-order tie-break applies).
    pub priority: u32,
}

impl ServeRequest {
    pub fn new(task: Task, arrival_tick: u64) -> ServeRequest {
        ServeRequest { task, arrival_tick, deadline_tick: u64::MAX, priority: 0 }
    }

    /// Set an absolute completion deadline (builder style).
    pub fn with_deadline(mut self, deadline_tick: u64) -> Self {
        self.deadline_tick = deadline_tick;
        self
    }

    /// Set the dispatch priority (builder style).
    pub fn with_priority(mut self, priority: u32) -> Self {
        self.priority = priority;
        self
    }
}

/// Why a request was shed instead of served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The admission oracle predicted the deadline cannot be met.
    Deadline,
    /// The bounded pending queue (`serve-queue-depth`) was full on
    /// arrival.
    QueueFull,
}

/// Per-request terminal state. Every request in the trace gets exactly
/// one outcome; latencies are virtual-clock ticks measured from the
/// request's arrival.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeOutcome {
    Completed {
        /// The streamed response (identical to the closed-batch tokens).
        response: Vec<i32>,
        /// Arrival → first streamed token.
        ttft_ticks: u64,
        /// Arrival → last streamed token.
        e2e_ticks: u64,
    },
    /// Reject-with-estimate: the server refused the request and told the
    /// client what the model predicted — the admission cost it would
    /// have charged and the tick it would have completed at.
    Shed {
        reason: ShedReason,
        predicted_cost_ticks: u64,
        predicted_done_tick: u64,
    },
}

impl ServeOutcome {
    pub fn is_shed(&self) -> bool {
        matches!(self, ServeOutcome::Shed { .. })
    }
}

/// Everything one serve run produced: per-request outcomes (indexed like
/// the input trace), the three live latency histograms, and the merged
/// engine stats underneath.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub outcomes: Vec<ServeOutcome>,
    /// Time-to-first-token over completed requests.
    pub ttft: LatencyHistogram,
    /// Gaps between consecutive streamed tokens of one request.
    pub inter_token: LatencyHistogram,
    /// Arrival → last token over completed requests.
    pub e2e: LatencyHistogram,
    /// Dispatch rounds the trace took.
    pub rounds: usize,
    /// Virtual clock when the last round finished.
    pub makespan_ticks: u64,
    /// Serial merge of every round's rollout stats.
    pub stats: RolloutStats,
}

impl ServeReport {
    pub fn completed(&self) -> usize {
        self.outcomes.iter().filter(|o| !o.is_shed()).count()
    }

    pub fn shed(&self) -> usize {
        self.outcomes.iter().filter(|o| o.is_shed()).count()
    }
}

/// Build the deterministic open-loop arrival trace the `serve`
/// subcommand and the benches drive: request `i` arrives at
/// `i * interarrival_ticks` with deadline `arrival + slo_ticks`
/// (`slo_ticks = 0` = no deadline), priority 0.
pub fn synthetic_trace(tasks: Vec<Task>, interarrival_ticks: u64, slo_ticks: u64) -> Vec<ServeRequest> {
    tasks
        .into_iter()
        .enumerate()
        .map(|(i, task)| {
            let arrival = i as u64 * interarrival_ticks;
            let deadline = if slo_ticks == 0 { u64::MAX } else { arrival + slo_ticks };
            ServeRequest::new(task, arrival).with_deadline(deadline)
        })
        .collect()
}

/// The serving front-end: one engine stack (scheduler + KV wall + lane
/// pool) behind an admission-controlled request queue. Generic over the
/// backend so the whole loop — admission, shedding, streaming, latency
/// accounting — is exercised hermetically on the mock.
///
/// Backend-lane convention matches `evaluate_with_backend`: the serial
/// engines use `backends[0]`; the pipelined engine uses them all, and
/// when the policy selects `prefill = async` the LAST backend is the
/// dedicated prefill-executor lane.
pub struct ServeServer<B: RolloutBackend + Send> {
    policy: RolloutPolicy,
    kind: EngineKind,
    cfg: ServeConfig,
    backends: Vec<B>,
    sched: Scheduler,
    kv: KvMemoryManager,
}

impl<B: RolloutBackend + Send> ServeServer<B> {
    pub fn new(
        policy: RolloutPolicy,
        kind: EngineKind,
        cfg: ServeConfig,
        backends: Vec<B>,
        sched: Scheduler,
        kv: KvMemoryManager,
    ) -> ServeServer<B> {
        ServeServer { policy, kind, cfg, backends, sched, kv }
    }

    /// Serve an arrival trace to completion. `trace` must be sorted by
    /// `arrival_tick`; `seed` keys the per-task RNG streams off the
    /// request index, so tokens match a closed-batch rollout of the same
    /// trace under the same seed exactly.
    pub fn run(&mut self, trace: &[ServeRequest], seed: u64) -> Result<ServeReport> {
        if self.backends.is_empty() {
            bail!("serve needs at least one backend lane");
        }
        if trace.windows(2).any(|w| w[0].arrival_tick > w[1].arrival_tick) {
            bail!("serve trace must be sorted by arrival tick");
        }
        let ServeServer { policy, kind, cfg, backends, sched, kv } = self;
        let n = trace.len();
        let max_response = policy.sampling.max_response;
        // the admission oracle's terms, by request index (the "task
        // position" namespace the deadline picker indexes)
        let cost: Vec<usize> = trace
            .iter()
            .map(|r| sched.predicted_cost_ticks(r.task.prompt_ids.len(), max_response) as usize)
            .collect();
        let deadline: Vec<u64> = trace.iter().map(|r| r.deadline_tick).collect();

        let mut outcomes: Vec<Option<ServeOutcome>> = vec![None; n];
        let mut ttft = LatencyHistogram::new();
        let mut inter_token = LatencyHistogram::new();
        let mut e2e = LatencyHistogram::new();
        let mut stats_total = RolloutStats::default();
        let mut rounds = 0usize;
        let mut now = 0u64;
        let mut next = 0usize; // trace cursor
        let mut queue: VecDeque<usize> = VecDeque::new();

        loop {
            // pull due arrivals; a bounded queue sheds overflow on the
            // spot (reject-with-estimate, like any other shed)
            while next < n && trace[next].arrival_tick <= now {
                if cfg.queue_depth > 0 && queue.len() >= cfg.queue_depth {
                    outcomes[next] = Some(ServeOutcome::Shed {
                        reason: ShedReason::QueueFull,
                        predicted_cost_ticks: cost[next] as u64,
                        predicted_done_tick: now + cost[next] as u64,
                    });
                } else {
                    queue.push_back(next);
                }
                next += 1;
            }
            if queue.is_empty() {
                if next < n {
                    // idle until the next arrival
                    now = now.max(trace[next].arrival_tick);
                    continue;
                }
                break;
            }
            // priority classes dispatch first; the sort is stable so the
            // deadline picker's queue-order tie-break still resolves
            // inside a class by arrival
            let mut held: Vec<usize> = queue.drain(..).collect();
            held.sort_by_key(|&r| std::cmp::Reverse(trace[r].priority));
            let mut pending: VecDeque<usize> = held.into();

            // admission at round start: every queued request is either
            // dispatched this round or shed with an estimate — under
            // overload the queue refuses work instead of collapsing
            let mut batch_reqs: Vec<usize> = Vec::new();
            if cfg.admission.is_slo() {
                while let Some(qi) = sched.pick_next_deadline(&pending, &cost, &deadline) {
                    let r = pending.remove(qi).expect("picked index in range");
                    let predicted = cost[r] as u64;
                    if now.saturating_add(predicted) > trace[r].deadline_tick {
                        outcomes[r] = Some(ServeOutcome::Shed {
                            reason: ShedReason::Deadline,
                            predicted_cost_ticks: predicted,
                            predicted_done_tick: now + predicted,
                        });
                    } else {
                        batch_reqs.push(r);
                    }
                }
            } else {
                batch_reqs.extend(pending.drain(..));
            }
            if batch_reqs.is_empty() {
                continue; // everything due was shed; wait for arrivals
            }

            // dispatch one session round: task_idx IS the request index,
            // so tokens are a pure function of (seed, request) — the
            // closed-batch identity the serve tests pin
            rounds += 1;
            let hub = StreamHub::new();
            let taps: Vec<(usize, Receiver<TokenEvent>)> =
                batch_reqs.iter().map(|&r| (r, hub.subscribe(r))).collect();
            let flat: Vec<(usize, &Task)> =
                batch_reqs.iter().map(|&r| (r, &trace[r].task)).collect();
            let ctx = RolloutCtx::new(sched, kv).with_stream(hub);
            let (seqs, stats) = match *kind {
                EngineKind::Static => {
                    policy.rollout_static_queue(&mut backends[0], &flat, seed, ctx)?
                }
                EngineKind::Continuous => {
                    policy.rollout_continuous(&mut backends[0], &flat, seed, ctx)?
                }
                EngineKind::Pipelined => {
                    if policy.prefill.is_async() && backends.len() >= 2 {
                        let split = backends.len() - 1;
                        let (lanes, exec) = backends.split_at_mut(split);
                        policy.rollout_pipelined(lanes, Some(&mut exec[0]), &flat, seed, ctx)?
                    } else {
                        policy.rollout_pipelined(backends, None, &flat, seed, ctx)?
                    }
                }
            };

            // fold the round's streams into per-request latencies; event
            // ticks are round-relative, `now` is the round's epoch
            for (r, rx) in taps {
                // keep the FIRST event per index (preempted-and-rerun
                // tasks replay their prefix bit-identically)
                let mut first_tick: Vec<Option<u64>> = Vec::new();
                for ev in rx.try_iter() {
                    if ev.index >= first_tick.len() {
                        first_tick.resize(ev.index + 1, None);
                    }
                    if first_tick[ev.index].is_none() {
                        first_tick[ev.index] = Some(ev.tick);
                    }
                }
                let seq = seqs
                    .iter()
                    .find(|s| s.task_idx == r)
                    .ok_or_else(|| anyhow::anyhow!("request {r} dispatched but not returned"))?;
                let ticks: Vec<u64> = first_tick.iter().filter_map(|t| *t).collect();
                let arrival = trace[r].arrival_tick;
                let (ttft_ticks, e2e_ticks) = match (ticks.first(), ticks.last()) {
                    (Some(&first), Some(&last)) => {
                        let ttft_t = (now + first).saturating_sub(arrival);
                        let e2e_t = (now + last).saturating_sub(arrival);
                        ttft.record(ttft_t);
                        e2e.record(e2e_t);
                        for pair in ticks.windows(2) {
                            inter_token.record(pair[1].saturating_sub(pair[0]));
                        }
                        (ttft_t, e2e_t)
                    }
                    // a request that streamed nothing (e.g. quarantined
                    // before its first token) records no latency sample
                    _ => (0, 0),
                };
                outcomes[r] = Some(ServeOutcome::Completed {
                    response: seq.response_ids.clone(),
                    ttft_ticks,
                    e2e_ticks,
                });
            }
            now += stats.modeled_makespan_ticks;
            stats_total.merge(&stats);
        }

        let outcomes: Vec<ServeOutcome> = outcomes
            .into_iter()
            .enumerate()
            .map(|(i, o)| o.ok_or_else(|| anyhow::anyhow!("request {i} never resolved")))
            .collect::<Result<_>>()?;
        Ok(ServeReport {
            outcomes,
            ttft,
            inter_token,
            e2e,
            rounds,
            makespan_ticks: now,
            stats: stats_total,
        })
    }
}
