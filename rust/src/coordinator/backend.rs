//! Model-backend abstraction for the rollout engines.
//!
//! Both rollout data paths (static chunked and continuous with slot
//! recycling, `rollout.rs`) are generic over a `RolloutBackend`: the small
//! surface a decode loop needs from the model — batched prefill, per-slot
//! prefill (slot recycling), one decode step, and masked KV compression.
//!
//! Two implementations exist:
//! * [`EngineBackend`] — the production path over the AOT artifacts
//!   (`runtime::ModelEngine`), owning the device cache state for one
//!   rollout.
//! * `coordinator::mock::MockModelBackend` — a deterministic pure-Rust
//!   model used by the determinism/equivalence test harness and the
//!   engine-comparison benches; it needs no artifacts, so the equivalence
//!   properties run hermetically in CI.
//!
//! The contract that makes engine equivalence testable token-for-token:
//! a slot's logits depend only on that slot's own cache contents (batch
//! rows are independent), and `prefill_slot` must leave the target slot in
//! exactly the state a batched `prefill` would have produced.
//!
//! **Threading (pipelined engine):** each pipelined worker owns one
//! backend value outright — backends are never shared between workers, so
//! the only bound the worker pool needs is `Send`. `MockModelBackend` is
//! plain data; `EngineBackend` is `Send` because `ModelEngine` is `Sync`
//! (executable cache behind a `Mutex`, atomic latency counters) and the
//! cache state it owns is host-side literals. That is the whole
//! ownership/handle story: N workers = N `EngineBackend`s over one shared
//! `&ModelEngine`.
//!
//! KV *allocation* (worst-case vs paged admission, grow/shrink/preempt —
//! see `kv_manager`/`scheduler`) deliberately lives outside this trait:
//! the backend stores cache planes per slot, while residency accounting is
//! the engine's job. That's also what makes preemption free here — a
//! preempted slot's stale cache is simply overwritten by the next
//! `prefill_slot`, identical to ordinary slot recycling.

use anyhow::{bail, Context, Result};

use crate::config::RolloutMode;
use crate::runtime::{CacheState, Method, ModelEngine, ParamsLit, SlotPlanes, Variant};

/// Modeled per-call device latency, in abstract virtual "ticks".
///
/// This is the deterministic latency cost model behind the hermetic
/// pipeline timing harness: the rollout engines charge every backend call
/// against a virtual clock using these costs, so overlap wins (prefill vs
/// decode, multiple decode lanes) are *measurable* without artifacts,
/// devices, or wall-clock noise — `bench_rollout` asserts the pipelined
/// engine's modeled makespan is strictly below the continuous engine's on
/// the same cost model. All-zero (the default, and what `EngineBackend`
/// reports) opts a backend out: modeled times collapse to 0 and real
/// backends are measured in wall time by the trainer instead.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CostModel {
    /// One batched prefill over all R slots.
    pub prefill_ticks: u64,
    /// One single-slot recycling prefill (`prefill_slot`).
    pub slot_prefill_ticks: u64,
    /// One decode step over the batch.
    pub decode_ticks: u64,
    /// One masked compression call.
    pub compress_ticks: u64,
    /// Attaching an already-prepared prompt prefill to a slot
    /// (`apply_prefill` of a cached payload — prefix sharing's
    /// prefill-once-attach-G path). A slot write, not a model run, so it
    /// is far cheaper than `slot_prefill_ticks`.
    pub attach_ticks: u64,
    /// Per-TOKEN cost of a chunked prefill call (`prefill_chunk`): a
    /// chunk of `n` prompt tokens charges `n * chunk_token_ticks`. The
    /// cost is token-proportional because a chunk rides an already-issued
    /// device step (no per-call fixed overhead), which is exactly the win
    /// `prefill-chunk-tokens` buys over the monolithic
    /// `slot_prefill_ticks` charge.
    pub chunk_token_ticks: u64,
}

impl CostModel {
    /// A representative accelerator profile for benches/tests: prefill is
    /// ~4x a decode step (it processes a whole prompt and, on the real
    /// path, `prefill_slot` additionally pays a host round-trip), and
    /// compression is cheaper than a decode step.
    pub fn representative() -> CostModel {
        CostModel {
            prefill_ticks: 40,
            slot_prefill_ticks: 40,
            decode_ticks: 10,
            compress_ticks: 5,
            attach_ticks: 4,
            // slot_prefill_ticks ≈ call overhead + the full prompt's
            // marginal token cost; a fused chunk pays only the marginal
            // part, so per-token it is far below 40 / typical prompt len
            chunk_token_ticks: 1,
        }
    }

    pub fn is_zero(&self) -> bool {
        *self == CostModel::default()
    }
}

/// What a rollout loop needs from the model. All logits returned are
/// log-probabilities over the vocabulary; batched calls return `[R * V]`
/// flattened, `prefill_slot` returns one `[V]` row.
///
/// **Async prefill (`prefill = async`):** `prepare_prefill` /
/// `apply_prefill` split a slot prefill into its expensive,
/// cache-independent half (runnable on a *different* backend value of the
/// same model — the pipelined engine's prefill-executor lane) and the
/// cheap slot write into the owning worker's cache. The contract:
/// `apply_prefill(slot, prepare_prefill(prompt)?)` must leave the target
/// slot in exactly the state `prefill_slot(slot, prompt)` would — same
/// planes, same returned logits row — so sync and async modes are
/// token-identical by construction.
pub trait RolloutBackend {
    /// Cache-independent product of `prepare_prefill`, transferable
    /// between backend values of the same model (the executor prepares on
    /// its own backend; the owning worker applies it to a slot).
    /// `Clone` because prefix sharing applies ONE prepared prompt to G
    /// sibling slots (prefill-once-attach-G): batch-row independence
    /// makes the payload slot-position-invariant, so a clone applied to
    /// any slot reproduces `prefill_slot` there bit-exactly.
    type Prepared: Send + Clone;
    /// Decode batch width R.
    fn slots(&self) -> usize;
    /// Maximum prompt tokens per sequence.
    fn prompt_len(&self) -> usize;
    /// Maximum absolute sequence position.
    fn max_seq(&self) -> usize;
    fn vocab(&self) -> usize;
    /// Per-sequence KV cache capacity for the active variant.
    fn capacity(&self) -> usize;
    /// Retained tokens after a compression (== capacity when dense).
    fn budget(&self) -> usize;

    /// Batched prefill of all R slots; replaces the whole cache. Returns
    /// last-prompt-token log-probs `[R * V]`.
    fn prefill(&mut self, ids: &[i32], plens: &[i32]) -> Result<Vec<f32>>;

    /// Prefill one slot in place without disturbing the others (slot
    /// recycling). Returns that slot's last-prompt-token log-probs `[V]`.
    fn prefill_slot(&mut self, slot: usize, prompt: &[i32]) -> Result<Vec<f32>>;

    /// Chunked slot prefill: write the partial prompt range
    /// `[start, start + chunk)` into `slot`'s cache planes, resuming
    /// where the previous chunk stopped (`start` must equal the tokens
    /// already written; `start == 0` begins a fresh slot). Returns
    /// `Some(logits [V])` — bit-identical to what `prefill_slot(slot,
    /// prompt)` would have produced — exactly when this chunk completes
    /// the prompt, `None` for an intermediate chunk. The token-budgeted
    /// step packer (`prefill-chunk-tokens`) drives this so a long prompt
    /// never head-of-line-blocks a whole device step.
    fn prefill_chunk(
        &mut self,
        slot: usize,
        prompt: &[i32],
        start: usize,
        chunk: usize,
    ) -> Result<Option<Vec<f32>>>;

    /// Expensive, cache-independent half of a slot prefill: run the
    /// prompt through the model without touching any live rollout state.
    /// The async executor calls this on its own backend, concurrently
    /// with the decode workers.
    fn prepare_prefill(&mut self, prompt: &[i32]) -> Result<Self::Prepared>;

    /// Apply a prepared prefill to `slot` of THIS backend's cache and
    /// return the slot's last-prompt-token log-probs `[V]` — must be
    /// bit-identical to what `prefill_slot` would have produced.
    fn apply_prefill(&mut self, slot: usize, prepared: Self::Prepared) -> Result<Vec<f32>>;

    /// One decode step over the whole batch. `lens[s]` is the occupied
    /// cache length (the write position), `pos[s]` the absolute position.
    fn decode(&mut self, lens: &[i32], pos: &[i32], tokens: &[i32]) -> Result<Vec<f32>>;

    /// Compress the cache of every slot with `do_mask[s] == 1.0` down to
    /// the budget.
    fn compress(&mut self, do_mask: &[f32]) -> Result<()>;

    /// Modeled per-call latencies for the virtual-clock harness. The
    /// default (all zeros) opts out of modeled timing — appropriate for
    /// real backends, whose latency is measured, not modeled.
    fn cost_model(&self) -> CostModel {
        CostModel::default()
    }
}

/// Production backend: drives the AOT artifacts through `ModelEngine`,
/// holding the device-side cache for the rollout in flight.
pub struct EngineBackend<'a> {
    engine: &'a ModelEngine,
    params: &'a ParamsLit,
    variant: Variant,
    method: Option<Method>,
    cache: Option<CacheState>,
}

/// A prepared (cache-independent) slot prefill on the artifact path: the
/// prompt's COMPACT cache planes (extracted from row 0 of the scratch
/// prefill — 1/R-th of a full cache, so in-flight async prefills stay
/// cheap) plus that row's logits. `apply_prefill` implants the planes
/// into the target slot — batch-row independence makes them
/// slot-position-invariant (and clonable across a sharing group's
/// sibling slots).
#[derive(Clone)]
pub struct PreparedSlotPrefill {
    planes: SlotPlanes,
    logp: Vec<f32>,
}

impl<'a> EngineBackend<'a> {
    pub fn new(engine: &'a ModelEngine, params: &'a ParamsLit, mode: RolloutMode) -> Self {
        let variant = if mode.is_sparse() { Variant::Sparse } else { Variant::Dense };
        EngineBackend { engine, params, variant, method: mode.method(), cache: None }
    }
}

impl RolloutBackend for EngineBackend<'_> {
    type Prepared = PreparedSlotPrefill;

    fn slots(&self) -> usize {
        self.engine.manifest.shapes.decode_batch
    }

    fn prompt_len(&self) -> usize {
        self.engine.manifest.config.prompt_len
    }

    fn max_seq(&self) -> usize {
        self.engine.manifest.config.max_seq
    }

    fn vocab(&self) -> usize {
        self.engine.manifest.config.vocab
    }

    fn capacity(&self) -> usize {
        match self.variant {
            Variant::Dense => self.engine.manifest.shapes.dense_capacity,
            Variant::Sparse => self.engine.manifest.shapes.sparse_capacity,
        }
    }

    fn budget(&self) -> usize {
        match self.variant {
            Variant::Dense => self.engine.manifest.shapes.dense_capacity,
            Variant::Sparse => self.engine.manifest.shapes.budget,
        }
    }

    fn prefill(&mut self, ids: &[i32], plens: &[i32]) -> Result<Vec<f32>> {
        let (cache, logp) = self.engine.prefill(self.variant, self.params, ids, plens)?;
        self.cache = Some(cache);
        Ok(logp)
    }

    fn prefill_slot(&mut self, slot: usize, prompt: &[i32]) -> Result<Vec<f32>> {
        let cache = self
            .cache
            .as_mut()
            .context("prefill_slot before the initial batched prefill")?;
        self.engine.prefill_slot(self.params, cache, slot, prompt)
    }

    fn prefill_chunk(
        &mut self,
        slot: usize,
        prompt: &[i32],
        start: usize,
        chunk: usize,
    ) -> Result<Option<Vec<f32>>> {
        let cache = self
            .cache
            .as_mut()
            .context("prefill_chunk before the initial batched prefill")?;
        self.engine
            .prefill_chunk(self.params, cache, slot, prompt, start, chunk)
    }

    fn prepare_prefill(&mut self, prompt: &[i32]) -> Result<Self::Prepared> {
        let (fresh, logp) = self
            .engine
            .prepare_slot_prefill(self.params, self.variant, prompt)?;
        // ship only row 0's planes: the other R-1 scratch rows are
        // discarded here, on the executor, instead of sitting in every
        // in-flight payload
        let planes = self.engine.extract_slot(&fresh, 0)?;
        Ok(PreparedSlotPrefill { planes, logp })
    }

    fn apply_prefill(&mut self, slot: usize, prepared: Self::Prepared) -> Result<Vec<f32>> {
        let Some(cache) = self.cache.as_mut() else {
            // the pipelined engine routes a lane with no live cache (its
            // whole first wave was refused at the wall) through the
            // batched single-row entry instead — see prefill_single_row
            bail!("apply_prefill before the initial batched prefill");
        };
        self.engine.implant_slot(cache, slot, &prepared.planes)?;
        Ok(prepared.logp)
    }

    fn decode(&mut self, lens: &[i32], pos: &[i32], tokens: &[i32]) -> Result<Vec<f32>> {
        let cache = self.cache.as_mut().context("decode before prefill")?;
        self.engine.decode(self.params, cache, lens, pos, tokens)
    }

    fn compress(&mut self, do_mask: &[f32]) -> Result<()> {
        let cache = self.cache.as_mut().context("compress before prefill")?;
        let method = self.method.context("compress in dense mode")?;
        self.engine.compress(method, cache, do_mask)
    }
}
