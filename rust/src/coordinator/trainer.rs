//! The GRPO / Sparse-RL training loop (paper §4, §5.1).
//!
//! One RL step:
//!   1. sample P prompts, G rollouts each (group layout preserved),
//!   2. schedule rollout chunks against the KV memory wall,
//!   3. generate with π_sparse (or dense), recording sampler log-probs,
//!   4. score every trajectory under the dense θ_old (teacher forcing) —
//!      the π_old of Eq. 4,
//!   5. verify rewards, compute group advantages (Eq. 10),
//!   6. Sparse-RL corrections: ξ ratios (Eq. 5) + rejection M^RS (Eq. 6),
//!   7. minibatch Eq. 7 updates via the train artifact (Adam inside).
//!
//! The mode switches reproduce the paper's baselines exactly:
//!   dense          -> ξ≡1, M^RS≡1, rollouts uncompressed (GRPO-Dense)
//!   naive:<m>      -> ξ≡1, M^RS≡1, rollouts compressed  (collapse-prone)
//!   sparse-rl:<m>  -> full corrections                   (ours)

use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::{EngineKind, ExperimentConfig};
use crate::data::task::{looks_repetitive, Task};
use crate::runtime::{ModelEngine, ParamsLit, TrainState};
use crate::util::rng::Rng;

use super::backend::EngineBackend;
use super::engine::{GenSeq, RolloutCtx, RolloutEngine, RolloutStats};
use super::fleet::{rollout_fleet, FleetReport, Replica};
use super::group::{batched_group_advantages, summarize};
use super::kv_manager::KvMemoryManager;
use super::metrics::Metrics;
use super::rejection::{self, RejectionStats};
use super::reweight::{self, TrainSeq};
use super::scheduler::Scheduler;

/// Everything produced by one RL step, for logging/analysis.
#[derive(Debug, Clone, Default)]
pub struct StepReport {
    pub reward_mean: f64,
    pub response_len_mean: f64,
    pub entropy_mean: f64,
    pub mismatch_kl: f64,
    pub rejection_rate: f64,
    pub anomaly_rate: f64,
    pub loss: f64,
    pub grad_norm: f64,
    pub clip_frac: f64,
    pub toks_saving: f64,
    pub rollout_secs: f64,
    pub train_secs: f64,
    pub rollout_chunks: usize,
    pub gen_tokens: usize,
    /// Decode artifact invocations this step (the continuous engine's
    /// whole point is minimizing this under skewed response lengths).
    pub decode_steps: usize,
    /// Mean decode-step slot occupancy in [0, 1].
    pub slot_occupancy: f64,
    /// Fraction of decode-slot work burned on idle (PAD) slots — the
    /// long-tail bubble.
    pub idle_token_frac: f64,
    /// Mid-flight slot refills (continuous engine; 0 under static).
    pub refills: usize,
    /// Partial prompt ranges written by chunked prefill
    /// (`prefill-chunk-tokens > 0`; 0 under monolithic prefill).
    pub prefill_chunks: usize,
    /// Refills served by attaching a cached prepared prompt instead of a
    /// model prefill (`prefix-sharing = group`; 0 otherwise).
    pub shared_prefill_attaches: usize,
    /// Sequences preempted/requeued by a paged-admission grow stall
    /// (0 under worst-case admission).
    pub preemptions: usize,
    /// Pending refills adopted from a peer lane by a drained worker
    /// (pipelined engine with `steal = on`; 0 otherwise).
    pub steals: usize,
    /// Slot prefills handed to the dedicated prefill-executor thread
    /// (pipelined engine with `prefill = async`; 0 otherwise).
    pub async_prefills: usize,
    /// Peak submitted-but-not-yet-joined async prefills (the executor
    /// pipeline's occupancy high-water; 0 under sync).
    pub async_prefill_inflight_peak: usize,
    /// Peak KV page occupancy in [0, 1] during the step's rollouts.
    pub kv_page_occupancy: f64,
    /// Peak concurrently occupied decode slots (admitted width).
    pub peak_live_slots: usize,
    /// Worker lanes the rollout ran on (1 unless `engine = pipelined`);
    /// under a fleet this sums lanes across replicas.
    pub rollout_workers: usize,
    /// Data-parallel rollout replicas the step ran on (the `replicas`
    /// knob; 1 = the single-engine path).
    pub replicas: usize,
    /// Tasks that moved across replica boundaries via cross-replica work
    /// stealing (`replicas > 1` with `replica-steal = on`; 0 otherwise).
    pub replica_steals: usize,
    /// Modeled-time breakdown on the backend cost model (all zero for the
    /// real artifact backend, which is wall-timed via `rollout_secs`):
    /// ticks busy decoding/compressing, summed over lanes.
    pub decode_busy_ticks: u64,
    /// Ticks a decode lane sat blocked on prefill work.
    pub prefill_blocked_ticks: u64,
    /// Ticks a decode lane idled at the memory wall waiting for a peer
    /// release (pipelined only).
    pub sched_stall_ticks: u64,
    /// Modeled end-to-end makespan (serial sum, or the lane max when
    /// pipelined).
    pub modeled_makespan_ticks: u64,
    /// Peak ticks any single engine step took (the per-step latency bound
    /// chunked prefill lowers; 0 under the static engine).
    pub max_step_ticks: u64,
    /// Backend calls that failed and were retried under the bounded-retry
    /// budget (`fault-retries`; 0 fault-free).
    pub retries: usize,
    /// Tasks requeued from a dead replica to a survivor by fleet failover
    /// (`fault-policy = quarantine` with `replicas > 1`; 0 otherwise).
    pub requeues: usize,
    /// Tasks quarantined after exhausting their retry budget
    /// (`fault-policy = quarantine`; 0 otherwise — abort errors instead).
    pub failed_tasks: usize,
    /// Replica threads declared dead and failed over this step.
    pub replica_deaths: usize,
    /// GRPO groups dropped by partial-batch delivery because a member was
    /// quarantined (the surviving groups trained normally).
    pub dropped_groups: usize,
}

/// Partial-batch delivery: drop every whole GRPO group containing a
/// quarantined member, keeping the survivors (in their original group
/// order, with their original flat `task_idx` — reward lookup stays
/// `task_indices[task_idx / g]`). A failed rollout carries no trustworthy
/// sampler log-probs, and group advantages (Eq. 10) need the full G-member
/// baseline, so the whole group goes. Returns the survivors plus the
/// dropped-group count; errors when nothing survives (a zero-sequence
/// train step has no gradient — surface the fault instead of dividing by
/// zero downstream).
fn drop_failed_groups(seqs: Vec<GenSeq>, g: usize) -> Result<(Vec<GenSeq>, usize)> {
    if !seqs.iter().any(|s| s.failed) {
        return Ok((seqs, 0));
    }
    let groups = seqs.len() / g.max(1);
    let mut out: Vec<GenSeq> = Vec::with_capacity(seqs.len());
    let mut buf: Vec<GenSeq> = Vec::with_capacity(g);
    let mut dropped = 0usize;
    for s in seqs {
        buf.push(s);
        if buf.len() == g {
            if buf.iter().any(|s| s.failed) {
                dropped += 1;
                buf.clear();
            } else {
                out.append(&mut buf);
            }
        }
    }
    if out.is_empty() {
        bail!(
            "all {groups} rollout groups had a quarantined member — nothing \
             left to train on (raise fault-retries or fix the backend)"
        );
    }
    Ok((out, dropped))
}

/// The trainer: owns learner state, data order, metrics, and the wall.
pub struct Trainer<'a> {
    pub engine: &'a ModelEngine,
    pub cfg: ExperimentConfig,
    pub state: TrainState,
    pub tasks: Vec<Task>,
    pub rng: Rng,
    pub metrics: Metrics,
    pub kv: KvMemoryManager,
    /// Routing/steal detail of the most recent fleet rollout (`replicas >
    /// 1` only; `None` after a single-engine rollout).
    pub last_fleet: Option<FleetReport>,
    cursor: usize,
    order: Vec<usize>,
}

impl<'a> Trainer<'a> {
    pub fn new(
        engine: &'a ModelEngine,
        cfg: ExperimentConfig,
        state: TrainState,
        tasks: Vec<Task>,
    ) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let mut order: Vec<usize> = (0..tasks.len()).collect();
        rng.shuffle(&mut order);
        let kv = KvMemoryManager::with_pages(cfg.memory.global_kv_tokens, cfg.memory.kv_page_tokens);
        Trainer {
            engine,
            cfg,
            state,
            tasks,
            rng,
            metrics: Metrics::new(),
            kv,
            last_fleet: None,
            cursor: 0,
            order,
        }
    }

    fn next_task_idx(&mut self) -> usize {
        if self.cursor >= self.order.len() {
            self.cursor = 0;
            self.rng.shuffle(&mut self.order);
        }
        let idx = self.order[self.cursor];
        self.cursor += 1;
        idx
    }

    /// Run all rollouts for one step through the memory-wall scheduler,
    /// on the configured engine (static chunked, continuous, or pipelined
    /// multi-worker batching).
    /// Returns sequences in prompt-major group order plus rollout stats.
    ///
    /// The rollout seed is drawn once per step and per-task RNG streams
    /// key off (seed, flat sequence id), so both engines generate
    /// token-identical sequences for the same step.
    pub fn rollout_batch(
        &mut self,
        task_indices: &[usize],
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let g = self.cfg.train.group_size;
        let n = task_indices.len() * g;
        let rollout = RolloutEngine::from_config(self.engine, &self.cfg);
        let seed = self.rng.next_u64();
        let params = ParamsLit::new(&self.state.params);
        // flat sequence ids: seq s belongs to prompt s / g
        let tasks: Vec<(usize, &Task)> = (0..n)
            .map(|s| (s, &self.tasks[task_indices[s / g]]))
            .collect();
        if self.cfg.replicas > 1 {
            // Fleet path: N full engine instances — fresh scheduler +
            // private KV wall + lane pool each (KV managers are cheap
            // accounting objects and every rollout drains its wall, so
            // building them per step costs nothing) — under the global
            // load-modeled router. Tokens are identical to the single-
            // engine path below: per-task RNG keys off (seed, flat id),
            // never off placement.
            let policy = rollout.policy();
            let lanes = match self.cfg.engine {
                EngineKind::Pipelined => {
                    let w = self.cfg.rollout_workers.max(1);
                    if self.cfg.prefill.is_async() { w + 1 } else { w }
                }
                _ => 1,
            };
            let mut replicas: Vec<Replica<EngineBackend>> = (0..self.cfg.replicas)
                .map(|_| {
                    let sched = Scheduler::new(&self.engine.manifest, self.cfg.mode.is_sparse())
                        .with_admission(self.cfg.memory.admission)
                        .with_headroom(self.cfg.memory.kv_admit_headroom_pages)
                        .with_order(self.cfg.admission_order)
                        .with_sharing(self.cfg.memory.prefix_sharing);
                    let kv = KvMemoryManager::with_pages(
                        self.cfg.memory.global_kv_tokens,
                        self.cfg.memory.kv_page_tokens,
                    );
                    let backends = (0..lanes)
                        .map(|_| EngineBackend::new(self.engine, &params, self.cfg.mode))
                        .collect();
                    Replica::new(sched, kv, backends)
                })
                .collect();
            let (seqs, stats, report) = rollout_fleet(
                &policy,
                self.cfg.engine,
                &mut replicas,
                &tasks,
                seed,
                self.cfg.replica_steal,
            )?;
            self.last_fleet = Some(report);
            return Ok((seqs, stats));
        }
        self.last_fleet = None;
        let mut scheduler = Scheduler::new(&self.engine.manifest, self.cfg.mode.is_sparse())
            .with_admission(self.cfg.memory.admission)
            .with_headroom(self.cfg.memory.kv_admit_headroom_pages)
            .with_order(self.cfg.admission_order)
            .with_sharing(self.cfg.memory.prefix_sharing);
        let (kind, workers) = (self.cfg.engine, self.cfg.rollout_workers);
        let ctx = RolloutCtx::new(&mut scheduler, &mut self.kv);
        rollout.session(&params, kind, workers, ctx).run(&tasks, seed)
    }

    /// Dense teacher-forcing scores for a set of sequences under the
    /// current (θ_old) weights. Returns per-seq (logp_old, entropy) over
    /// *response* tokens.
    pub fn score_sequences(&self, seqs: &[GenSeq]) -> Result<Vec<(Vec<f32>, Vec<f32>)>> {
        let m = &self.engine.manifest;
        let (b, t) = (m.shapes.train_batch, m.config.max_seq);
        let mut out = Vec::with_capacity(seqs.len());
        for chunk in seqs.chunks(b) {
            let mut ids = vec![0i32; b * t];
            let mut lens = vec![1i32; b];
            for (row, seq) in chunk.iter().enumerate() {
                let full = seq.full_ids();
                let n = full.len().min(t);
                ids[row * t..row * t + n].copy_from_slice(&full[..n]);
                lens[row] = n as i32;
            }
            let (logp, ent) = self.engine.score(&self.state.params, &ids, &lens)?;
            for (row, seq) in chunk.iter().enumerate() {
                let p0 = seq.prompt_ids.len();
                let rl = seq.response_ids.len().min(t - p0);
                let lo: Vec<f32> = (0..rl).map(|r| logp[row * t + p0 + r]).collect();
                let en: Vec<f32> = (0..rl).map(|r| ent[row * t + p0 + r]).collect();
                out.push((lo, en));
            }
        }
        Ok(out)
    }

    /// One full RL step.
    pub fn rl_step(&mut self) -> Result<StepReport> {
        let cfg = self.cfg.clone();
        let g = cfg.train.group_size;
        let task_indices: Vec<usize> =
            (0..cfg.train.prompts_per_step).map(|_| self.next_task_idx()).collect();

        // ---- rollouts ---------------------------------------------------
        let t0 = Instant::now();
        let (seqs, rstats) = self.rollout_batch(&task_indices)?;
        let rollout_secs = t0.elapsed().as_secs_f64();

        // ---- partial-batch delivery: quarantined tasks (fault-policy =
        // quarantine) poison their whole GRPO group; train on the rest ----
        let (seqs, dropped_groups) = drop_failed_groups(seqs, g)?;

        // ---- dense scoring (π_old) --------------------------------------
        let scored = self.score_sequences(&seqs)?;

        // ---- rewards + advantages ---------------------------------------
        let rewards: Vec<f64> = seqs
            .iter()
            .map(|s| self.tasks[task_indices[s.task_idx / g]].reward(&s.response_ids))
            .collect();
        let advantages = batched_group_advantages(&rewards, g)?;
        let summary = summarize(&rewards, g)?;

        // ---- corrections -------------------------------------------------
        let corrections = cfg.mode.corrections();
        let mut rej_stats = RejectionStats::default();
        let mut anomalies = 0usize;
        let mut train_seqs: Vec<TrainSeq> = Vec::with_capacity(seqs.len());
        let mut kl_pairs: Vec<(&[f32], &[f32])> = Vec::with_capacity(seqs.len());
        for (i, seq) in seqs.iter().enumerate() {
            let (logp_old, _ent) = &scored[i];
            let rl = logp_old.len();
            let sampler = &seq.sampler_logp[..rl];
            if looks_repetitive(&seq.response_ids, 5) {
                anomalies += 1;
            }
            let (xi, accept) = if corrections {
                let mut xi = rejection::xi_ratios(logp_old, sampler);
                let verdict = rejection::verdict(&xi, cfg.train.rejection_eps);
                rej_stats.record(&verdict);
                let accept = match cfg.train.correction_mode {
                    // Eq. 6: hard sequence-level veto
                    crate::config::CorrectionMode::Reject => {
                        !cfg.train.rejection || verdict.accept
                    }
                    // future-work variant: keep the trajectory, clamp the
                    // offending ratios so no token dominates or vanishes
                    crate::config::CorrectionMode::Clamp => {
                        let eps = cfg.train.rejection_eps;
                        for x in xi.iter_mut() {
                            *x = x.max(eps);
                        }
                        true
                    }
                };
                let xi = if cfg.train.reweight { xi } else { vec![1.0; rl] };
                (xi, accept)
            } else {
                (vec![1.0; rl], true)
            };
            train_seqs.push(TrainSeq {
                ids: seq.full_ids(),
                prompt_len: seq.prompt_ids.len(),
                advantage: advantages[i],
                xi,
                accept,
                logp_old: logp_old.clone(),
            });
            kl_pairs.push((sampler, &logp_old[..]));
        }
        let mismatch_kl = reweight::mismatch_kl(&kl_pairs)?;

        // ---- policy updates ----------------------------------------------
        let t1 = Instant::now();
        let btr = self.engine.manifest.shapes.train_batch;
        let mut order: Vec<usize> = (0..train_seqs.len()).collect();
        let mut loss_acc = 0.0;
        let mut gnorm_acc = 0.0f64;
        let mut clip_acc = 0.0;
        let mut _ent_acc = 0.0;
        let mut n_updates = 0usize;
        for _ in 0..cfg.train.updates_per_step {
            self.rng.shuffle(&mut order);
            for mb in order.chunks(btr) {
                let refs: Vec<&TrainSeq> = mb.iter().map(|&i| &train_seqs[i]).collect();
                let batch = reweight::pack(&self.engine.manifest, &refs)?;
                let stats = self.engine.train(
                    &mut self.state,
                    &batch.ids,
                    &batch.loss_mask,
                    &batch.lens,
                    &batch.adv,
                    &batch.xi,
                    &batch.mrs,
                    &batch.logp_old,
                    cfg.train.hyp,
                )?;
                loss_acc += stats.loss;
                gnorm_acc = gnorm_acc.max(stats.grad_norm);
                clip_acc += stats.clip_frac;
                _ent_acc += stats.entropy;
                n_updates += 1;
            }
        }
        let train_secs = t1.elapsed().as_secs_f64();

        // ---- accounting + metrics ----------------------------------------
        let mut acct = crate::compression::KvAccounting::new();
        for s in &seqs {
            acct.merge(&s.accounting);
        }
        let gen_tokens: usize = seqs.iter().map(|s| s.response_ids.len()).sum();
        let report = StepReport {
            reward_mean: summary.mean,
            response_len_mean: gen_tokens as f64 / seqs.len() as f64,
            entropy_mean: {
                let (mut s, mut n) = (0.0, 0usize);
                for (_, ent) in &scored {
                    for &e in ent {
                        s += e as f64;
                        n += 1;
                    }
                }
                if n == 0 { 0.0 } else { s / n as f64 }
            },
            mismatch_kl,
            rejection_rate: rej_stats.rate(),
            anomaly_rate: anomalies as f64 / seqs.len() as f64,
            loss: loss_acc / n_updates.max(1) as f64,
            grad_norm: gnorm_acc,
            clip_frac: clip_acc / n_updates.max(1) as f64,
            toks_saving: acct.toks_saving(),
            rollout_secs,
            train_secs,
            rollout_chunks: rstats.chunks,
            gen_tokens,
            decode_steps: rstats.decode_steps,
            slot_occupancy: rstats.occupancy(),
            idle_token_frac: rstats.idle_frac(),
            refills: rstats.refills,
            prefill_chunks: rstats.prefill_chunks,
            shared_prefill_attaches: rstats.shared_prefill_attaches,
            preemptions: rstats.preemptions,
            steals: rstats.steals,
            async_prefills: rstats.async_prefills_submitted,
            async_prefill_inflight_peak: rstats.async_prefill_inflight_peak,
            kv_page_occupancy: if self.kv.total_pages() == 0 {
                0.0
            } else {
                rstats.max_used_pages as f64 / self.kv.total_pages() as f64
            },
            peak_live_slots: rstats.peak_live_slots,
            rollout_workers: rstats.workers.max(1),
            replicas: cfg.replicas.max(1),
            replica_steals: self.last_fleet.as_ref().map_or(0, |f| f.replica_steals),
            decode_busy_ticks: rstats.decode_busy_ticks,
            prefill_blocked_ticks: rstats.prefill_blocked_ticks,
            sched_stall_ticks: rstats.sched_stall_ticks,
            modeled_makespan_ticks: rstats.modeled_makespan_ticks,
            max_step_ticks: rstats.max_step_ticks,
            retries: rstats.retries,
            requeues: rstats.requeues,
            failed_tasks: rstats.failed_tasks,
            replica_deaths: rstats.replica_deaths,
            dropped_groups,
        };

        self.metrics.begin_step();
        self.metrics.push("reward", report.reward_mean);
        self.metrics.push("response_len", report.response_len_mean);
        self.metrics.push("entropy", report.entropy_mean);
        self.metrics.push("mismatch_kl", report.mismatch_kl);
        self.metrics.push("rejection_rate", report.rejection_rate);
        self.metrics.push("anomaly_rate", report.anomaly_rate);
        self.metrics.push("loss", report.loss);
        self.metrics.push("grad_norm", report.grad_norm);
        self.metrics.push("clip_frac", report.clip_frac);
        self.metrics.push("toks_saving", report.toks_saving);
        self.metrics.push("rollout_secs", report.rollout_secs);
        self.metrics.push("train_secs", report.train_secs);
        self.metrics.push("decode_steps", report.decode_steps as f64);
        self.metrics.push("slot_occupancy", report.slot_occupancy);
        self.metrics.push("idle_token_frac", report.idle_token_frac);
        self.metrics.push("refills", report.refills as f64);
        self.metrics.push("prefill_chunks", report.prefill_chunks as f64);
        self.metrics
            .push("shared_prefill_attaches", report.shared_prefill_attaches as f64);
        self.metrics.push("preemptions", report.preemptions as f64);
        self.metrics.push("steals", report.steals as f64);
        self.metrics.push("async_prefills", report.async_prefills as f64);
        self.metrics.push(
            "async_prefill_inflight_peak",
            report.async_prefill_inflight_peak as f64,
        );
        self.metrics.push("kv_page_occupancy", report.kv_page_occupancy);
        // page-padding overhead at the rollout's residency peak (0 at
        // page size 1 or when nothing was resident)
        let frag = if rstats.max_used_pages == 0 {
            0.0
        } else {
            1.0 - rstats.max_reserved_kv as f64
                / (rstats.max_used_pages * self.kv.page_tokens()) as f64
        };
        self.metrics.push("kv_fragmentation", frag);
        self.metrics.push("peak_live_slots", report.peak_live_slots as f64);
        self.metrics.push("rollout_workers", report.rollout_workers as f64);
        self.metrics.push("replicas", report.replicas as f64);
        self.metrics.push("replica_steals", report.replica_steals as f64);
        // modeled-time breakdown (all zero on the real backend; the
        // hermetic mock benches populate it — kept in the CSV so engine
        // comparisons line up column-for-column either way)
        self.metrics.push("decode_busy_ticks", report.decode_busy_ticks as f64);
        self.metrics.push("prefill_blocked_ticks", report.prefill_blocked_ticks as f64);
        self.metrics.push("sched_stall_ticks", report.sched_stall_ticks as f64);
        self.metrics.push("modeled_makespan_ticks", report.modeled_makespan_ticks as f64);
        self.metrics.push("max_step_ticks", report.max_step_ticks as f64);
        // fault-tolerance counters (all zero fault-free and under the
        // default abort policy — the CSV schema is stable either way)
        self.metrics.push("retries", report.retries as f64);
        self.metrics.push("requeues", report.requeues as f64);
        self.metrics.push("failed_tasks", report.failed_tasks as f64);
        self.metrics.push("replica_deaths", report.replica_deaths as f64);
        self.metrics.push("dropped_groups", report.dropped_groups as f64);
        self.metrics.push("informative_groups", summary.informative_groups);
        Ok(report)
    }

    /// Supervised pretraining over worked examples (base-model analog).
    /// Returns the per-step losses.
    pub fn pretrain(&mut self, corpus: &[Task], steps: usize, log_every: usize) -> Result<Vec<f64>> {
        let m = &self.engine.manifest;
        let (b, t) = (m.shapes.train_batch, m.config.max_seq);
        let mut losses = Vec::with_capacity(steps);
        for step in 0..steps {
            let mut ids = vec![0i32; b * t];
            let mut mask = vec![0.0f32; b * t];
            let mut lens = vec![1i32; b];
            for row in 0..b {
                let task = &corpus[self.rng.below(corpus.len())];
                let mut full = task.prompt_ids.clone();
                full.extend(task.target_ids());
                let n = full.len().min(t);
                ids[row * t..row * t + n].copy_from_slice(&full[..n]);
                lens[row] = n as i32;
                // predict every token after BOS (full-sequence LM loss)
                for i in 1..n {
                    mask[row * t + i] = 1.0;
                }
            }
            let loss = self.engine.lm(&mut self.state, &ids, &mask, &lens, self.cfg.train.hyp)?;
            losses.push(loss);
            if log_every > 0 && (step % log_every == 0 || step + 1 == steps) {
                println!("  pretrain step {step:>5}  ce-loss {loss:.4}");
            }
        }
        Ok(losses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::KvAccounting;

    fn seq(task_idx: usize, failed: bool) -> GenSeq {
        GenSeq {
            task_idx,
            prompt_ids: vec![1, 2],
            response_ids: vec![3],
            sampler_logp: vec![-0.5],
            finished: true,
            accounting: KvAccounting::new(),
            failed,
        }
    }

    #[test]
    fn drop_failed_groups_is_identity_fault_free() {
        let seqs: Vec<GenSeq> = (0..6).map(|i| seq(i, false)).collect();
        let (kept, dropped) = drop_failed_groups(seqs, 3).unwrap();
        assert_eq!(dropped, 0);
        assert_eq!(kept.len(), 6);
        // original order and ids untouched
        assert!(kept.iter().enumerate().all(|(i, s)| s.task_idx == i));
    }

    #[test]
    fn drop_failed_groups_drops_exactly_the_poisoned_groups() {
        // groups of 2 over 8 seqs; fail one member of group 1 and both of
        // group 3 — groups 0 and 2 must survive intact, in order, with
        // their ORIGINAL flat task ids (reward lookup is task_idx / g)
        let mut seqs: Vec<GenSeq> = (0..8).map(|i| seq(i, false)).collect();
        seqs[3].failed = true; // group 1
        seqs[6].failed = true; // group 3
        seqs[7].failed = true; // group 3
        let (kept, dropped) = drop_failed_groups(seqs, 2).unwrap();
        assert_eq!(dropped, 2);
        let ids: Vec<usize> = kept.iter().map(|s| s.task_idx).collect();
        assert_eq!(ids, vec![0, 1, 4, 5]);
        assert!(kept.iter().all(|s| !s.failed));
    }

    #[test]
    fn drop_failed_groups_errors_when_nothing_survives() {
        // one failed member per group — every group is poisoned, and a
        // zero-sequence train step must be a loud error, not a panic in
        // the advantage math
        let mut seqs: Vec<GenSeq> = (0..4).map(|i| seq(i, false)).collect();
        seqs[0].failed = true;
        seqs[2].failed = true;
        let err = drop_failed_groups(seqs, 2).unwrap_err().to_string();
        assert!(err.contains("all 2 rollout groups"), "got: {err}");
    }
}
