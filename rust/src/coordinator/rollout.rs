//! Batched autoregressive rollout engine (dense and sparse paths).
//!
//! Drives the AOT prefill/decode/compress executables over a chunk of
//! sequences occupying the decode batch's slots. The engine owns sampling
//! (temperature / top-p), EOS handling, per-token sampler log-prob
//! recording (this *is* log π_sparse — Eq. 2 — the number the corrections
//! need), KV compression triggering, and KV accounting.
//!
//! The sparse path realizes the paper's rollout: the cache holds at most
//! `budget + buffer` slots; whenever a sequence fills the buffer, the
//! compression artifact compacts it back to `budget` retained tokens.

use anyhow::Result;

use crate::compression::KvAccounting;
use crate::config::{RolloutMode, SamplingConfig};
use crate::data::task::Task;
use crate::data::tokenizer::{BOS, EOS, PAD};
use crate::runtime::{ModelEngine, ParamsLit, Variant};
use crate::util::rng::Rng;

/// One finished rollout.
#[derive(Debug, Clone)]
pub struct GenSeq {
    /// Caller-side identifier (index into the step's task list).
    pub task_idx: usize,
    pub prompt_ids: Vec<i32>,
    /// Generated tokens (includes the terminating EOS when finished).
    pub response_ids: Vec<i32>,
    /// log π_sparse(o_t | ·) of every generated token (the actual sampling
    /// distribution, i.e. after temperature/top-p modification).
    pub sampler_logp: Vec<f32>,
    /// True iff the model emitted EOS before the length cap.
    pub finished: bool,
    pub accounting: KvAccounting,
}

impl GenSeq {
    /// Full sequence ids: prompt + response.
    pub fn full_ids(&self) -> Vec<i32> {
        let mut v = self.prompt_ids.clone();
        v.extend_from_slice(&self.response_ids);
        v
    }
}

/// Sample from log-probs with temperature/top-p; returns the token and the
/// log-prob of the token under the *modified* (actually sampled)
/// distribution. With temperature=1, top_p=1 this is exactly `logp[tok]`.
pub fn sample_token(rng: &mut Rng, logp: &[f32], s: &SamplingConfig) -> (usize, f32) {
    if s.temperature < 1e-3 {
        // greedy decoding: a point mass
        let (mut best, mut bv) = (0usize, f32::NEG_INFINITY);
        for (i, &l) in logp.iter().enumerate() {
            if l > bv {
                best = i;
                bv = l;
            }
        }
        return (best, 0.0);
    }
    if (s.temperature - 1.0).abs() < 1e-6 && s.top_p >= 1.0 {
        let tok = rng.sample_logits(logp, 1.0, 1.0);
        return (tok, logp[tok]);
    }
    // general case: materialize the modified distribution
    let inv_t = 1.0 / s.temperature;
    let mx = logp.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<f32> = logp.iter().map(|&l| ((l - mx) * inv_t).exp()).collect();
    let z: f32 = probs.iter().sum();
    for p in probs.iter_mut() {
        *p /= z;
    }
    if s.top_p < 1.0 {
        let mut idx: Vec<usize> = (0..probs.len()).collect();
        idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
        let mut acc = 0.0;
        let mut cut = probs.len();
        for (rank, &i) in idx.iter().enumerate() {
            acc += probs[i];
            if acc >= s.top_p {
                cut = rank + 1;
                break;
            }
        }
        let keep: std::collections::HashSet<usize> = idx[..cut].iter().cloned().collect();
        let mut mass = 0.0;
        for (i, p) in probs.iter_mut().enumerate() {
            if keep.contains(&i) {
                mass += *p;
            } else {
                *p = 0.0;
            }
        }
        for p in probs.iter_mut() {
            *p /= mass;
        }
    }
    let r = rng.next_f32();
    let mut acc = 0.0;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if r < acc && p > 0.0 {
            return (i, p.ln());
        }
    }
    let last = probs.iter().rposition(|&p| p > 0.0).unwrap_or(0);
    (last, probs[last].ln())
}

/// The rollout engine for one artifact set + mode.
pub struct RolloutEngine<'a> {
    pub engine: &'a ModelEngine,
    pub mode: RolloutMode,
    pub sampling: SamplingConfig,
}

impl<'a> RolloutEngine<'a> {
    pub fn new(engine: &'a ModelEngine, mode: RolloutMode, sampling: SamplingConfig) -> Self {
        RolloutEngine { engine, mode, sampling }
    }

    fn variant(&self) -> Variant {
        if self.mode.is_sparse() {
            Variant::Sparse
        } else {
            Variant::Dense
        }
    }

    /// Roll out one chunk of tasks (≤ decode_batch sequences; the
    /// scheduler guarantees admission). `tasks` pairs a caller-side index
    /// with the task occupying that slot.
    pub fn rollout_chunk(
        &self,
        params: &[f32],
        tasks: &[(usize, &Task)],
        rng: &mut Rng,
    ) -> Result<Vec<GenSeq>> {
        // weights are uploaded once per chunk, not once per decode step
        let params = ParamsLit::new(params);
        self.rollout_chunk_lit(&params, tasks, rng)
    }

    /// Same as `rollout_chunk` but with pre-uploaded weights (callers that
    /// run many chunks per step share one upload).
    pub fn rollout_chunk_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        rng: &mut Rng,
    ) -> Result<Vec<GenSeq>> {
        let m = &self.engine.manifest;
        let r = m.shapes.decode_batch;
        let p_len = m.config.prompt_len;
        let max_seq = m.config.max_seq;
        let variant = self.variant();
        let capacity = match variant {
            Variant::Dense => m.shapes.dense_capacity,
            Variant::Sparse => m.shapes.sparse_capacity,
        };
        let budget = m.shapes.budget;
        assert!(tasks.len() <= r, "chunk of {} > {} slots", tasks.len(), r);

        // ---- prefill ----------------------------------------------------
        let mut ids = vec![PAD; r * p_len];
        let mut plens = vec![1i32; r];
        for (slot, (_, task)) in tasks.iter().enumerate() {
            let pi = &task.prompt_ids;
            assert!(pi.len() <= p_len, "prompt {} > {}", pi.len(), p_len);
            ids[slot * p_len..slot * p_len + pi.len()].copy_from_slice(pi);
            plens[slot] = pi.len() as i32;
        }
        for slot in tasks.len()..r {
            ids[slot * p_len] = BOS;
        }
        let (mut cache, mut logp) = self.engine.prefill(variant, params, &ids, &plens)?;

        // ---- decode loop -------------------------------------------------
        let vocab = m.config.vocab;
        let n = tasks.len();
        let mut active: Vec<bool> = (0..r).map(|i| i < n).collect();
        let mut lens: Vec<i32> = plens.clone(); // occupied cache slots
        let mut abs_pos: Vec<i32> = plens.clone(); // absolute next position
        let mut out: Vec<GenSeq> = tasks
            .iter()
            .map(|(idx, task)| GenSeq {
                task_idx: *idx,
                prompt_ids: task.prompt_ids.clone(),
                response_ids: vec![],
                sampler_logp: vec![],
                finished: false,
                accounting: KvAccounting::new(),
            })
            .collect();
        let mut slot_rngs: Vec<Rng> = (0..r).map(|i| rng.fork(i as u64 + 1)).collect();

        let mut tokens = vec![PAD; r];
        let mut do_mask = vec![0.0f32; r];
        loop {
            // sample next token per active slot
            let mut any_active = false;
            for slot in 0..n {
                if !active[slot] {
                    tokens[slot] = PAD;
                    continue;
                }
                let dist = &logp[slot * vocab..(slot + 1) * vocab];
                let (tok, lp) = sample_token(&mut slot_rngs[slot], dist, &self.sampling);
                tokens[slot] = tok as i32;
                out[slot].response_ids.push(tok as i32);
                out[slot].sampler_logp.push(lp);
                let dense_equiv = abs_pos[slot] as usize + 1;
                out[slot].accounting.step(
                    ((lens[slot] + 1) as usize).min(capacity),
                    dense_equiv,
                );
                if tok as i32 == EOS {
                    active[slot] = false;
                    out[slot].finished = true;
                    tokens[slot] = tok as i32; // still fed once below
                }
                let gen_len = out[slot].response_ids.len();
                let cap_hit = gen_len >= self.sampling.max_response
                    || (abs_pos[slot] as usize + 1) >= max_seq;
                if cap_hit {
                    active[slot] = false;
                }
                any_active = any_active || active[slot];
            }
            if !any_active {
                break; // final tokens recorded; their logits are never needed
            }

            // compression trigger: a slot whose next write would overflow
            if variant == Variant::Sparse {
                let mut any = false;
                for slot in 0..r {
                    let need = active[slot] && lens[slot] as usize >= capacity;
                    do_mask[slot] = if need { 1.0 } else { 0.0 };
                    if need {
                        any = true;
                    }
                }
                if any {
                    let method = self.mode.method().expect("sparse mode has a method");
                    self.engine.compress(method, &mut cache, &do_mask)?;
                    for slot in 0..r {
                        if do_mask[slot] > 0.0 {
                            out[slot].accounting.compression(capacity - budget);
                            lens[slot] = budget as i32;
                        }
                    }
                }
            }

            // one decode step over the whole batch
            let step_tokens: Vec<i32> = (0..r)
                .map(|s| if s < n { tokens[s] } else { PAD })
                .collect();
            logp = self
                .engine
                .decode(params, &mut cache, &lens, &abs_pos, &step_tokens)?;
            for slot in 0..r {
                // frozen for finished/idle slots: they take no cache writes
                // we care about, and freezing avoids spurious compressions
                if slot < n && (active[slot] || step_tokens[slot] == EOS) {
                    lens[slot] += 1;
                    abs_pos[slot] += 1;
                }
            }
            // EOS has been fed exactly once; fully retire those slots
            for slot in 0..n {
                if out[slot].finished {
                    // no-op: active already false
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(t: f32, p: f32) -> SamplingConfig {
        SamplingConfig { temperature: t, top_p: p, max_response: 16 }
    }

    #[test]
    fn sample_token_records_exact_logp_at_unit_temp() {
        let mut rng = Rng::new(1);
        let logp = [-0.5f32, -1.5, -3.0];
        for _ in 0..50 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(1.0, 1.0));
            assert_eq!(lp, logp[tok]);
        }
    }

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(2);
        let logp = [-2.0f32, -0.1, -5.0];
        for _ in 0..20 {
            let (tok, _) = sample_token(&mut rng, &logp, &cfg(0.0, 1.0));
            assert_eq!(tok, 1);
        }
    }

    #[test]
    fn tempered_logp_is_normalized() {
        let mut rng = Rng::new(3);
        let logp = [-0.7f32, -1.1, -2.0, -2.5];
        // collect the modified distribution empirically
        let mut mass = [0.0f64; 4];
        let n = 30_000;
        for _ in 0..n {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(0.7, 0.95));
            mass[tok] += 1.0;
            // recorded logp must be a valid log-probability
            assert!(lp <= 0.0 && lp.is_finite());
        }
        let total: f64 = mass.iter().sum();
        assert_eq!(total as usize, n);
        // last token should be rarer than first under sharpening
        assert!(mass[0] > mass[3]);
    }
}
