//! Autoregressive rollout engines (dense and sparse paths; static chunked
//! and continuous batching).
//!
//! Drives the prefill/decode/compress backend over sequences occupying the
//! decode batch's slots. The engines own sampling (temperature / top-p),
//! EOS handling, per-token sampler log-prob recording (this *is*
//! log π_sparse — Eq. 2 — the number the corrections need), KV compression
//! triggering, and KV accounting.
//!
//! Three data paths share all of that per-sequence logic:
//!
//! * **Static chunked** (`rollout_static`): a chunk of ≤ R sequences is
//!   prefilled together and decodes until the *slowest* sequence finishes.
//!   Every slot whose sequence hit EOS early burns PAD decode work until
//!   the chunk drains — the long-tail bubble.
//! * **Continuous with slot recycling** (`rollout_continuous`): the moment
//!   a sequence finishes, its KV reservation is released, the next pending
//!   prompt is admitted, prefilled *into that slot in place*, and the
//!   mixed batch keeps decoding. Total decode steps drop from
//!   Σ_chunks max(len) to the list-scheduling makespan of the per-sequence
//!   decode costs — strictly better whenever response lengths are skewed.
//!   But every slot prefill still stalls the whole decode batch.
//! * **Pipelined multi-worker** (`rollout_pipelined`): N worker threads
//!   each drive a continuous-style decode batch against ONE shared
//!   scheduler/KV wall, and slot prefills are deferred to a dedicated
//!   prefill lane so recycling overlaps decode instead of stalling it.
//!   The overlap win is measured hermetically on a virtual clock
//!   (`CostModel` ticks; see `RolloutStats`' timing breakdown).
//!
//! Token-for-token equivalence between the paths is guaranteed by
//! per-TASK RNG streams (`task_rng`): a task's sampling randomness is a
//! pure function of (rollout seed, task index), never of the slot, chunk,
//! worker, or join step it lands in. Combined with batch-row independence
//! of the model, a given task emits identical `response_ids` and
//! `sampler_logp` under all engines — which keeps the Eq. 2/5 correction
//! math bit-reproducible and is what `tests/engine_equivalence.rs` checks
//! exhaustively.
//!
//! The sparse path realizes the paper's rollout: the cache holds at most
//! `budget + buffer` slots; whenever a sequence fills the buffer, the
//! compression artifact compacts it back to `budget` retained tokens.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::compression::KvAccounting;
use crate::config::{RolloutMode, SamplingConfig};
use crate::data::task::Task;
use crate::data::tokenizer::{BOS, EOS, PAD};
use crate::runtime::{ModelEngine, ParamsLit, Variant};
use crate::util::rng::Rng;

use super::backend::{EngineBackend, RolloutBackend};
use super::kv_manager::KvMemoryManager;
use super::scheduler::Scheduler;

/// One finished rollout.
#[derive(Debug, Clone)]
pub struct GenSeq {
    /// Caller-side identifier (index into the step's task list).
    pub task_idx: usize,
    pub prompt_ids: Vec<i32>,
    /// Generated tokens (includes the terminating EOS when finished).
    pub response_ids: Vec<i32>,
    /// log π_sparse(o_t | ·) of every generated token (the actual sampling
    /// distribution, i.e. after temperature/top-p modification).
    pub sampler_logp: Vec<f32>,
    /// True iff the model emitted EOS before the length cap.
    pub finished: bool,
    pub accounting: KvAccounting,
}

impl GenSeq {
    fn new(task_idx: usize, prompt_ids: Vec<i32>) -> GenSeq {
        GenSeq {
            task_idx,
            prompt_ids,
            response_ids: vec![],
            sampler_logp: vec![],
            finished: false,
            accounting: KvAccounting::new(),
        }
    }

    /// Full sequence ids: prompt + response.
    pub fn full_ids(&self) -> Vec<i32> {
        let mut v = self.prompt_ids.clone();
        v.extend_from_slice(&self.response_ids);
        v
    }
}

/// Per-task RNG stream: a pure function of (rollout seed, task index).
/// A given task therefore samples the identical token sequence no matter
/// which slot, chunk, or engine (static vs continuous) runs it.
pub fn task_rng(seed: u64, task_idx: usize) -> Rng {
    Rng::new(seed ^ (task_idx as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Sample from log-probs with temperature/top-p; returns the token and the
/// log-prob of the token under the *modified* (actually sampled)
/// distribution. With temperature=1, top_p=1 this is exactly `logp[tok]`.
///
/// Robustness: non-finite logits (NaN from a diverged model, ±inf) carry
/// zero mass instead of poisoning the sort/normalization; if *every* logit
/// is non-finite the sampler falls back to a uniform draw. The top-p
/// nucleus always keeps at least one token — when the top-1 probability
/// alone exceeds `top_p`, the cut is exactly {argmax} and its renormalized
/// mass is 1 (recorded log-prob 0).
pub fn sample_token(rng: &mut Rng, logp: &[f32], s: &SamplingConfig) -> (usize, f32) {
    if s.temperature < 1e-3 {
        // greedy decoding: a point mass (NaN never wins a `>` comparison)
        let (mut best, mut bv) = (0usize, f32::NEG_INFINITY);
        for (i, &l) in logp.iter().enumerate() {
            if l > bv {
                best = i;
                bv = l;
            }
        }
        return (best, 0.0);
    }
    if (s.temperature - 1.0).abs() < 1e-6
        && s.top_p >= 1.0
        && logp.iter().all(|l| l.is_finite())
    {
        // unmodified distribution: record the artifact's own log-prob
        // bit-exactly (the finite guard keeps NaN inputs on the hardened
        // path below instead of this shortcut)
        let tok = rng.sample_logits(logp, 1.0, 1.0);
        return (tok, logp[tok]);
    }
    // general case: the shared temperature/top-p machinery (single
    // implementation for both samplers — util::rng::modified_probs)
    let Some(probs) = crate::util::rng::modified_probs(logp, s.temperature, s.top_p) else {
        // fully degenerate input: uniform fallback
        let tok = rng.below(logp.len());
        return (tok, -(logp.len() as f32).ln());
    };
    let r = rng.next_f32();
    let mut acc = 0.0;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if r < acc && p > 0.0 {
            return (i, p.ln());
        }
    }
    let last = probs.iter().rposition(|&p| p > 0.0).unwrap_or(0);
    (last, probs[last].ln())
}

/// Throughput/occupancy statistics for one rollout (any engine).
///
/// `occupied_slot_steps` counts, per decode step, the slots doing live
/// generation; `idle_slot_steps` counts the complement — PAD work on
/// finished or never-admitted slots (the long-tail bubble the continuous
/// engine removes).
///
/// **Denominator contract (cross-engine audit):** every counter here is
/// denominated in *modeled device work*, never in engine loop iterations.
/// One `decode` artifact invocation contributes exactly `slots` slot-steps
/// (`occupied + idle == decode_steps * slots` — the equivalence tests
/// assert this identity for all three engines), so `occupancy()` and
/// `idle_frac()` are apples-to-apples across static, continuous, and
/// pipelined runs, and across worker counts. The `*_ticks` fields are the
/// virtual-clock breakdown on the backend's `CostModel` (all zero for
/// real backends, which are wall-timed by the trainer instead).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RolloutStats {
    /// Scheduled chunks (continuous: one pass over the whole queue).
    pub chunks: usize,
    /// Decode artifact invocations.
    pub decode_steps: usize,
    pub occupied_slot_steps: usize,
    pub idle_slot_steps: usize,
    /// Mid-flight slot refills (continuous only).
    pub refills: usize,
    /// Batched prefill calls.
    pub prefills: usize,
    /// Per-slot (recycling) prefill calls.
    pub slot_prefills: usize,
    /// Max KV tokens reserved simultaneously (continuous only; the
    /// invariant tests check this never exceeds the wall).
    pub max_reserved_kv: usize,
    /// Max pool pages in use simultaneously (continuous only; page
    /// occupancy = this over the manager's `total_pages`).
    pub max_used_pages: usize,
    /// Max concurrently occupied decode slots at any step (the admitted
    /// width the paged-vs-worst-case benches compare).
    pub peak_live_slots: usize,
    /// Sequences preempted and requeued by a paged-admission grow stall
    /// (0 under worst-case admission).
    pub preemptions: usize,
    /// Worker lanes that produced these stats (1 for static/continuous;
    /// the pool size for pipelined).
    pub workers: usize,
    /// Modeled ticks spent busy on decode + compression calls, summed
    /// over lanes.
    pub decode_busy_ticks: u64,
    /// Modeled ticks a decode lane sat blocked on prefill work: batched
    /// prefills, plus slot prefills that could not be hidden behind decode
    /// (the continuous engine charges *every* slot prefill here — that
    /// serial stall is exactly what the pipelined engine's dedicated
    /// prefill lane removes).
    pub prefill_blocked_ticks: u64,
    /// Modeled ticks a decode lane idled empty at the memory wall,
    /// waiting for another lane to release KV (pipelined only; the
    /// single-lane engines keep decoding or bail instead of waiting).
    pub sched_stall_ticks: u64,
    /// Modeled end-to-end makespan. Serial engines: busy + blocked +
    /// stall. Pipelined: max over worker lanes' finish clocks — which is
    /// why `merge` (serial composition, e.g. static chunks) SUMS this
    /// field and the pipelined joiner overwrites it with the lane max.
    pub modeled_makespan_ticks: u64,
}

impl RolloutStats {
    /// Total device slot-steps: the shared denominator of `occupancy` and
    /// `idle_frac`. Always equals `decode_steps * slots` when the engines
    /// uphold the denominator contract (asserted by the equivalence
    /// tests).
    pub fn device_slot_steps(&self) -> usize {
        self.occupied_slot_steps + self.idle_slot_steps
    }

    /// Mean decode-step slot occupancy in [0, 1].
    pub fn occupancy(&self) -> f64 {
        let total = self.device_slot_steps();
        if total == 0 {
            0.0
        } else {
            self.occupied_slot_steps as f64 / total as f64
        }
    }

    /// Fraction of decode-slot work wasted on idle (PAD) slots.
    pub fn idle_frac(&self) -> f64 {
        let total = self.device_slot_steps();
        if total == 0 {
            0.0
        } else {
            self.idle_slot_steps as f64 / total as f64
        }
    }

    /// Combine stats from two runs. Work counters (steps, slot-steps,
    /// refills, ticks, makespan) ADD — serial composition, as when the
    /// static queue driver folds chunk after chunk. Residency peaks take
    /// the MAX (they are high-water marks, not work). The pipelined
    /// joiner uses `merge` for the per-lane work sums, then overwrites
    /// `modeled_makespan_ticks` with the lane max and `peak_live_slots`
    /// with the globally observed admitted width.
    pub fn merge(&mut self, o: &RolloutStats) {
        self.chunks += o.chunks;
        self.decode_steps += o.decode_steps;
        self.occupied_slot_steps += o.occupied_slot_steps;
        self.idle_slot_steps += o.idle_slot_steps;
        self.refills += o.refills;
        self.prefills += o.prefills;
        self.slot_prefills += o.slot_prefills;
        self.max_reserved_kv = self.max_reserved_kv.max(o.max_reserved_kv);
        self.max_used_pages = self.max_used_pages.max(o.max_used_pages);
        self.peak_live_slots = self.peak_live_slots.max(o.peak_live_slots);
        self.preemptions += o.preemptions;
        self.workers = self.workers.max(o.workers);
        self.decode_busy_ticks += o.decode_busy_ticks;
        self.prefill_blocked_ticks += o.prefill_blocked_ticks;
        self.sched_stall_ticks += o.sched_stall_ticks;
        self.modeled_makespan_ticks += o.modeled_makespan_ticks;
    }
}

/// The backend-independent rollout policy: mode + sampling. Holds the
/// whole decode-loop logic for both engines; `RolloutEngine` binds it to
/// the AOT artifacts, the test harness binds it to the mock backend.
#[derive(Debug, Clone, Copy)]
pub struct RolloutPolicy {
    pub mode: RolloutMode,
    pub sampling: SamplingConfig,
}

/// A sequence live in a decode slot (continuous engine bookkeeping).
struct LiveSeq {
    /// Position in the pending task list (== results index).
    pos: usize,
    rng: Rng,
    gen: GenSeq,
}

/// A slot refill admitted to the wall and issued to the dedicated prefill
/// lane, but not yet joined into its worker's decode batch (pipelined
/// engine). The slot idles (PAD) until the lane's virtual clock reaches
/// `ready_at`; its KV reservation is already held.
struct PendingRefill {
    /// Position in the pending task list (== results index).
    pos: usize,
    /// Virtual time at which the lane finishes this prefill.
    ready_at: u64,
}

/// State the pipelined worker threads coordinate on, behind one mutex:
/// the shared task queue, the shared scheduler + KV wall, the result
/// table, and the virtual clocks that tie the lanes' timelines together.
struct PipeShared<'s> {
    queue: VecDeque<usize>,
    sched: &'s mut Scheduler,
    kv: &'s mut KvMemoryManager,
    results: Vec<Option<GenSeq>>,
    /// Virtual clock of the single shared prefill lane.
    lane_clock: u64,
    /// Latest virtual time any lane released KV — the earliest honest
    /// timestamp for an admission that had to wait on the wall.
    release_floor: u64,
    /// Sequences currently admitted across all lanes (live + pending).
    live_now: usize,
    /// Peak of `live_now`: the globally admitted width.
    peak_live: usize,
    /// First worker error, if any — parked peers bail instead of waiting
    /// for releases that will never come.
    failed: Option<String>,
}

impl PipeShared<'_> {
    /// Admit the queue-front sequence: scheduler charge + global width
    /// accounting, in one place so the three admission sites (initial
    /// wave, slot refills, parked retry) cannot drift. Returns the
    /// admitted task position; `None` means the queue is empty or the
    /// wall refused (callers that care which must check the queue first).
    fn admit_front(&mut self, tasks: &[(usize, &Task)], seq_id_base: u64) -> Option<usize> {
        let &pos = self.queue.front()?;
        if !self
            .sched
            .try_admit(self.kv, seq_id_base + pos as u64, tasks[pos].1.prompt_ids.len())
        {
            return None;
        }
        self.queue.pop_front();
        self.live_now += 1;
        self.peak_live = self.peak_live.max(self.live_now);
        Some(pos)
    }

    /// Issue one prefill on the shared lane, starting no earlier than the
    /// caller's local time `now`; returns its completion time.
    fn lane_issue(&mut self, now: u64, ticks: u64) -> u64 {
        self.lane_clock = self.lane_clock.max(now) + ticks;
        self.lane_clock
    }

    /// Account a release/preemption happening at the caller's local time
    /// `now` — the floor a peer's stalled admission jumps its clock to.
    fn release_at(&mut self, now: u64) {
        self.live_now -= 1;
        self.release_floor = self.release_floor.max(now);
    }

    /// Record the wall's current residency into a lane's stats (exact
    /// global peaks: every reserve/grow site snapshots under the mutex).
    fn snap_residency(&self, stats: &mut RolloutStats) {
        stats.max_reserved_kv = stats.max_reserved_kv.max(self.kv.reserved());
        stats.max_used_pages = stats.max_used_pages.max(self.kv.used_pages());
    }
}

impl RolloutPolicy {
    pub fn new(mode: RolloutMode, sampling: SamplingConfig) -> Self {
        RolloutPolicy { mode, sampling }
    }

    /// Sample one token into `gen` — recording the sampler log-prob and KV
    /// accounting — and report `(token, done)` where `done` means the
    /// sequence just terminated (EOS or a length cap). THE single
    /// implementation of per-token semantics: the static loop, the
    /// continuous loop, and the continuous refill path all call this, so
    /// EOS/cap/accounting rules cannot drift between engines (which would
    /// silently break the token-equivalence contract).
    ///
    /// `len` is the occupied cache length and `abs` the absolute position
    /// *before* this token's cache write.
    fn sample_step(
        &self,
        rng: &mut Rng,
        dist: &[f32],
        gen: &mut GenSeq,
        len: i32,
        abs: i32,
        capacity: usize,
        max_seq: usize,
    ) -> (i32, bool) {
        let (tok, lp) = sample_token(rng, dist, &self.sampling);
        gen.response_ids.push(tok as i32);
        gen.sampler_logp.push(lp);
        gen.accounting
            .step(((len + 1) as usize).min(capacity), abs as usize + 1);
        let mut done = false;
        if tok as i32 == EOS {
            gen.finished = true;
            done = true;
        }
        if gen.response_ids.len() >= self.sampling.max_response
            || (abs as usize + 1) >= max_seq
        {
            done = true;
        }
        (tok as i32, done)
    }

    /// Static chunked rollout of ≤ R sequences (the scheduler guarantees
    /// admission). `tasks` pairs a caller-side index with the task
    /// occupying that slot. The chunk decodes until its slowest sequence
    /// finishes; early-EOS slots stay frozen (PAD-fed) until then.
    pub fn rollout_static<B: RolloutBackend>(
        &self,
        b: &mut B,
        tasks: &[(usize, &Task)],
        seed: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let r = b.slots();
        let p_len = b.prompt_len();
        let max_seq = b.max_seq();
        let vocab = b.vocab();
        let capacity = b.capacity();
        let budget = b.budget();
        let costs = b.cost_model();
        let sparse = self.mode.is_sparse();
        assert!(tasks.len() <= r, "chunk of {} > {} slots", tasks.len(), r);
        let mut stats = RolloutStats { chunks: 1, workers: 1, ..RolloutStats::default() };
        if tasks.is_empty() {
            return Ok((vec![], stats));
        }

        // ---- prefill ----------------------------------------------------
        let mut ids = vec![PAD; r * p_len];
        let mut plens = vec![1i32; r];
        for (slot, (_, task)) in tasks.iter().enumerate() {
            let pi = &task.prompt_ids;
            assert!(pi.len() <= p_len, "prompt {} > {}", pi.len(), p_len);
            ids[slot * p_len..slot * p_len + pi.len()].copy_from_slice(pi);
            plens[slot] = pi.len() as i32;
        }
        for slot in tasks.len()..r {
            ids[slot * p_len] = BOS;
        }
        let mut logp = b.prefill(&ids, &plens)?;
        stats.prefills += 1;
        stats.prefill_blocked_ticks += costs.prefill_ticks;

        // ---- decode loop -------------------------------------------------
        let n = tasks.len();
        let mut active: Vec<bool> = (0..r).map(|i| i < n).collect();
        let mut lens: Vec<i32> = plens.clone(); // occupied cache slots
        let mut abs_pos: Vec<i32> = plens.clone(); // absolute next position
        let mut out: Vec<GenSeq> = tasks
            .iter()
            .map(|(idx, task)| GenSeq::new(*idx, task.prompt_ids.clone()))
            .collect();
        // per-TASK streams: slot/chunk placement never changes the tokens
        let mut rngs: Vec<Rng> = tasks.iter().map(|(idx, _)| task_rng(seed, *idx)).collect();

        let mut tokens = vec![PAD; r];
        let mut do_mask = vec![0.0f32; r];
        loop {
            // sample next token per active slot
            let mut any_active = false;
            for slot in 0..n {
                if !active[slot] {
                    tokens[slot] = PAD;
                    continue;
                }
                let dist = &logp[slot * vocab..(slot + 1) * vocab];
                let (tok, done) = self.sample_step(
                    &mut rngs[slot],
                    dist,
                    &mut out[slot],
                    lens[slot],
                    abs_pos[slot],
                    capacity,
                    max_seq,
                );
                tokens[slot] = tok;
                if done {
                    // a terminating EOS is still fed to the decode below
                    // (one final cache write); after that the slot stays
                    // frozen — lens/pos stop advancing and its logits are
                    // ignored until the chunk drains.
                    active[slot] = false;
                }
                any_active = any_active || active[slot];
            }
            if !any_active {
                break; // final tokens recorded; their logits are never needed
            }

            // compression trigger: a slot whose next write would overflow
            if sparse {
                let mut any = false;
                for slot in 0..r {
                    let need = active[slot] && lens[slot] as usize >= capacity;
                    do_mask[slot] = if need { 1.0 } else { 0.0 };
                    if need {
                        any = true;
                    }
                }
                if any {
                    b.compress(&do_mask)?;
                    stats.decode_busy_ticks += costs.compress_ticks;
                    for slot in 0..r {
                        if do_mask[slot] > 0.0 {
                            out[slot].accounting.compression(capacity - budget);
                            lens[slot] = budget as i32;
                        }
                    }
                }
            }

            // one decode step over the whole batch
            let occupied = active.iter().filter(|&&a| a).count();
            stats.peak_live_slots = stats.peak_live_slots.max(occupied);
            let step_tokens: Vec<i32> = (0..r)
                .map(|s| if s < n { tokens[s] } else { PAD })
                .collect();
            logp = b.decode(&lens, &abs_pos, &step_tokens)?;
            stats.decode_steps += 1;
            stats.decode_busy_ticks += costs.decode_ticks;
            stats.occupied_slot_steps += occupied;
            stats.idle_slot_steps += r - occupied;
            for slot in 0..r {
                // frozen for finished/idle slots: they take no cache writes
                // we care about, and freezing avoids spurious compressions.
                // The one EOS feed advances a final time so its write lands.
                if slot < n && (active[slot] || step_tokens[slot] == EOS) {
                    lens[slot] += 1;
                    abs_pos[slot] += 1;
                }
            }
        }
        // serial engine: the lane's makespan is simply everything it did
        stats.modeled_makespan_ticks =
            stats.decode_busy_ticks + stats.prefill_blocked_ticks + stats.sched_stall_ticks;
        Ok((out, stats))
    }

    /// Drive the static chunked engine over a whole pending queue: admit
    /// a chunk against the wall, roll it out to completion, release, and
    /// repeat. THE single driver for queue-scale static rollouts — the
    /// trainer, the equivalence harness, and the benches all call this,
    /// so they exercise identical admission/ordering semantics.
    ///
    /// Results come back in task order (position in `tasks`).
    pub fn rollout_static_queue<B: RolloutBackend>(
        &self,
        b: &mut B,
        tasks: &[(usize, &Task)],
        seed: u64,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let n = tasks.len();
        let mut pending: Vec<usize> = (0..n).collect();
        let mut results: Vec<Option<GenSeq>> = (0..n).map(|_| None).collect();
        let mut stats = RolloutStats::default();
        let mut base = seq_id_base;
        // Predicted worst-case residency per task: a chunk member's cache
        // never holds more than its prompt, max_response generated tokens,
        // and one trailing frozen-slot PAD write (nor more than the
        // per-seq capacity bound). Paged admission sizes chunks by this
        // instead of the global worst case; worst-case admission ignores
        // it.
        let residency: Vec<usize> = tasks
            .iter()
            .map(|(_, t)| {
                (t.prompt_ids.len() + self.sampling.max_response + 1)
                    .min(sched.reserve_per_seq)
            })
            .collect();
        while !pending.is_empty() {
            let Some(chunk) = sched.next_chunk(&mut pending, kv, base, &residency) else {
                bail!(
                    "static rollout stalled: {} pending but nothing admissible \
                     (static batching drains synchronously)",
                    pending.len()
                );
            };
            stats.max_reserved_kv = stats.max_reserved_kv.max(kv.reserved());
            stats.max_used_pages = stats.max_used_pages.max(kv.used_pages());
            let chunk_tasks: Vec<(usize, &Task)> =
                chunk.items.iter().map(|&i| tasks[i]).collect();
            let (seqs, cstats) = self.rollout_static(b, &chunk_tasks, seed)?;
            stats.merge(&cstats);
            // rollout_static returns sequences in slot (= chunk) order
            for (&pos, seq) in chunk.items.iter().zip(seqs) {
                results[pos] = Some(seq);
            }
            sched.finish_chunk(&chunk, kv, base);
            base += chunk.items.len() as u64;
        }
        let out = results
            .into_iter()
            .map(|s| s.expect("every queued task completed"))
            .collect();
        Ok((out, stats))
    }

    /// Continuous-batching rollout with slot recycling over an arbitrarily
    /// long task queue. Admission is per sequence: each admitted sequence
    /// reserves its worst-case KV with the scheduler/manager, and the
    /// reservation is released the moment the sequence finishes — not when
    /// the whole batch drains. Freed slots are immediately re-prefilled
    /// (in place) with the next pending prompt, so the decode batch stays
    /// as full as the memory wall allows.
    ///
    /// Sequences are returned in task order. Total decode steps equal the
    /// list-scheduling makespan of per-sequence decode costs, which
    /// `Scheduler::predicted_decode_steps` computes in closed form.
    pub fn rollout_continuous<B: RolloutBackend>(
        &self,
        b: &mut B,
        tasks: &[(usize, &Task)],
        seed: u64,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let r = b.slots();
        let p_len = b.prompt_len();
        let max_seq = b.max_seq();
        let vocab = b.vocab();
        let capacity = b.capacity();
        let budget = b.budget();
        let costs = b.cost_model();
        let sparse = self.mode.is_sparse();
        let n = tasks.len();
        let mut stats = RolloutStats { chunks: 1, workers: 1, ..RolloutStats::default() };
        if n == 0 {
            return Ok((vec![], stats));
        }

        // Paged admission must be able to grow a lone sequence to its
        // worst-case residency, or the preempt/requeue path could thrash
        // forever on a wall that cannot hold even one sequence.
        if kv.pages_for(sched.reserve_per_seq) > kv.total_pages() {
            bail!(
                "continuous rollout deadlock: one sequence may need {} KV tokens \
                 but the wall holds only {}",
                sched.reserve_per_seq,
                kv.capacity()
            );
        }

        let mut results: Vec<Option<GenSeq>> = (0..n).map(|_| None).collect();
        let mut queue: VecDeque<usize> = (0..n).collect();
        let mut slots: Vec<Option<LiveSeq>> = (0..r).map(|_| None).collect();
        let mut lens = vec![1i32; r];
        let mut abs_pos = vec![1i32; r];

        // ---- initial wave: one batched prefill over the admissible head
        let mut ids = vec![PAD; r * p_len];
        let mut plens = vec![1i32; r];
        let mut w = 0usize;
        while w < r && !queue.is_empty() {
            let pos = queue[0];
            if !sched.try_admit(kv, seq_id_base + pos as u64, tasks[pos].1.prompt_ids.len()) {
                break;
            }
            queue.pop_front();
            let (idx, task) = tasks[pos];
            let pi = &task.prompt_ids;
            assert!(pi.len() <= p_len, "prompt {} > {}", pi.len(), p_len);
            ids[w * p_len..w * p_len + pi.len()].copy_from_slice(pi);
            plens[w] = pi.len() as i32;
            lens[w] = pi.len() as i32;
            abs_pos[w] = pi.len() as i32;
            slots[w] = Some(LiveSeq {
                pos,
                rng: task_rng(seed, idx),
                gen: GenSeq::new(idx, pi.clone()),
            });
            w += 1;
        }
        if w == 0 {
            bail!(
                "continuous rollout deadlock: cannot admit any sequence \
                 (reserve {} > free KV {} of {})",
                sched.reserve_per_seq,
                kv.available(),
                kv.capacity()
            );
        }
        for slot in w..r {
            ids[slot * p_len] = BOS;
        }
        let mut logp = b.prefill(&ids, &plens)?;
        stats.prefills += 1;
        stats.prefill_blocked_ticks += costs.prefill_ticks;
        stats.max_reserved_kv = stats.max_reserved_kv.max(kv.reserved());

        let mut tokens = vec![PAD; r];
        let mut do_mask = vec![0.0f32; r];
        loop {
            // ---- sample one token per occupied slot; retire finishers ---
            for slot in 0..r {
                let Some(live) = slots[slot].as_mut() else {
                    tokens[slot] = PAD;
                    continue;
                };
                let dist = &logp[slot * vocab..(slot + 1) * vocab];
                let (tok, done) = self.sample_step(
                    &mut live.rng,
                    dist,
                    &mut live.gen,
                    lens[slot],
                    abs_pos[slot],
                    capacity,
                    max_seq,
                );
                tokens[slot] = tok;
                if done {
                    // per-sequence release: THE difference from the static
                    // engine — the KV reservation frees now, not when the
                    // whole batch drains
                    let live = slots[slot].take().expect("occupied");
                    sched.release_seq(kv, seq_id_base + live.pos as u64)?;
                    results[live.pos] = Some(live.gen);
                    tokens[slot] = PAD;
                }
            }

            // ---- slot recycling: refill freed slots from the queue ------
            for slot in 0..r {
                if slots[slot].is_some() {
                    continue;
                }
                while let Some(&pos) = queue.front() {
                    if !sched.try_admit(kv, seq_id_base + pos as u64, tasks[pos].1.prompt_ids.len())
                    {
                        break; // memory wall: retry after future releases
                    }
                    queue.pop_front();
                    let (idx, task) = tasks[pos];
                    let pi = &task.prompt_ids;
                    assert!(pi.len() <= p_len, "prompt {} > {}", pi.len(), p_len);
                    let row = b.prefill_slot(slot, pi)?;
                    stats.slot_prefills += 1;
                    stats.refills += 1;
                    // serial engine: the whole decode batch stalls for this
                    // slot prefill — the bubble the pipelined lane removes
                    stats.prefill_blocked_ticks += costs.slot_prefill_ticks;
                    stats.max_reserved_kv = stats.max_reserved_kv.max(kv.reserved());
                    let mut live = LiveSeq {
                        pos,
                        rng: task_rng(seed, idx),
                        gen: GenSeq::new(idx, pi.clone()),
                    };
                    // first token comes from the slot-prefill logits — the
                    // same logits (and the same per-token semantics, via
                    // sample_step) the batched-prefill path would have used
                    let plen = pi.len() as i32;
                    let (tok, done) = self.sample_step(
                        &mut live.rng,
                        &row,
                        &mut live.gen,
                        plen,
                        plen,
                        capacity,
                        max_seq,
                    );
                    // prefill_slot replaced this slot's cache, so the
                    // control vectors must track it even when the sequence
                    // dies immediately — a stale lens would make the next
                    // decode write at an out-of-sync position
                    tokens[slot] = tok;
                    lens[slot] = plen;
                    abs_pos[slot] = plen;
                    if done {
                        // degenerate single-token sequence: release and try
                        // the next pending prompt for this same slot
                        sched.release_seq(kv, seq_id_base + live.pos as u64)?;
                        results[live.pos] = Some(live.gen);
                        tokens[slot] = PAD;
                        continue;
                    }
                    slots[slot] = Some(live);
                    break;
                }
            }

            // ---- drained? -----------------------------------------------
            let occupied = slots.iter().filter(|s| s.is_some()).count();
            if occupied == 0 {
                if queue.is_empty() {
                    break;
                }
                bail!(
                    "continuous rollout stalled: {} pending but nothing \
                     admissible (reserve {} > free KV {})",
                    queue.len(),
                    sched.reserve_per_seq,
                    kv.available()
                );
            }

            // ---- compression trigger (same per-sequence rule as static) -
            if sparse {
                let mut any = false;
                for slot in 0..r {
                    let need = slots[slot].is_some() && lens[slot] as usize >= capacity;
                    do_mask[slot] = if need { 1.0 } else { 0.0 };
                    if need {
                        any = true;
                    }
                }
                if any {
                    b.compress(&do_mask)?;
                    stats.decode_busy_ticks += costs.compress_ticks;
                    for slot in 0..r {
                        if do_mask[slot] > 0.0 {
                            let live = slots[slot].as_mut().expect("masked slot occupied");
                            live.gen.accounting.compression(capacity - budget);
                            lens[slot] = budget as i32;
                            // paged admission: the freed residency returns
                            // to the pool immediately (no-op worst-case)
                            sched.compressed(kv, seq_id_base + live.pos as u64, budget)?;
                        }
                    }
                }
            }

            // ---- paged growth: every occupied slot must hold pages for
            // its next cache write. A grow refused by the wall preempts
            // the lowest-progress live sequence (possibly the grower
            // itself) and requeues it — per-task RNG makes the rerun
            // token-identical, so preemption costs decode steps but never
            // changes outputs. (Worst-case admission: grow is a no-op.)
            for slot in 0..r {
                loop {
                    let Some(live) = slots[slot].as_ref() else { break };
                    let pos = live.pos;
                    let need = lens[slot] as usize + 1;
                    if sched.grow(kv, seq_id_base + pos as u64, need)? {
                        break;
                    }
                    let victim = (0..r)
                        .filter_map(|s| {
                            slots[s]
                                .as_ref()
                                .map(|l| (l.gen.response_ids.len(), l.pos, s))
                        })
                        .min()
                        .expect("the grower itself is live")
                        .2;
                    let v = slots[victim].take().expect("victim occupied");
                    sched.preempt(kv, seq_id_base + v.pos as u64)?;
                    queue.push_front(v.pos);
                    tokens[victim] = PAD;
                    stats.preemptions += 1;
                    if victim == slot {
                        break; // grower evicted: its slot is free now
                    }
                }
            }
            debug_assert!(kv.check_invariants().is_ok(), "wall invariants broken mid-rollout");
            stats.max_reserved_kv = stats.max_reserved_kv.max(kv.reserved());
            stats.max_used_pages = stats.max_used_pages.max(kv.used_pages());

            // ---- one decode step over the mixed batch -------------------
            // (recount: paged growth may have preempted slots; the guard
            // above guarantees at least one survivor)
            let occupied = slots.iter().filter(|s| s.is_some()).count();
            stats.peak_live_slots = stats.peak_live_slots.max(occupied);
            logp = b.decode(&lens, &abs_pos, &tokens)?;
            stats.decode_steps += 1;
            stats.decode_busy_ticks += costs.decode_ticks;
            stats.occupied_slot_steps += occupied;
            stats.idle_slot_steps += r - occupied;
            for slot in 0..r {
                if slots[slot].is_some() {
                    lens[slot] += 1;
                    abs_pos[slot] += 1;
                }
            }
        }

        // serial engine: makespan is the sum of everything the lane did
        stats.modeled_makespan_ticks =
            stats.decode_busy_ticks + stats.prefill_blocked_ticks + stats.sched_stall_ticks;
        let out = results
            .into_iter()
            .map(|s| s.expect("every queued task completed"))
            .collect();
        Ok((out, stats))
    }

    /// Pipelined rollout: a pool of worker threads drives one in-flight
    /// decode batch each against a SHARED scheduler/KV wall, with slot
    /// prefills issued to a dedicated prefill lane so recycling overlaps
    /// decode instead of stalling it.
    ///
    /// The modeled hardware (virtual clock, `CostModel` ticks) is
    /// disaggregated serving: one decode lane per worker plus a single
    /// shared prefill lane. The continuous engine on the same cost model
    /// is the serial baseline — one lane that pays every slot prefill
    /// inline. `bench_rollout` holds the pipelined makespan strictly below
    /// it.
    ///
    /// Mechanics per worker (each owns `backends[w]`):
    /// * admissions (`try_admit`), releases, preemptions, and compression
    ///   shrinks go through the shared `Scheduler`/`KvMemoryManager`
    ///   behind one mutex; decode/prefill device calls run outside it;
    /// * a freed slot's next prompt is admitted immediately, but its
    ///   `prefill_slot` is *deferred* to the prefill lane: the slot idles
    ///   (PAD) until the lane's virtual clock reaches its ready time,
    ///   then joins the decode batch — so neighbours never stall;
    /// * a paged grow stall preempts the lowest-progress sequence of the
    ///   worker's OWN batch (cross-worker caches are untouchable) and
    ///   requeues it on the shared queue — any worker may rerun it;
    /// * a worker whose batch drains while the queue is non-empty parks
    ///   until a peer releases KV; its virtual clock jumps to the
    ///   release's timestamp (`sched_stall_ticks`).
    ///
    /// Token identity with `continuous` holds by construction: per-task
    /// RNG plus batch-row independence make a task's tokens a pure
    /// function of (seed, task) regardless of worker, slot, join step, or
    /// preemption — `tests/engine_equivalence.rs` enforces it for worker
    /// counts 1/2/4. Results come back in task order. Work counters in
    /// the merged stats sum over lanes; `modeled_makespan_ticks` is the
    /// lane max and `peak_live_slots` the peak globally admitted width.
    pub fn rollout_pipelined<B: RolloutBackend + Send>(
        &self,
        backends: &mut [B],
        tasks: &[(usize, &Task)],
        seed: u64,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let workers = backends.len();
        if workers == 0 {
            bail!("pipelined rollout needs at least one worker backend");
        }
        let n = tasks.len();
        if n == 0 {
            return Ok((vec![], RolloutStats { workers, ..RolloutStats::default() }));
        }
        // every worker must see the same model geometry — they share one
        // task queue and one wall
        let b0 = &backends[0];
        let geom = (b0.slots(), b0.prompt_len(), b0.max_seq(), b0.vocab(), b0.capacity(), b0.budget());
        for b in backends.iter() {
            let g = (b.slots(), b.prompt_len(), b.max_seq(), b.vocab(), b.capacity(), b.budget());
            if g != geom {
                bail!("pipelined worker backends disagree on geometry: {g:?} vs {geom:?}");
            }
        }
        // same progress guarantee as the continuous engine: a lone
        // sequence must be able to grow to its worst-case residency
        if kv.pages_for(sched.reserve_per_seq) > kv.total_pages() {
            bail!(
                "pipelined rollout deadlock: one sequence may need {} KV tokens \
                 but the wall holds only {}",
                sched.reserve_per_seq,
                kv.capacity()
            );
        }

        let shared = Mutex::new(PipeShared {
            queue: (0..n).collect(),
            sched,
            kv,
            results: (0..n).map(|_| None).collect(),
            lane_clock: 0,
            release_floor: 0,
            live_now: 0,
            peak_live: 0,
            failed: None,
        });
        let cv = Condvar::new();
        let (shared, cv) = (&shared, &cv);
        let policy = *self;

        let joined = std::thread::scope(|scope| {
            let handles: Vec<_> = backends
                .iter_mut()
                .map(|b| {
                    scope.spawn(move || {
                        let out =
                            policy.pipelined_worker(b, tasks, seed, seq_id_base, shared, cv);
                        if let Err(e) = &out {
                            // poison the run so parked peers bail out
                            // instead of waiting on releases that will
                            // never come
                            if let Ok(mut sh) = shared.lock() {
                                if sh.failed.is_none() {
                                    sh.failed = Some(e.to_string());
                                }
                            }
                            cv.notify_all();
                        }
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join())
                .collect::<Vec<_>>()
        });

        let mut stats = RolloutStats::default();
        let mut makespan = 0u64;
        for res in joined {
            let (ws, finish) =
                res.unwrap_or_else(|_| Err(anyhow::anyhow!("pipelined worker panicked")))?;
            stats.merge(&ws);
            makespan = makespan.max(finish);
        }
        stats.workers = workers;
        stats.modeled_makespan_ticks = makespan;
        let mut sh = shared
            .lock()
            .map_err(|_| anyhow::anyhow!("pipelined shared state poisoned"))?;
        stats.peak_live_slots = stats.peak_live_slots.max(sh.peak_live);
        let mut out = Vec::with_capacity(n);
        for (pos, seq) in sh.results.iter_mut().enumerate() {
            match seq.take() {
                Some(s) => out.push(s),
                None => bail!("pipelined rollout dropped task at position {pos}"),
            }
        }
        Ok((out, stats))
    }

    /// One pipelined worker lane: a continuous-style decode loop over its
    /// own backend, coordinating admission/release/growth through the
    /// shared state and deferring slot prefills to the shared prefill
    /// lane. Returns its stats and its final virtual clock.
    fn pipelined_worker<B: RolloutBackend>(
        &self,
        b: &mut B,
        tasks: &[(usize, &Task)],
        seed: u64,
        seq_id_base: u64,
        shared: &Mutex<PipeShared<'_>>,
        cv: &Condvar,
    ) -> Result<(RolloutStats, u64)> {
        let r = b.slots();
        let p_len = b.prompt_len();
        let max_seq = b.max_seq();
        let vocab = b.vocab();
        let capacity = b.capacity();
        let budget = b.budget();
        let costs = b.cost_model();
        let sparse = self.mode.is_sparse();
        let lock = || {
            shared
                .lock()
                .map_err(|_| anyhow::anyhow!("pipelined shared state poisoned"))
        };

        let mut stats = RolloutStats { chunks: 1, workers: 1, ..RolloutStats::default() };
        // this lane's virtual clock (ticks on the backend's cost model)
        let mut now = 0u64;
        let mut slots: Vec<Option<LiveSeq>> = (0..r).map(|_| None).collect();
        let mut pending: Vec<Option<PendingRefill>> = (0..r).map(|_| None).collect();
        let mut lens = vec![1i32; r];
        let mut abs_pos = vec![1i32; r];
        let mut tokens = vec![PAD; r];
        let mut do_mask = vec![0.0f32; r];
        // slots whose row in `logp` is fresh (sampled at the loop top);
        // freshly joined slots carry an already-sampled token instead
        let mut decoded = vec![false; r];
        let mut logp: Vec<f32> = Vec::new();

        // ---- initial wave: admit a batch head, one batched prefill ------
        let mut ids = vec![PAD; r * p_len];
        let mut plens = vec![1i32; r];
        let mut w = 0usize;
        {
            let mut guard = lock()?;
            while w < r {
                let Some(pos) = guard.admit_front(tasks, seq_id_base) else { break };
                let (idx, task) = tasks[pos];
                let pi = &task.prompt_ids;
                assert!(pi.len() <= p_len, "prompt {} > {}", pi.len(), p_len);
                ids[w * p_len..w * p_len + pi.len()].copy_from_slice(pi);
                plens[w] = pi.len() as i32;
                lens[w] = pi.len() as i32;
                abs_pos[w] = pi.len() as i32;
                slots[w] = Some(LiveSeq {
                    pos,
                    rng: task_rng(seed, idx),
                    gen: GenSeq::new(idx, pi.clone()),
                });
                w += 1;
            }
            guard.snap_residency(&mut stats);
        }
        if w > 0 {
            for slot in w..r {
                ids[slot * p_len] = BOS;
            }
            // the batched prefill shares the single modeled prefill lane
            // with every other worker's; the decode lane blocks on it
            // (nothing to decode before the first logits anyway)
            let ready = lock()?.lane_issue(now, costs.prefill_ticks);
            logp = b.prefill(&ids, &plens)?;
            stats.prefills += 1;
            stats.prefill_blocked_ticks += ready - now;
            now = ready;
            for d in decoded.iter_mut().take(w) {
                *d = true;
            }
        }

        loop {
            // ---- sample from fresh logits; release finishers ------------
            let mut released = false;
            for slot in 0..r {
                if !decoded[slot] {
                    if slots[slot].is_none() && pending[slot].is_none() {
                        tokens[slot] = PAD;
                    }
                    continue;
                }
                decoded[slot] = false;
                let Some(live) = slots[slot].as_mut() else {
                    tokens[slot] = PAD;
                    continue;
                };
                let dist = &logp[slot * vocab..(slot + 1) * vocab];
                let (tok, done) = self.sample_step(
                    &mut live.rng,
                    dist,
                    &mut live.gen,
                    lens[slot],
                    abs_pos[slot],
                    capacity,
                    max_seq,
                );
                tokens[slot] = tok;
                if done {
                    let live = slots[slot].take().expect("occupied");
                    let mut guard = lock()?;
                    let sh = &mut *guard;
                    sh.sched.release_seq(sh.kv, seq_id_base + live.pos as u64)?;
                    sh.release_at(now);
                    sh.results[live.pos] = Some(live.gen);
                    tokens[slot] = PAD;
                    released = true;
                }
            }
            if released {
                cv.notify_all();
            }

            // ---- join refills whose lane prefill has completed ----------
            for slot in 0..r {
                let ready = matches!(&pending[slot], Some(p) if p.ready_at <= now);
                if !ready {
                    continue;
                }
                let p = pending[slot].take().expect("checked above");
                let (idx, task) = tasks[p.pos];
                let pi = &task.prompt_ids;
                assert!(pi.len() <= p_len, "prompt {} > {}", pi.len(), p_len);
                let row = if stats.prefills == 0 {
                    // this lane's whole first wave was refused at the wall,
                    // so it has no live cache yet and the real backend's
                    // prefill_slot would reject: run the batched entry with
                    // just this prompt instead — batch-row independence
                    // makes the slot's logits identical either way
                    let mut jids = vec![PAD; r * p_len];
                    let mut jplens = vec![1i32; r];
                    jids[slot * p_len..slot * p_len + pi.len()].copy_from_slice(pi);
                    jplens[slot] = pi.len() as i32;
                    for (s, chunk) in jids.chunks_mut(p_len).enumerate() {
                        if s != slot {
                            chunk[0] = BOS;
                        }
                    }
                    let all = b.prefill(&jids, &jplens)?;
                    stats.prefills += 1;
                    all[slot * vocab..(slot + 1) * vocab].to_vec()
                } else {
                    stats.slot_prefills += 1;
                    b.prefill_slot(slot, pi)?
                };
                stats.refills += 1;
                let mut live = LiveSeq {
                    pos: p.pos,
                    rng: task_rng(seed, idx),
                    gen: GenSeq::new(idx, pi.clone()),
                };
                // identical per-token semantics to the continuous refill
                // path: first token from the slot-prefill logits
                let plen = pi.len() as i32;
                let (tok, done) = self.sample_step(
                    &mut live.rng,
                    &row,
                    &mut live.gen,
                    plen,
                    plen,
                    capacity,
                    max_seq,
                );
                tokens[slot] = tok;
                lens[slot] = plen;
                abs_pos[slot] = plen;
                decoded[slot] = false;
                if done {
                    // degenerate single-token sequence: release; the slot
                    // frees for the next admission pass below
                    let mut guard = lock()?;
                    let sh = &mut *guard;
                    sh.sched.release_seq(sh.kv, seq_id_base + live.pos as u64)?;
                    sh.release_at(now);
                    sh.results[p.pos] = Some(live.gen);
                    drop(guard);
                    cv.notify_all();
                    tokens[slot] = PAD;
                    continue;
                }
                slots[slot] = Some(live);
            }

            // ---- issue refills: admit + queue on the prefill lane -------
            {
                let mut guard = lock()?;
                for slot in 0..r {
                    if slots[slot].is_some() || pending[slot].is_some() {
                        continue;
                    }
                    let Some(pos) = guard.admit_front(tasks, seq_id_base) else {
                        break; // queue empty, or wall: retry after releases
                    };
                    let ready_at = guard.lane_issue(now, costs.slot_prefill_ticks);
                    pending[slot] = Some(PendingRefill { pos, ready_at });
                    guard.snap_residency(&mut stats);
                }
            }

            // ---- empty lane: wait for a join, a release, or the drain ---
            let occupied = slots.iter().filter(|s| s.is_some()).count();
            if occupied == 0 {
                if let Some(t) = pending.iter().flatten().map(|p| p.ready_at).min() {
                    // nothing decodable while the lane prefills: the
                    // decode lane waits for the earliest join
                    stats.prefill_blocked_ticks += t.saturating_sub(now);
                    now = now.max(t);
                    continue;
                }
                let mut guard = lock()?;
                if guard.queue.is_empty() {
                    break; // worker done (peers drain their own batches)
                }
                // the queue has work this lane cannot admit: a peer holds
                // the wall. Park until a release (releases notify; the
                // timeout re-checks `failed` and the deadlock predicate,
                // never aborting a merely-slow run).
                let stall_start = now;
                let admitted = loop {
                    if let Some(e) = &guard.failed {
                        bail!("pipelined peer failed: {e}");
                    }
                    if guard.queue.is_empty() {
                        break false;
                    }
                    if let Some(pos) = guard.admit_front(tasks, seq_id_base) {
                        // honest virtual time: this admission only became
                        // possible when a peer released KV
                        now = now.max(guard.release_floor);
                        let ready_at = guard.lane_issue(now, costs.slot_prefill_ticks);
                        pending[0] = Some(PendingRefill { pos, ready_at });
                        guard.snap_residency(&mut stats);
                        break true;
                    }
                    // state-based deadlock check (NOT wall-clock based — a
                    // slow real backend may take arbitrarily long between
                    // releases): with no sequence admitted anywhere, no
                    // future release can ever free room, so a refusal now
                    // is a refusal forever.
                    if guard.live_now == 0 {
                        bail!(
                            "pipelined rollout stalled: {} pending but nothing \
                             admissible on an idle wall (reserve {} > free KV {})",
                            guard.queue.len(),
                            guard.sched.reserve_per_seq,
                            guard.kv.available()
                        );
                    }
                    let (g, _) = cv
                        .wait_timeout(guard, Duration::from_millis(2))
                        .map_err(|_| anyhow::anyhow!("pipelined shared state poisoned"))?;
                    guard = g;
                };
                drop(guard);
                if !admitted {
                    break; // queue drained while waiting: worker done
                }
                stats.sched_stall_ticks += now - stall_start;
                continue; // the pending refill joins via the lane
            }

            // ---- compression trigger (same per-sequence rule) -----------
            if sparse {
                let mut any = false;
                for slot in 0..r {
                    let need = slots[slot].is_some() && lens[slot] as usize >= capacity;
                    do_mask[slot] = if need { 1.0 } else { 0.0 };
                    if need {
                        any = true;
                    }
                }
                if any {
                    b.compress(&do_mask)?;
                    now += costs.compress_ticks;
                    stats.decode_busy_ticks += costs.compress_ticks;
                    let mut guard = lock()?;
                    let sh = &mut *guard;
                    for slot in 0..r {
                        if do_mask[slot] > 0.0 {
                            let live = slots[slot].as_mut().expect("masked slot occupied");
                            live.gen.accounting.compression(capacity - budget);
                            lens[slot] = budget as i32;
                            sh.sched.compressed(sh.kv, seq_id_base + live.pos as u64, budget)?;
                        }
                    }
                }
            }

            // ---- paged growth; stalls preempt from the OWN batch --------
            {
                let mut guard = lock()?;
                let sh = &mut *guard;
                let mut preempted = false;
                for slot in 0..r {
                    loop {
                        let Some(live) = slots[slot].as_ref() else { break };
                        let pos = live.pos;
                        let need = lens[slot] as usize + 1;
                        if sh.sched.grow(sh.kv, seq_id_base + pos as u64, need)? {
                            sh.snap_residency(&mut stats);
                            break;
                        }
                        // cross-worker caches are untouchable, so the
                        // victim comes from this worker's batch; freed
                        // pages help every lane (notify below)
                        let victim = (0..r)
                            .filter_map(|s| {
                                slots[s]
                                    .as_ref()
                                    .map(|l| (l.gen.response_ids.len(), l.pos, s))
                            })
                            .min()
                            .expect("the grower itself is live")
                            .2;
                        let v = slots[victim].take().expect("victim occupied");
                        sh.sched.preempt(sh.kv, seq_id_base + v.pos as u64)?;
                        sh.release_at(now);
                        sh.queue.push_front(v.pos);
                        tokens[victim] = PAD;
                        decoded[victim] = false;
                        stats.preemptions += 1;
                        preempted = true;
                        if victim == slot {
                            break; // grower evicted: its slot is free now
                        }
                    }
                }
                debug_assert!(
                    sh.kv.check_invariants().is_ok(),
                    "wall invariants broken mid-rollout"
                );
                drop(guard);
                if preempted {
                    cv.notify_all();
                }
            }

            // ---- one decode step over the mixed batch -------------------
            let occupied = slots.iter().filter(|s| s.is_some()).count();
            if occupied == 0 {
                continue; // growth evicted the whole batch: re-admit/wait
            }
            stats.peak_live_slots = stats.peak_live_slots.max(occupied);
            logp = b.decode(&lens, &abs_pos, &tokens)?;
            now += costs.decode_ticks;
            stats.decode_steps += 1;
            stats.decode_busy_ticks += costs.decode_ticks;
            stats.occupied_slot_steps += occupied;
            stats.idle_slot_steps += r - occupied;
            for slot in 0..r {
                decoded[slot] = slots[slot].is_some();
                if slots[slot].is_some() {
                    lens[slot] += 1;
                    abs_pos[slot] += 1;
                }
            }
        }

        Ok((stats, now))
    }
}

/// The artifact-bound rollout engine for one model + mode.
pub struct RolloutEngine<'a> {
    pub engine: &'a ModelEngine,
    pub mode: RolloutMode,
    pub sampling: SamplingConfig,
}

impl<'a> RolloutEngine<'a> {
    pub fn new(engine: &'a ModelEngine, mode: RolloutMode, sampling: SamplingConfig) -> Self {
        RolloutEngine { engine, mode, sampling }
    }

    pub fn policy(&self) -> RolloutPolicy {
        RolloutPolicy::new(self.mode, self.sampling)
    }

    pub fn variant(&self) -> Variant {
        if self.mode.is_sparse() {
            Variant::Sparse
        } else {
            Variant::Dense
        }
    }

    /// Roll out one static chunk of tasks (≤ decode_batch sequences; the
    /// scheduler guarantees admission). `seed` is the rollout seed feeding
    /// the per-task RNG streams.
    pub fn rollout_chunk(
        &self,
        params: &[f32],
        tasks: &[(usize, &Task)],
        seed: u64,
    ) -> Result<Vec<GenSeq>> {
        // weights are uploaded once per chunk, not once per decode step
        let params = ParamsLit::new(params);
        self.rollout_chunk_lit(&params, tasks, seed)
    }

    /// Same as `rollout_chunk` but with pre-uploaded weights (callers that
    /// run many chunks per step share one upload).
    pub fn rollout_chunk_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
    ) -> Result<Vec<GenSeq>> {
        Ok(self.rollout_chunk_stats_lit(params, tasks, seed)?.0)
    }

    /// Static chunk rollout returning occupancy statistics as well.
    pub fn rollout_chunk_stats_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let mut backend = EngineBackend::new(self.engine, params, self.mode);
        self.policy().rollout_static(&mut backend, tasks, seed)
    }

    /// Static chunked rollout over the whole pending queue (any length).
    /// See `RolloutPolicy::rollout_static_queue`.
    pub fn rollout_static_queue_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let mut backend = EngineBackend::new(self.engine, params, self.mode);
        self.policy()
            .rollout_static_queue(&mut backend, tasks, seed, sched, kv, seq_id_base)
    }

    /// Continuous-batching rollout over the whole pending queue (any
    /// length), recycling slots as sequences finish. See
    /// `RolloutPolicy::rollout_continuous`.
    pub fn rollout_continuous_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let mut backend = EngineBackend::new(self.engine, params, self.mode);
        self.policy()
            .rollout_continuous(&mut backend, tasks, seed, sched, kv, seq_id_base)
    }

    /// Pipelined rollout over the whole pending queue: `workers` decode
    /// lanes (one `EngineBackend` each, all over this engine's artifacts)
    /// against the shared scheduler/wall. See
    /// `RolloutPolicy::rollout_pipelined`. This is the "handle story" for
    /// the production path: `ModelEngine` is `Sync` (executable cache
    /// behind a mutex), so N worker threads may each own an
    /// `EngineBackend` borrowing the same engine + uploaded weights.
    #[allow(clippy::too_many_arguments)]
    pub fn rollout_pipelined_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
        workers: usize,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let mut backends: Vec<EngineBackend> = (0..workers.max(1))
            .map(|_| EngineBackend::new(self.engine, params, self.mode))
            .collect();
        self.policy()
            .rollout_pipelined(&mut backends, tasks, seed, sched, kv, seq_id_base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(t: f32, p: f32) -> SamplingConfig {
        SamplingConfig { temperature: t, top_p: p, max_response: 16 }
    }

    #[test]
    fn sample_token_records_exact_logp_at_unit_temp() {
        let mut rng = Rng::new(1);
        let logp = [-0.5f32, -1.5, -3.0];
        for _ in 0..50 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(1.0, 1.0));
            assert_eq!(lp, logp[tok]);
        }
    }

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(2);
        let logp = [-2.0f32, -0.1, -5.0];
        for _ in 0..20 {
            let (tok, _) = sample_token(&mut rng, &logp, &cfg(0.0, 1.0));
            assert_eq!(tok, 1);
        }
    }

    #[test]
    fn tempered_logp_is_normalized() {
        let mut rng = Rng::new(3);
        let logp = [-0.7f32, -1.1, -2.0, -2.5];
        // collect the modified distribution empirically
        let mut mass = [0.0f64; 4];
        let n = 30_000;
        for _ in 0..n {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(0.7, 0.95));
            mass[tok] += 1.0;
            // recorded logp must be a valid log-probability
            assert!(lp <= 0.0 && lp.is_finite());
        }
        let total: f64 = mass.iter().sum();
        assert_eq!(total as usize, n);
        // last token should be rarer than first under sharpening
        assert!(mass[0] > mass[3]);
    }

    #[test]
    fn nan_logits_do_not_panic_and_carry_no_mass() {
        let mut rng = Rng::new(4);
        let logp = [f32::NAN, -1.0, f32::NAN, -2.0];
        for _ in 0..200 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(0.8, 0.9));
            assert!(tok == 1 || tok == 3, "sampled NaN token {tok}");
            assert!(lp.is_finite() && lp <= 0.0);
        }
        // the T=1/top-p=1 default config must be just as hardened (it
        // normally takes the exact-logp fast path)
        for _ in 0..200 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(1.0, 1.0));
            assert!(tok == 1 || tok == 3, "fast path sampled NaN token {tok}");
            assert!(lp.is_finite() && lp <= 0.0);
        }
        // fully degenerate input: uniform fallback, still no panic
        let bad = [f32::NAN; 5];
        for _ in 0..50 {
            let (tok, lp) = sample_token(&mut rng, &bad, &cfg(0.8, 0.9));
            assert!(tok < 5);
            assert!((lp - (-(5f32).ln())).abs() < 1e-6);
        }
    }

    #[test]
    fn top1_exceeding_top_p_keeps_exactly_argmax() {
        let mut rng = Rng::new(5);
        // token 1 holds ~99% of the tempered mass, far beyond top_p = 0.5:
        // the nucleus must be {1} with renormalized mass 1 (log-prob 0)
        let logp = [-8.0f32, -0.01, -9.0, -10.0];
        for _ in 0..100 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(0.9, 0.5));
            assert_eq!(tok, 1);
            assert_eq!(lp, 0.0, "renormalized point mass must be exactly 1");
        }
    }

    #[test]
    fn stats_merge_sums_work_and_maxes_peaks() {
        let a = RolloutStats {
            chunks: 1,
            decode_steps: 10,
            occupied_slot_steps: 30,
            idle_slot_steps: 10,
            refills: 2,
            prefills: 1,
            slot_prefills: 2,
            max_reserved_kv: 100,
            max_used_pages: 5,
            peak_live_slots: 4,
            preemptions: 1,
            workers: 1,
            decode_busy_ticks: 100,
            prefill_blocked_ticks: 40,
            sched_stall_ticks: 0,
            modeled_makespan_ticks: 140,
        };
        let b = RolloutStats {
            chunks: 1,
            decode_steps: 5,
            occupied_slot_steps: 15,
            idle_slot_steps: 5,
            max_reserved_kv: 80,
            max_used_pages: 9,
            peak_live_slots: 2,
            workers: 1,
            decode_busy_ticks: 50,
            prefill_blocked_ticks: 40,
            sched_stall_ticks: 7,
            modeled_makespan_ticks: 97,
            ..RolloutStats::default()
        };
        let mut m = a;
        m.merge(&b);
        // work counters sum (serial composition)...
        assert_eq!(m.decode_steps, 15);
        assert_eq!(m.device_slot_steps(), 60);
        assert_eq!(m.decode_busy_ticks, 150);
        assert_eq!(m.prefill_blocked_ticks, 80);
        assert_eq!(m.sched_stall_ticks, 7);
        assert_eq!(m.modeled_makespan_ticks, 237);
        // ...high-water marks take the max
        assert_eq!(m.max_reserved_kv, 100);
        assert_eq!(m.max_used_pages, 9);
        assert_eq!(m.peak_live_slots, 4);
        // denominator contract: slot-steps stay per-device-step, so the
        // merged occupancy is the slot-step-weighted mean
        assert!((m.occupancy() - 45.0 / 60.0).abs() < 1e-12);
        assert!((m.idle_frac() - 15.0 / 60.0).abs() < 1e-12);
    }

    #[test]
    fn task_rng_is_slot_and_order_independent() {
        // same (seed, task) => same stream; different task => different
        let mut a = task_rng(42, 7);
        let mut b = task_rng(42, 7);
        let mut c = task_rng(42, 8);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }
}
