//! Autoregressive rollout engines (dense and sparse paths; static chunked
//! and continuous batching).
//!
//! Drives the prefill/decode/compress backend over sequences occupying the
//! decode batch's slots. The engines own sampling (temperature / top-p),
//! EOS handling, per-token sampler log-prob recording (this *is*
//! log π_sparse — Eq. 2 — the number the corrections need), KV compression
//! triggering, and KV accounting.
//!
//! Two data paths share all of that per-sequence logic:
//!
//! * **Static chunked** (`rollout_static`): a chunk of ≤ R sequences is
//!   prefilled together and decodes until the *slowest* sequence finishes.
//!   Every slot whose sequence hit EOS early burns PAD decode work until
//!   the chunk drains — the long-tail bubble.
//! * **Continuous with slot recycling** (`rollout_continuous`): the moment
//!   a sequence finishes, its KV reservation is released, the next pending
//!   prompt is admitted, prefilled *into that slot in place*, and the
//!   mixed batch keeps decoding. Total decode steps drop from
//!   Σ_chunks max(len) to the list-scheduling makespan of the per-sequence
//!   decode costs — strictly better whenever response lengths are skewed.
//!
//! Token-for-token equivalence between the two paths is guaranteed by
//! per-TASK RNG streams (`task_rng`): a task's sampling randomness is a
//! pure function of (rollout seed, task index), never of the slot or chunk
//! it lands in. Combined with batch-row independence of the model, a given
//! task emits identical `response_ids` and `sampler_logp` under both
//! engines — which keeps the Eq. 2/5 correction math bit-reproducible and
//! is what `tests/engine_equivalence.rs` checks exhaustively.
//!
//! The sparse path realizes the paper's rollout: the cache holds at most
//! `budget + buffer` slots; whenever a sequence fills the buffer, the
//! compression artifact compacts it back to `budget` retained tokens.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::compression::KvAccounting;
use crate::config::{RolloutMode, SamplingConfig};
use crate::data::task::Task;
use crate::data::tokenizer::{BOS, EOS, PAD};
use crate::runtime::{ModelEngine, ParamsLit, Variant};
use crate::util::rng::Rng;

use super::backend::{EngineBackend, RolloutBackend};
use super::kv_manager::KvMemoryManager;
use super::scheduler::Scheduler;

/// One finished rollout.
#[derive(Debug, Clone)]
pub struct GenSeq {
    /// Caller-side identifier (index into the step's task list).
    pub task_idx: usize,
    pub prompt_ids: Vec<i32>,
    /// Generated tokens (includes the terminating EOS when finished).
    pub response_ids: Vec<i32>,
    /// log π_sparse(o_t | ·) of every generated token (the actual sampling
    /// distribution, i.e. after temperature/top-p modification).
    pub sampler_logp: Vec<f32>,
    /// True iff the model emitted EOS before the length cap.
    pub finished: bool,
    pub accounting: KvAccounting,
}

impl GenSeq {
    fn new(task_idx: usize, prompt_ids: Vec<i32>) -> GenSeq {
        GenSeq {
            task_idx,
            prompt_ids,
            response_ids: vec![],
            sampler_logp: vec![],
            finished: false,
            accounting: KvAccounting::new(),
        }
    }

    /// Full sequence ids: prompt + response.
    pub fn full_ids(&self) -> Vec<i32> {
        let mut v = self.prompt_ids.clone();
        v.extend_from_slice(&self.response_ids);
        v
    }
}

/// Per-task RNG stream: a pure function of (rollout seed, task index).
/// A given task therefore samples the identical token sequence no matter
/// which slot, chunk, or engine (static vs continuous) runs it.
pub fn task_rng(seed: u64, task_idx: usize) -> Rng {
    Rng::new(seed ^ (task_idx as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Sample from log-probs with temperature/top-p; returns the token and the
/// log-prob of the token under the *modified* (actually sampled)
/// distribution. With temperature=1, top_p=1 this is exactly `logp[tok]`.
///
/// Robustness: non-finite logits (NaN from a diverged model, ±inf) carry
/// zero mass instead of poisoning the sort/normalization; if *every* logit
/// is non-finite the sampler falls back to a uniform draw. The top-p
/// nucleus always keeps at least one token — when the top-1 probability
/// alone exceeds `top_p`, the cut is exactly {argmax} and its renormalized
/// mass is 1 (recorded log-prob 0).
pub fn sample_token(rng: &mut Rng, logp: &[f32], s: &SamplingConfig) -> (usize, f32) {
    if s.temperature < 1e-3 {
        // greedy decoding: a point mass (NaN never wins a `>` comparison)
        let (mut best, mut bv) = (0usize, f32::NEG_INFINITY);
        for (i, &l) in logp.iter().enumerate() {
            if l > bv {
                best = i;
                bv = l;
            }
        }
        return (best, 0.0);
    }
    if (s.temperature - 1.0).abs() < 1e-6
        && s.top_p >= 1.0
        && logp.iter().all(|l| l.is_finite())
    {
        // unmodified distribution: record the artifact's own log-prob
        // bit-exactly (the finite guard keeps NaN inputs on the hardened
        // path below instead of this shortcut)
        let tok = rng.sample_logits(logp, 1.0, 1.0);
        return (tok, logp[tok]);
    }
    // general case: the shared temperature/top-p machinery (single
    // implementation for both samplers — util::rng::modified_probs)
    let Some(probs) = crate::util::rng::modified_probs(logp, s.temperature, s.top_p) else {
        // fully degenerate input: uniform fallback
        let tok = rng.below(logp.len());
        return (tok, -(logp.len() as f32).ln());
    };
    let r = rng.next_f32();
    let mut acc = 0.0;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if r < acc && p > 0.0 {
            return (i, p.ln());
        }
    }
    let last = probs.iter().rposition(|&p| p > 0.0).unwrap_or(0);
    (last, probs[last].ln())
}

/// Throughput/occupancy statistics for one rollout (either engine).
///
/// `occupied_slot_steps` counts, per decode step, the slots doing live
/// generation; `idle_slot_steps` counts the complement — PAD work on
/// finished or never-admitted slots (the long-tail bubble the continuous
/// engine removes).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RolloutStats {
    /// Scheduled chunks (continuous: one pass over the whole queue).
    pub chunks: usize,
    /// Decode artifact invocations.
    pub decode_steps: usize,
    pub occupied_slot_steps: usize,
    pub idle_slot_steps: usize,
    /// Mid-flight slot refills (continuous only).
    pub refills: usize,
    /// Batched prefill calls.
    pub prefills: usize,
    /// Per-slot (recycling) prefill calls.
    pub slot_prefills: usize,
    /// Max KV tokens reserved simultaneously (continuous only; the
    /// invariant tests check this never exceeds the wall).
    pub max_reserved_kv: usize,
    /// Max pool pages in use simultaneously (continuous only; page
    /// occupancy = this over the manager's `total_pages`).
    pub max_used_pages: usize,
    /// Max concurrently occupied decode slots at any step (the admitted
    /// width the paged-vs-worst-case benches compare).
    pub peak_live_slots: usize,
    /// Sequences preempted and requeued by a paged-admission grow stall
    /// (0 under worst-case admission).
    pub preemptions: usize,
}

impl RolloutStats {
    /// Mean decode-step slot occupancy in [0, 1].
    pub fn occupancy(&self) -> f64 {
        let total = self.occupied_slot_steps + self.idle_slot_steps;
        if total == 0 {
            0.0
        } else {
            self.occupied_slot_steps as f64 / total as f64
        }
    }

    /// Fraction of decode-slot work wasted on idle (PAD) slots.
    pub fn idle_frac(&self) -> f64 {
        let total = self.occupied_slot_steps + self.idle_slot_steps;
        if total == 0 {
            0.0
        } else {
            self.idle_slot_steps as f64 / total as f64
        }
    }

    pub fn merge(&mut self, o: &RolloutStats) {
        self.chunks += o.chunks;
        self.decode_steps += o.decode_steps;
        self.occupied_slot_steps += o.occupied_slot_steps;
        self.idle_slot_steps += o.idle_slot_steps;
        self.refills += o.refills;
        self.prefills += o.prefills;
        self.slot_prefills += o.slot_prefills;
        self.max_reserved_kv = self.max_reserved_kv.max(o.max_reserved_kv);
        self.max_used_pages = self.max_used_pages.max(o.max_used_pages);
        self.peak_live_slots = self.peak_live_slots.max(o.peak_live_slots);
        self.preemptions += o.preemptions;
    }
}

/// The backend-independent rollout policy: mode + sampling. Holds the
/// whole decode-loop logic for both engines; `RolloutEngine` binds it to
/// the AOT artifacts, the test harness binds it to the mock backend.
#[derive(Debug, Clone, Copy)]
pub struct RolloutPolicy {
    pub mode: RolloutMode,
    pub sampling: SamplingConfig,
}

/// A sequence live in a decode slot (continuous engine bookkeeping).
struct LiveSeq {
    /// Position in the pending task list (== results index).
    pos: usize,
    rng: Rng,
    gen: GenSeq,
}

impl RolloutPolicy {
    pub fn new(mode: RolloutMode, sampling: SamplingConfig) -> Self {
        RolloutPolicy { mode, sampling }
    }

    /// Sample one token into `gen` — recording the sampler log-prob and KV
    /// accounting — and report `(token, done)` where `done` means the
    /// sequence just terminated (EOS or a length cap). THE single
    /// implementation of per-token semantics: the static loop, the
    /// continuous loop, and the continuous refill path all call this, so
    /// EOS/cap/accounting rules cannot drift between engines (which would
    /// silently break the token-equivalence contract).
    ///
    /// `len` is the occupied cache length and `abs` the absolute position
    /// *before* this token's cache write.
    fn sample_step(
        &self,
        rng: &mut Rng,
        dist: &[f32],
        gen: &mut GenSeq,
        len: i32,
        abs: i32,
        capacity: usize,
        max_seq: usize,
    ) -> (i32, bool) {
        let (tok, lp) = sample_token(rng, dist, &self.sampling);
        gen.response_ids.push(tok as i32);
        gen.sampler_logp.push(lp);
        gen.accounting
            .step(((len + 1) as usize).min(capacity), abs as usize + 1);
        let mut done = false;
        if tok as i32 == EOS {
            gen.finished = true;
            done = true;
        }
        if gen.response_ids.len() >= self.sampling.max_response
            || (abs as usize + 1) >= max_seq
        {
            done = true;
        }
        (tok as i32, done)
    }

    /// Static chunked rollout of ≤ R sequences (the scheduler guarantees
    /// admission). `tasks` pairs a caller-side index with the task
    /// occupying that slot. The chunk decodes until its slowest sequence
    /// finishes; early-EOS slots stay frozen (PAD-fed) until then.
    pub fn rollout_static<B: RolloutBackend>(
        &self,
        b: &mut B,
        tasks: &[(usize, &Task)],
        seed: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let r = b.slots();
        let p_len = b.prompt_len();
        let max_seq = b.max_seq();
        let vocab = b.vocab();
        let capacity = b.capacity();
        let budget = b.budget();
        let sparse = self.mode.is_sparse();
        assert!(tasks.len() <= r, "chunk of {} > {} slots", tasks.len(), r);
        let mut stats = RolloutStats { chunks: 1, ..RolloutStats::default() };
        if tasks.is_empty() {
            return Ok((vec![], stats));
        }

        // ---- prefill ----------------------------------------------------
        let mut ids = vec![PAD; r * p_len];
        let mut plens = vec![1i32; r];
        for (slot, (_, task)) in tasks.iter().enumerate() {
            let pi = &task.prompt_ids;
            assert!(pi.len() <= p_len, "prompt {} > {}", pi.len(), p_len);
            ids[slot * p_len..slot * p_len + pi.len()].copy_from_slice(pi);
            plens[slot] = pi.len() as i32;
        }
        for slot in tasks.len()..r {
            ids[slot * p_len] = BOS;
        }
        let mut logp = b.prefill(&ids, &plens)?;
        stats.prefills += 1;

        // ---- decode loop -------------------------------------------------
        let n = tasks.len();
        let mut active: Vec<bool> = (0..r).map(|i| i < n).collect();
        let mut lens: Vec<i32> = plens.clone(); // occupied cache slots
        let mut abs_pos: Vec<i32> = plens.clone(); // absolute next position
        let mut out: Vec<GenSeq> = tasks
            .iter()
            .map(|(idx, task)| GenSeq::new(*idx, task.prompt_ids.clone()))
            .collect();
        // per-TASK streams: slot/chunk placement never changes the tokens
        let mut rngs: Vec<Rng> = tasks.iter().map(|(idx, _)| task_rng(seed, *idx)).collect();

        let mut tokens = vec![PAD; r];
        let mut do_mask = vec![0.0f32; r];
        loop {
            // sample next token per active slot
            let mut any_active = false;
            for slot in 0..n {
                if !active[slot] {
                    tokens[slot] = PAD;
                    continue;
                }
                let dist = &logp[slot * vocab..(slot + 1) * vocab];
                let (tok, done) = self.sample_step(
                    &mut rngs[slot],
                    dist,
                    &mut out[slot],
                    lens[slot],
                    abs_pos[slot],
                    capacity,
                    max_seq,
                );
                tokens[slot] = tok;
                if done {
                    // a terminating EOS is still fed to the decode below
                    // (one final cache write); after that the slot stays
                    // frozen — lens/pos stop advancing and its logits are
                    // ignored until the chunk drains.
                    active[slot] = false;
                }
                any_active = any_active || active[slot];
            }
            if !any_active {
                break; // final tokens recorded; their logits are never needed
            }

            // compression trigger: a slot whose next write would overflow
            if sparse {
                let mut any = false;
                for slot in 0..r {
                    let need = active[slot] && lens[slot] as usize >= capacity;
                    do_mask[slot] = if need { 1.0 } else { 0.0 };
                    if need {
                        any = true;
                    }
                }
                if any {
                    b.compress(&do_mask)?;
                    for slot in 0..r {
                        if do_mask[slot] > 0.0 {
                            out[slot].accounting.compression(capacity - budget);
                            lens[slot] = budget as i32;
                        }
                    }
                }
            }

            // one decode step over the whole batch
            let occupied = active.iter().filter(|&&a| a).count();
            stats.peak_live_slots = stats.peak_live_slots.max(occupied);
            let step_tokens: Vec<i32> = (0..r)
                .map(|s| if s < n { tokens[s] } else { PAD })
                .collect();
            logp = b.decode(&lens, &abs_pos, &step_tokens)?;
            stats.decode_steps += 1;
            stats.occupied_slot_steps += occupied;
            stats.idle_slot_steps += r - occupied;
            for slot in 0..r {
                // frozen for finished/idle slots: they take no cache writes
                // we care about, and freezing avoids spurious compressions.
                // The one EOS feed advances a final time so its write lands.
                if slot < n && (active[slot] || step_tokens[slot] == EOS) {
                    lens[slot] += 1;
                    abs_pos[slot] += 1;
                }
            }
        }
        Ok((out, stats))
    }

    /// Drive the static chunked engine over a whole pending queue: admit
    /// a chunk against the wall, roll it out to completion, release, and
    /// repeat. THE single driver for queue-scale static rollouts — the
    /// trainer, the equivalence harness, and the benches all call this,
    /// so they exercise identical admission/ordering semantics.
    ///
    /// Results come back in task order (position in `tasks`).
    pub fn rollout_static_queue<B: RolloutBackend>(
        &self,
        b: &mut B,
        tasks: &[(usize, &Task)],
        seed: u64,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let n = tasks.len();
        let mut pending: Vec<usize> = (0..n).collect();
        let mut results: Vec<Option<GenSeq>> = (0..n).map(|_| None).collect();
        let mut stats = RolloutStats::default();
        let mut base = seq_id_base;
        // Predicted worst-case residency per task: a chunk member's cache
        // never holds more than its prompt, max_response generated tokens,
        // and one trailing frozen-slot PAD write (nor more than the
        // per-seq capacity bound). Paged admission sizes chunks by this
        // instead of the global worst case; worst-case admission ignores
        // it.
        let residency: Vec<usize> = tasks
            .iter()
            .map(|(_, t)| {
                (t.prompt_ids.len() + self.sampling.max_response + 1)
                    .min(sched.reserve_per_seq)
            })
            .collect();
        while !pending.is_empty() {
            let Some(chunk) = sched.next_chunk(&mut pending, kv, base, &residency) else {
                bail!(
                    "static rollout stalled: {} pending but nothing admissible \
                     (static batching drains synchronously)",
                    pending.len()
                );
            };
            stats.max_reserved_kv = stats.max_reserved_kv.max(kv.reserved());
            stats.max_used_pages = stats.max_used_pages.max(kv.used_pages());
            let chunk_tasks: Vec<(usize, &Task)> =
                chunk.items.iter().map(|&i| tasks[i]).collect();
            let (seqs, cstats) = self.rollout_static(b, &chunk_tasks, seed)?;
            stats.merge(&cstats);
            // rollout_static returns sequences in slot (= chunk) order
            for (&pos, seq) in chunk.items.iter().zip(seqs) {
                results[pos] = Some(seq);
            }
            sched.finish_chunk(&chunk, kv, base);
            base += chunk.items.len() as u64;
        }
        let out = results
            .into_iter()
            .map(|s| s.expect("every queued task completed"))
            .collect();
        Ok((out, stats))
    }

    /// Continuous-batching rollout with slot recycling over an arbitrarily
    /// long task queue. Admission is per sequence: each admitted sequence
    /// reserves its worst-case KV with the scheduler/manager, and the
    /// reservation is released the moment the sequence finishes — not when
    /// the whole batch drains. Freed slots are immediately re-prefilled
    /// (in place) with the next pending prompt, so the decode batch stays
    /// as full as the memory wall allows.
    ///
    /// Sequences are returned in task order. Total decode steps equal the
    /// list-scheduling makespan of per-sequence decode costs, which
    /// `Scheduler::predicted_decode_steps` computes in closed form.
    pub fn rollout_continuous<B: RolloutBackend>(
        &self,
        b: &mut B,
        tasks: &[(usize, &Task)],
        seed: u64,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let r = b.slots();
        let p_len = b.prompt_len();
        let max_seq = b.max_seq();
        let vocab = b.vocab();
        let capacity = b.capacity();
        let budget = b.budget();
        let sparse = self.mode.is_sparse();
        let n = tasks.len();
        let mut stats = RolloutStats { chunks: 1, ..RolloutStats::default() };
        if n == 0 {
            return Ok((vec![], stats));
        }

        // Paged admission must be able to grow a lone sequence to its
        // worst-case residency, or the preempt/requeue path could thrash
        // forever on a wall that cannot hold even one sequence.
        if kv.pages_for(sched.reserve_per_seq) > kv.total_pages() {
            bail!(
                "continuous rollout deadlock: one sequence may need {} KV tokens \
                 but the wall holds only {}",
                sched.reserve_per_seq,
                kv.capacity()
            );
        }

        let mut results: Vec<Option<GenSeq>> = (0..n).map(|_| None).collect();
        let mut queue: VecDeque<usize> = (0..n).collect();
        let mut slots: Vec<Option<LiveSeq>> = (0..r).map(|_| None).collect();
        let mut lens = vec![1i32; r];
        let mut abs_pos = vec![1i32; r];

        // ---- initial wave: one batched prefill over the admissible head
        let mut ids = vec![PAD; r * p_len];
        let mut plens = vec![1i32; r];
        let mut w = 0usize;
        while w < r && !queue.is_empty() {
            let pos = queue[0];
            if !sched.try_admit(kv, seq_id_base + pos as u64, tasks[pos].1.prompt_ids.len()) {
                break;
            }
            queue.pop_front();
            let (idx, task) = tasks[pos];
            let pi = &task.prompt_ids;
            assert!(pi.len() <= p_len, "prompt {} > {}", pi.len(), p_len);
            ids[w * p_len..w * p_len + pi.len()].copy_from_slice(pi);
            plens[w] = pi.len() as i32;
            lens[w] = pi.len() as i32;
            abs_pos[w] = pi.len() as i32;
            slots[w] = Some(LiveSeq {
                pos,
                rng: task_rng(seed, idx),
                gen: GenSeq::new(idx, pi.clone()),
            });
            w += 1;
        }
        if w == 0 {
            bail!(
                "continuous rollout deadlock: cannot admit any sequence \
                 (reserve {} > free KV {} of {})",
                sched.reserve_per_seq,
                kv.available(),
                kv.capacity()
            );
        }
        for slot in w..r {
            ids[slot * p_len] = BOS;
        }
        let mut logp = b.prefill(&ids, &plens)?;
        stats.prefills += 1;
        stats.max_reserved_kv = stats.max_reserved_kv.max(kv.reserved());

        let mut tokens = vec![PAD; r];
        let mut do_mask = vec![0.0f32; r];
        loop {
            // ---- sample one token per occupied slot; retire finishers ---
            for slot in 0..r {
                let Some(live) = slots[slot].as_mut() else {
                    tokens[slot] = PAD;
                    continue;
                };
                let dist = &logp[slot * vocab..(slot + 1) * vocab];
                let (tok, done) = self.sample_step(
                    &mut live.rng,
                    dist,
                    &mut live.gen,
                    lens[slot],
                    abs_pos[slot],
                    capacity,
                    max_seq,
                );
                tokens[slot] = tok;
                if done {
                    // per-sequence release: THE difference from the static
                    // engine — the KV reservation frees now, not when the
                    // whole batch drains
                    let live = slots[slot].take().expect("occupied");
                    sched.release_seq(kv, seq_id_base + live.pos as u64)?;
                    results[live.pos] = Some(live.gen);
                    tokens[slot] = PAD;
                }
            }

            // ---- slot recycling: refill freed slots from the queue ------
            for slot in 0..r {
                if slots[slot].is_some() {
                    continue;
                }
                while let Some(&pos) = queue.front() {
                    if !sched.try_admit(kv, seq_id_base + pos as u64, tasks[pos].1.prompt_ids.len())
                    {
                        break; // memory wall: retry after future releases
                    }
                    queue.pop_front();
                    let (idx, task) = tasks[pos];
                    let pi = &task.prompt_ids;
                    assert!(pi.len() <= p_len, "prompt {} > {}", pi.len(), p_len);
                    let row = b.prefill_slot(slot, pi)?;
                    stats.slot_prefills += 1;
                    stats.refills += 1;
                    stats.max_reserved_kv = stats.max_reserved_kv.max(kv.reserved());
                    let mut live = LiveSeq {
                        pos,
                        rng: task_rng(seed, idx),
                        gen: GenSeq::new(idx, pi.clone()),
                    };
                    // first token comes from the slot-prefill logits — the
                    // same logits (and the same per-token semantics, via
                    // sample_step) the batched-prefill path would have used
                    let plen = pi.len() as i32;
                    let (tok, done) = self.sample_step(
                        &mut live.rng,
                        &row,
                        &mut live.gen,
                        plen,
                        plen,
                        capacity,
                        max_seq,
                    );
                    // prefill_slot replaced this slot's cache, so the
                    // control vectors must track it even when the sequence
                    // dies immediately — a stale lens would make the next
                    // decode write at an out-of-sync position
                    tokens[slot] = tok;
                    lens[slot] = plen;
                    abs_pos[slot] = plen;
                    if done {
                        // degenerate single-token sequence: release and try
                        // the next pending prompt for this same slot
                        sched.release_seq(kv, seq_id_base + live.pos as u64)?;
                        results[live.pos] = Some(live.gen);
                        tokens[slot] = PAD;
                        continue;
                    }
                    slots[slot] = Some(live);
                    break;
                }
            }

            // ---- drained? -----------------------------------------------
            let occupied = slots.iter().filter(|s| s.is_some()).count();
            if occupied == 0 {
                if queue.is_empty() {
                    break;
                }
                bail!(
                    "continuous rollout stalled: {} pending but nothing \
                     admissible (reserve {} > free KV {})",
                    queue.len(),
                    sched.reserve_per_seq,
                    kv.available()
                );
            }

            // ---- compression trigger (same per-sequence rule as static) -
            if sparse {
                let mut any = false;
                for slot in 0..r {
                    let need = slots[slot].is_some() && lens[slot] as usize >= capacity;
                    do_mask[slot] = if need { 1.0 } else { 0.0 };
                    if need {
                        any = true;
                    }
                }
                if any {
                    b.compress(&do_mask)?;
                    for slot in 0..r {
                        if do_mask[slot] > 0.0 {
                            let live = slots[slot].as_mut().expect("masked slot occupied");
                            live.gen.accounting.compression(capacity - budget);
                            lens[slot] = budget as i32;
                            // paged admission: the freed residency returns
                            // to the pool immediately (no-op worst-case)
                            sched.compressed(kv, seq_id_base + live.pos as u64, budget)?;
                        }
                    }
                }
            }

            // ---- paged growth: every occupied slot must hold pages for
            // its next cache write. A grow refused by the wall preempts
            // the lowest-progress live sequence (possibly the grower
            // itself) and requeues it — per-task RNG makes the rerun
            // token-identical, so preemption costs decode steps but never
            // changes outputs. (Worst-case admission: grow is a no-op.)
            for slot in 0..r {
                loop {
                    let Some(live) = slots[slot].as_ref() else { break };
                    let pos = live.pos;
                    let need = lens[slot] as usize + 1;
                    if sched.grow(kv, seq_id_base + pos as u64, need)? {
                        break;
                    }
                    let victim = (0..r)
                        .filter_map(|s| {
                            slots[s]
                                .as_ref()
                                .map(|l| (l.gen.response_ids.len(), l.pos, s))
                        })
                        .min()
                        .expect("the grower itself is live")
                        .2;
                    let v = slots[victim].take().expect("victim occupied");
                    sched.preempt(kv, seq_id_base + v.pos as u64)?;
                    queue.push_front(v.pos);
                    tokens[victim] = PAD;
                    stats.preemptions += 1;
                    if victim == slot {
                        break; // grower evicted: its slot is free now
                    }
                }
            }
            debug_assert!(kv.check_invariants().is_ok(), "wall invariants broken mid-rollout");
            stats.max_reserved_kv = stats.max_reserved_kv.max(kv.reserved());
            stats.max_used_pages = stats.max_used_pages.max(kv.used_pages());

            // ---- one decode step over the mixed batch -------------------
            // (recount: paged growth may have preempted slots; the guard
            // above guarantees at least one survivor)
            let occupied = slots.iter().filter(|s| s.is_some()).count();
            stats.peak_live_slots = stats.peak_live_slots.max(occupied);
            logp = b.decode(&lens, &abs_pos, &tokens)?;
            stats.decode_steps += 1;
            stats.occupied_slot_steps += occupied;
            stats.idle_slot_steps += r - occupied;
            for slot in 0..r {
                if slots[slot].is_some() {
                    lens[slot] += 1;
                    abs_pos[slot] += 1;
                }
            }
        }

        let out = results
            .into_iter()
            .map(|s| s.expect("every queued task completed"))
            .collect();
        Ok((out, stats))
    }
}

/// The artifact-bound rollout engine for one model + mode.
pub struct RolloutEngine<'a> {
    pub engine: &'a ModelEngine,
    pub mode: RolloutMode,
    pub sampling: SamplingConfig,
}

impl<'a> RolloutEngine<'a> {
    pub fn new(engine: &'a ModelEngine, mode: RolloutMode, sampling: SamplingConfig) -> Self {
        RolloutEngine { engine, mode, sampling }
    }

    pub fn policy(&self) -> RolloutPolicy {
        RolloutPolicy::new(self.mode, self.sampling)
    }

    pub fn variant(&self) -> Variant {
        if self.mode.is_sparse() {
            Variant::Sparse
        } else {
            Variant::Dense
        }
    }

    /// Roll out one static chunk of tasks (≤ decode_batch sequences; the
    /// scheduler guarantees admission). `seed` is the rollout seed feeding
    /// the per-task RNG streams.
    pub fn rollout_chunk(
        &self,
        params: &[f32],
        tasks: &[(usize, &Task)],
        seed: u64,
    ) -> Result<Vec<GenSeq>> {
        // weights are uploaded once per chunk, not once per decode step
        let params = ParamsLit::new(params);
        self.rollout_chunk_lit(&params, tasks, seed)
    }

    /// Same as `rollout_chunk` but with pre-uploaded weights (callers that
    /// run many chunks per step share one upload).
    pub fn rollout_chunk_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
    ) -> Result<Vec<GenSeq>> {
        Ok(self.rollout_chunk_stats_lit(params, tasks, seed)?.0)
    }

    /// Static chunk rollout returning occupancy statistics as well.
    pub fn rollout_chunk_stats_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let mut backend = EngineBackend::new(self.engine, params, self.mode);
        self.policy().rollout_static(&mut backend, tasks, seed)
    }

    /// Static chunked rollout over the whole pending queue (any length).
    /// See `RolloutPolicy::rollout_static_queue`.
    pub fn rollout_static_queue_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let mut backend = EngineBackend::new(self.engine, params, self.mode);
        self.policy()
            .rollout_static_queue(&mut backend, tasks, seed, sched, kv, seq_id_base)
    }

    /// Continuous-batching rollout over the whole pending queue (any
    /// length), recycling slots as sequences finish. See
    /// `RolloutPolicy::rollout_continuous`.
    pub fn rollout_continuous_lit(
        &self,
        params: &ParamsLit,
        tasks: &[(usize, &Task)],
        seed: u64,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let mut backend = EngineBackend::new(self.engine, params, self.mode);
        self.policy()
            .rollout_continuous(&mut backend, tasks, seed, sched, kv, seq_id_base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(t: f32, p: f32) -> SamplingConfig {
        SamplingConfig { temperature: t, top_p: p, max_response: 16 }
    }

    #[test]
    fn sample_token_records_exact_logp_at_unit_temp() {
        let mut rng = Rng::new(1);
        let logp = [-0.5f32, -1.5, -3.0];
        for _ in 0..50 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(1.0, 1.0));
            assert_eq!(lp, logp[tok]);
        }
    }

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(2);
        let logp = [-2.0f32, -0.1, -5.0];
        for _ in 0..20 {
            let (tok, _) = sample_token(&mut rng, &logp, &cfg(0.0, 1.0));
            assert_eq!(tok, 1);
        }
    }

    #[test]
    fn tempered_logp_is_normalized() {
        let mut rng = Rng::new(3);
        let logp = [-0.7f32, -1.1, -2.0, -2.5];
        // collect the modified distribution empirically
        let mut mass = [0.0f64; 4];
        let n = 30_000;
        for _ in 0..n {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(0.7, 0.95));
            mass[tok] += 1.0;
            // recorded logp must be a valid log-probability
            assert!(lp <= 0.0 && lp.is_finite());
        }
        let total: f64 = mass.iter().sum();
        assert_eq!(total as usize, n);
        // last token should be rarer than first under sharpening
        assert!(mass[0] > mass[3]);
    }

    #[test]
    fn nan_logits_do_not_panic_and_carry_no_mass() {
        let mut rng = Rng::new(4);
        let logp = [f32::NAN, -1.0, f32::NAN, -2.0];
        for _ in 0..200 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(0.8, 0.9));
            assert!(tok == 1 || tok == 3, "sampled NaN token {tok}");
            assert!(lp.is_finite() && lp <= 0.0);
        }
        // the T=1/top-p=1 default config must be just as hardened (it
        // normally takes the exact-logp fast path)
        for _ in 0..200 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(1.0, 1.0));
            assert!(tok == 1 || tok == 3, "fast path sampled NaN token {tok}");
            assert!(lp.is_finite() && lp <= 0.0);
        }
        // fully degenerate input: uniform fallback, still no panic
        let bad = [f32::NAN; 5];
        for _ in 0..50 {
            let (tok, lp) = sample_token(&mut rng, &bad, &cfg(0.8, 0.9));
            assert!(tok < 5);
            assert!((lp - (-(5f32).ln())).abs() < 1e-6);
        }
    }

    #[test]
    fn top1_exceeding_top_p_keeps_exactly_argmax() {
        let mut rng = Rng::new(5);
        // token 1 holds ~99% of the tempered mass, far beyond top_p = 0.5:
        // the nucleus must be {1} with renormalized mass 1 (log-prob 0)
        let logp = [-8.0f32, -0.01, -9.0, -10.0];
        for _ in 0..100 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(0.9, 0.5));
            assert_eq!(tok, 1);
            assert_eq!(lp, 0.0, "renormalized point mass must be exactly 1");
        }
    }

    #[test]
    fn task_rng_is_slot_and_order_independent() {
        // same (seed, task) => same stream; different task => different
        let mut a = task_rng(42, 7);
        let mut b = task_rng(42, 7);
        let mut c = task_rng(42, 8);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }
}
