//! Importance-based Reweighting: assembling the Eq. 7 training inputs.
//!
//! The train artifact consumes, per sequence: token ids, a response loss
//! mask, the advantage Â_i, the per-token ξ_{i,t} (applied OUTSIDE the
//! clip), the rejection weight M^RS, and the dense-old-policy log-probs
//! (the denominator of the clipped staleness ratio w_{i,t}). This module
//! packs ragged rollout results into the fixed [Btr, T] tensors and
//! computes the mismatch-KL diagnostic (Fig. 3).

use anyhow::{bail, Result};

use crate::runtime::manifest::Manifest;

/// One finished rollout sequence, ready for training.
#[derive(Debug, Clone)]
pub struct TrainSeq {
    /// Prompt + response token ids (unpadded).
    pub ids: Vec<i32>,
    /// Prompt length (response starts here).
    pub prompt_len: usize,
    /// Â_i.
    pub advantage: f64,
    /// ξ_{i,t} for response tokens (len = response length); 1.0 for the
    /// uncorrected baselines.
    pub xi: Vec<f64>,
    /// M^RS ∈ {0,1}.
    pub accept: bool,
    /// Dense old-policy log-prob of each response token.
    pub logp_old: Vec<f32>,
}

/// Fixed-shape tensors for one train_step call.
#[derive(Debug, Clone)]
pub struct TrainBatch {
    pub ids: Vec<i32>,       // [B, T]
    pub loss_mask: Vec<f32>, // [B, T]
    pub lens: Vec<i32>,      // [B]
    pub adv: Vec<f32>,       // [B]
    pub xi: Vec<f32>,        // [B, T]
    pub mrs: Vec<f32>,       // [B]
    pub logp_old: Vec<f32>,  // [B, T]
    /// Number of real (non-padding) rows.
    pub rows: usize,
}

/// ξ values are clamped to this ceiling before entering the objective.
/// The paper applies ξ unclipped; a finite ceiling only guards against
/// degenerate exp() overflow on f32 (ξ > 1e4 implies the dense policy
/// *strongly prefers* the sampled token — keeping the weight huge adds
/// variance without information). Documented deviation, measured in the
/// ablation bench.
pub const XI_CAP: f64 = 1e4;

/// Pack up to `train_batch` sequences into one fixed-shape batch.
///
/// Rows beyond `seqs.len()` are padding: mrs = 0 so they contribute
/// nothing to the objective (the artifact multiplies per-sequence terms by
/// M^RS).
///
/// A sequence whose `xi` or `logp_old` is shorter than its response is a
/// producer bug and is reported as `Err` — these used to be
/// `debug_assert!`s only, so a release build would panic on the raw
/// `seq.xi[r]` index below instead of failing cleanly.
pub fn pack(manifest: &Manifest, seqs: &[&TrainSeq]) -> Result<TrainBatch> {
    let b = manifest.shapes.train_batch;
    let t = manifest.config.max_seq;
    if seqs.len() > b {
        bail!("{} seqs > train_batch {}", seqs.len(), b);
    }

    let mut batch = TrainBatch {
        ids: vec![0; b * t],
        loss_mask: vec![0.0; b * t],
        lens: vec![1; b],
        adv: vec![0.0; b],
        xi: vec![1.0; b * t],
        mrs: vec![0.0; b],
        logp_old: vec![0.0; b * t],
        rows: seqs.len(),
    };

    for (row, seq) in seqs.iter().enumerate() {
        let n = seq.ids.len().min(t);
        let resp_len = n.saturating_sub(seq.prompt_len);
        if seq.xi.len() < resp_len {
            bail!("seq {row}: xi has {} entries for a {resp_len}-token response", seq.xi.len());
        }
        if seq.logp_old.len() < resp_len {
            bail!(
                "seq {row}: logp_old has {} entries for a {resp_len}-token response",
                seq.logp_old.len()
            );
        }
        batch.lens[row] = n as i32;
        batch.adv[row] = seq.advantage as f32;
        batch.mrs[row] = if seq.accept { 1.0 } else { 0.0 };
        for i in 0..n {
            batch.ids[row * t + i] = seq.ids[i];
        }
        for r in 0..resp_len {
            let col = seq.prompt_len + r;
            batch.loss_mask[row * t + col] = 1.0;
            // Non-finite ξ must not reach the objective: f64::min passes
            // NaN through to the *other* operand, so an unguarded
            // `.min(XI_CAP)` used to turn NaN into the full 1e4 weight.
            // NaN / -inf carry no information -> 0; +inf means the dense
            // policy overwhelmingly prefers the token -> the cap.
            let xi = seq.xi[r];
            batch.xi[row * t + col] = if xi.is_finite() {
                xi.clamp(0.0, XI_CAP) as f32
            } else if xi == f64::INFINITY {
                XI_CAP as f32
            } else {
                0.0
            };
            batch.logp_old[row * t + col] = seq.logp_old[r];
        }
    }
    Ok(batch)
}

/// Mismatch KL estimate KL(π_sparse ‖ π_old) over a set of sequences
/// (Fig. 3): mean over response tokens of (log π_sparse - log π_old)
/// under samples from π_sparse.
///
/// The two log-prob vectors of a pair must cover the same response
/// tokens; a length mismatch is reported as `Err` (the old
/// `debug_assert_eq!` let a release build silently `zip`-truncate to the
/// shorter vector, skewing the diagnostic the trainer logs).
pub fn mismatch_kl(seqs: &[(&[f32], &[f32])]) -> Result<f64> {
    // seqs: (logp_sparse, logp_old) pairs per sequence
    let mut sum = 0.0f64;
    let mut n = 0usize;
    for (i, (sp, old)) in seqs.iter().enumerate() {
        if sp.len() != old.len() {
            bail!(
                "seq {i}: {} sparse log-probs vs {} old-policy log-probs",
                sp.len(),
                old.len()
            );
        }
        for (s, o) in sp.iter().zip(old.iter()) {
            sum += (*s as f64) - (*o as f64);
            n += 1;
        }
    }
    Ok(if n == 0 { 0.0 } else { sum / n as f64 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use std::path::Path;

    fn tiny_manifest() -> Option<Manifest> {
        // Prefer real artifacts when present (CI builds them first).
        for cand in ["artifacts/nano", "../artifacts/nano", "../../artifacts/nano"] {
            if let Ok(m) = Manifest::load(Path::new(cand)) {
                return Some(m);
            }
        }
        // Hermetic fallback: pack() only reads shapes.train_batch and
        // config.max_seq, so an in-memory manifest keeps these regression
        // tests running with no artifacts built.
        Some(Manifest {
            dir: std::path::PathBuf::from("."),
            config: crate::runtime::manifest::ModelDims {
                name: "mem".into(),
                vocab: 32,
                d_model: 8,
                n_layers: 1,
                n_heads: 1,
                d_ff: 16,
                d_head: 8,
                max_seq: 32,
                prompt_len: 8,
                n_params: 0,
            },
            shapes: crate::runtime::manifest::RolloutDims {
                decode_batch: 4,
                train_batch: 4,
                budget: 12,
                buffer: 4,
                alpha: 4,
                lam: 0.5,
                sinks: 2,
                sparse_capacity: 16,
                dense_capacity: 32,
            },
            params: vec![],
            entries: std::collections::BTreeMap::new(),
        })
    }

    fn mk_seq(prompt: usize, resp: usize, accept: bool) -> TrainSeq {
        TrainSeq {
            ids: (0..(prompt + resp) as i32).map(|i| i % 30).collect(),
            prompt_len: prompt,
            advantage: 0.5,
            xi: vec![1.1; resp],
            accept,
            logp_old: vec![-0.7; resp],
        }
    }

    #[test]
    fn pack_masks_and_pads() {
        let Some(m) = tiny_manifest() else {
            eprintln!("skipping: no artifacts built");
            return;
        };
        let t = m.config.max_seq;
        let s1 = mk_seq(5, 7, true);
        let s2 = mk_seq(3, 2, false);
        let b = pack(&m, &[&s1, &s2]).unwrap();
        assert_eq!(b.rows, 2);
        assert_eq!(b.lens[0], 12);
        assert_eq!(b.mrs[0], 1.0);
        assert_eq!(b.mrs[1], 0.0);
        // padding rows are inert
        for row in 2..m.shapes.train_batch {
            assert_eq!(b.mrs[row], 0.0);
            assert_eq!(b.adv[row], 0.0);
            assert!(b.loss_mask[row * t..(row + 1) * t].iter().all(|&x| x == 0.0));
        }
        // mask exactly covers the response
        let mask_sum: f32 = b.loss_mask[..t].iter().sum();
        assert_eq!(mask_sum, 7.0);
        assert_eq!(b.loss_mask[5], 1.0);
        assert_eq!(b.loss_mask[4], 0.0);
        // xi written at masked positions only
        assert!((b.xi[5] - 1.1).abs() < 1e-6);
        assert_eq!(b.xi[4], 1.0);
    }

    #[test]
    fn xi_capped() {
        let Some(m) = tiny_manifest() else {
            eprintln!("skipping: no artifacts built");
            return;
        };
        let mut s = mk_seq(2, 3, true);
        s.xi = vec![1e9, 0.5, -1.0]; // -1 can't happen but must clamp safely
        let b = pack(&m, &[&s]).unwrap();
        let t = m.config.max_seq;
        assert_eq!(b.xi[2], XI_CAP as f32);
        assert_eq!(b.xi[3], 0.5);
        assert_eq!(b.xi[4], 0.0);
        let _ = t;
    }

    #[test]
    fn non_finite_xi_clamped_in_pack() {
        // regression: NaN.min(XI_CAP) == XI_CAP, so a NaN ξ used to enter
        // the objective with the full 1e4 weight
        let m = tiny_manifest().unwrap();
        let mut s = mk_seq(2, 4, true);
        s.xi = vec![f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 2.0];
        let b = pack(&m, &[&s]).unwrap();
        assert_eq!(b.xi[2], 0.0, "NaN must carry zero weight");
        assert_eq!(b.xi[3], XI_CAP as f32, "+inf clamps to the cap");
        assert_eq!(b.xi[4], 0.0, "-inf must carry zero weight");
        assert_eq!(b.xi[5], 2.0);
        assert!(b.xi.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn mismatch_kl_signs() {
        // sparse assigns higher prob to its own samples -> positive KL
        let sp = [-0.5f32, -0.6];
        let old = [-1.0f32, -1.2];
        let kl = mismatch_kl(&[(&sp, &old)]).unwrap();
        assert!(kl > 0.0);
        // identical policies -> zero
        assert_eq!(mismatch_kl(&[(&sp, &sp)]).unwrap(), 0.0);
        assert_eq!(mismatch_kl(&[]).unwrap(), 0.0);
    }

    #[test]
    fn length_mismatches_are_errors_without_debug_assertions() {
        // regression for the debug_assert-only guards: these inputs used
        // to panic (pack: raw index past xi/logp_old) or silently
        // zip-truncate (mismatch_kl) in a release build, where the old
        // debug_assert!s compile away. The checks must hold as real
        // errors regardless of cfg(debug_assertions).
        let m = tiny_manifest().unwrap();

        let mut s = mk_seq(2, 4, true);
        s.xi = vec![1.0; 3]; // one short for a 4-token response
        assert!(pack(&m, &[&s]).is_err());

        let mut s = mk_seq(2, 4, true);
        s.logp_old = vec![-0.7; 2]; // two short
        assert!(pack(&m, &[&s]).is_err());

        let sp = [-0.5f32, -0.6, -0.7];
        let old = [-1.0f32, -1.2];
        assert!(mismatch_kl(&[(&sp, &old)]).is_err());
    }
}
