//! Rollout throughput/occupancy statistics + virtual-clock tick
//! accounting, shared by every engine shell over the decode core.

/// Per-request latency distribution over virtual-clock ticks — the
/// serving front-end keeps one each for TTFT (arrival → first streamed
/// token), inter-token gaps, and end-to-end completion. Samples are
/// modeled ticks (the mock backend's `CostModel`), so the histograms are
/// bit-deterministic and the hermetic serve tests assert exact p50/p99
/// values. Quantiles are nearest-rank over the sorted sample set: exact,
/// scale-free, and stable under insertion order.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LatencyHistogram {
    samples: Vec<u64>,
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    /// Record one latency sample (virtual-clock ticks).
    pub fn record(&mut self, ticks: u64) {
        self.samples.push(ticks);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Fold another histogram's samples into this one (fleet / per-lane
    /// composition; quantiles over the union, not a mean of quantiles).
    pub fn merge(&mut self, o: &LatencyHistogram) {
        self.samples.extend_from_slice(&o.samples);
    }

    pub fn max(&self) -> u64 {
        self.samples.iter().copied().max().unwrap_or(0)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<u64>() as f64 / self.samples.len() as f64
    }

    /// Nearest-rank quantile: the smallest sample with at least
    /// `q * len` samples at or below it (`q` clamped to [0, 1]; 0 on an
    /// empty histogram). `quantile(1.0)` is the max, `quantile(0.5)` the
    /// upper median — exact order statistics, no interpolation, so
    /// hermetic tests can pin values to the tick.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let n = sorted.len();
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as usize).clamp(1, n);
        sorted[rank - 1]
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
}

/// Throughput/occupancy statistics for one rollout (any engine).
///
/// `occupied_slot_steps` counts, per decode step, the slots doing live
/// generation; `idle_slot_steps` counts the complement — PAD work on
/// finished or never-admitted slots (the long-tail bubble the continuous
/// engine removes).
///
/// **Denominator contract (cross-engine audit):** every counter here is
/// denominated in *modeled device work*, never in engine loop iterations.
/// One `decode` artifact invocation contributes exactly `slots` slot-steps
/// (`occupied + idle == decode_steps * slots` — the equivalence tests
/// assert this identity for all three engines), so `occupancy()` and
/// `idle_frac()` are apples-to-apples across static, continuous, and
/// pipelined runs, and across worker counts. The `*_ticks` fields are the
/// virtual-clock breakdown on the backend's `CostModel` (all zero for
/// real backends, which are wall-timed by the trainer instead).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RolloutStats {
    /// Scheduled chunks (continuous: one pass over the whole queue).
    pub chunks: usize,
    /// Decode artifact invocations.
    pub decode_steps: usize,
    pub occupied_slot_steps: usize,
    pub idle_slot_steps: usize,
    /// Mid-flight slot refills (continuous only).
    pub refills: usize,
    /// Batched prefill calls.
    pub prefills: usize,
    /// Per-slot (recycling) prefill calls.
    pub slot_prefills: usize,
    /// Slot refills served by attaching a cached prepared prompt instead
    /// of running a fresh prefill (prefix sharing's prefill-once-attach-G
    /// path; 0 with `prefix-sharing = off`). Disjoint from
    /// `slot_prefills`: a refill is counted in exactly one of the two.
    pub shared_prefill_attaches: usize,
    /// Chunked-prefill backend calls (`prefill-chunk-tokens > 0` only):
    /// each partial prompt range written into a slot counts once, so a
    /// prompt trickled in over k steps contributes k. Disjoint from
    /// `slot_prefills`/`shared_prefill_attaches` — a chunked refill makes
    /// no monolithic prefill call at all.
    pub prefill_chunks: usize,
    /// Max KV tokens reserved simultaneously (continuous only; the
    /// invariant tests check this never exceeds the wall).
    pub max_reserved_kv: usize,
    /// Max pool pages in use simultaneously (continuous only; page
    /// occupancy = this over the manager's `total_pages`).
    pub max_used_pages: usize,
    /// Max concurrently occupied decode slots at any step (the admitted
    /// width the paged-vs-worst-case benches compare).
    pub peak_live_slots: usize,
    /// Sequences preempted and requeued by a paged-admission grow stall
    /// (0 under worst-case admission).
    pub preemptions: usize,
    /// Pending refills adopted from a peer lane by a drained worker
    /// (pipelined with `steal = on` only; scheduling-only — never changes
    /// tokens).
    pub steals: usize,
    /// Slot prefills handed to the dedicated prefill-executor thread
    /// (pipelined with `prefill = async` only; 0 in sync mode, where the
    /// decode workers make the calls themselves).
    pub async_prefills_submitted: usize,
    /// Async prefills the executor finished preparing. Every submission
    /// is prepared exactly once, so this equals `submitted` at drain —
    /// the propcheck and the stress test assert it.
    pub async_prefills_completed: usize,
    /// Peak count of submitted-but-not-yet-joined async prefills — the
    /// prefill pipeline's occupancy high-water. Deterministic at one
    /// worker: it advances on virtual-clock events (submits/joins), not
    /// on physical executor timing. A peak: `merge` takes the max, and
    /// the pipelined joiner overwrites it with the globally observed
    /// value.
    pub async_prefill_inflight_peak: usize,
    /// Backend calls that failed and were retried under the bounded-retry
    /// policy (`fault-retries`). Each retried attempt counts once; a call
    /// that succeeds first try contributes 0.
    pub retries: usize,
    /// Tasks requeued from a dead replica to a survivor by fleet failover
    /// (0 outside the fleet tier).
    pub requeues: usize,
    /// Tasks quarantined after exhausting their retry budget
    /// (`fault-policy = quarantine` only; their `GenSeq.failed` is set and
    /// the trainer drops their whole GRPO group).
    pub failed_tasks: usize,
    /// Replica threads declared dead (error or panic) and failed over
    /// (0 outside the fleet tier).
    pub replica_deaths: usize,
    /// Worker lanes that produced these stats (1 for static/continuous;
    /// the pool size for pipelined).
    pub workers: usize,
    /// Modeled ticks spent busy on decode + compression calls, summed
    /// over lanes.
    pub decode_busy_ticks: u64,
    /// Modeled ticks a decode lane sat blocked on prefill work: batched
    /// prefills, plus slot prefills that could not be hidden behind
    /// decode. The continuous engine — and the pipelined engine under
    /// `prefill = sync`, where the joining worker makes the call itself —
    /// charges *every* slot prefill here; that serial stall is exactly
    /// what `prefill = async`'s dedicated executor lane removes.
    pub prefill_blocked_ticks: u64,
    /// Modeled ticks a decode lane idled empty at the memory wall,
    /// waiting for another lane to release KV (pipelined only; the
    /// single-lane engines keep decoding or bail instead of waiting).
    pub sched_stall_ticks: u64,
    /// Modeled end-to-end makespan. Serial engines: busy + blocked +
    /// stall. Pipelined: max over worker lanes' finish clocks — which is
    /// why `merge` (serial composition, e.g. static chunks) SUMS this
    /// field and the pipelined joiner overwrites it with the lane max.
    pub modeled_makespan_ticks: u64,
    /// Peak modeled ticks charged by any single steady-state engine step
    /// (one main-loop iteration; the initial batched prefill wave is
    /// excluded). This is the per-step latency bound chunked prefill
    /// lowers: a monolithic refill step costs `slot_prefill_ticks` on top
    /// of the decode, a chunked step at most the token budget's worth of
    /// `chunk_token_ticks`. A high-water mark: both merges take the MAX.
    /// Populated by the continuous and pipelined shells; 0 for static.
    pub max_step_ticks: u64,
}

impl RolloutStats {
    /// Total device slot-steps: the shared denominator of `occupancy` and
    /// `idle_frac`. Always equals `decode_steps * slots` when the engines
    /// uphold the denominator contract (asserted by the equivalence
    /// tests).
    pub fn device_slot_steps(&self) -> usize {
        self.occupied_slot_steps + self.idle_slot_steps
    }

    /// Mean decode-step slot occupancy in [0, 1].
    pub fn occupancy(&self) -> f64 {
        let total = self.device_slot_steps();
        if total == 0 {
            0.0
        } else {
            self.occupied_slot_steps as f64 / total as f64
        }
    }

    /// Fraction of decode-slot work wasted on idle (PAD) slots.
    pub fn idle_frac(&self) -> f64 {
        let total = self.device_slot_steps();
        if total == 0 {
            0.0
        } else {
            self.idle_slot_steps as f64 / total as f64
        }
    }

    /// Combine stats from two runs. Work counters (steps, slot-steps,
    /// refills, preemptions, steals, ticks, makespan) ADD — serial
    /// composition, as when the static queue driver folds chunk after
    /// chunk. Residency peaks take the MAX (they are high-water marks,
    /// not work). The pipelined joiner uses `merge` for the per-lane work
    /// sums, then overwrites `modeled_makespan_ticks` with the lane max
    /// and `peak_live_slots` with the globally observed admitted width.
    pub fn merge(&mut self, o: &RolloutStats) {
        self.chunks += o.chunks;
        self.decode_steps += o.decode_steps;
        self.occupied_slot_steps += o.occupied_slot_steps;
        self.idle_slot_steps += o.idle_slot_steps;
        self.refills += o.refills;
        self.prefills += o.prefills;
        self.slot_prefills += o.slot_prefills;
        self.shared_prefill_attaches += o.shared_prefill_attaches;
        self.prefill_chunks += o.prefill_chunks;
        self.max_reserved_kv = self.max_reserved_kv.max(o.max_reserved_kv);
        self.max_used_pages = self.max_used_pages.max(o.max_used_pages);
        self.peak_live_slots = self.peak_live_slots.max(o.peak_live_slots);
        self.preemptions += o.preemptions;
        self.steals += o.steals;
        self.async_prefills_submitted += o.async_prefills_submitted;
        self.async_prefills_completed += o.async_prefills_completed;
        self.async_prefill_inflight_peak =
            self.async_prefill_inflight_peak.max(o.async_prefill_inflight_peak);
        self.retries += o.retries;
        self.requeues += o.requeues;
        self.failed_tasks += o.failed_tasks;
        self.replica_deaths += o.replica_deaths;
        self.workers = self.workers.max(o.workers);
        self.decode_busy_ticks += o.decode_busy_ticks;
        self.prefill_blocked_ticks += o.prefill_blocked_ticks;
        self.sched_stall_ticks += o.sched_stall_ticks;
        self.modeled_makespan_ticks += o.modeled_makespan_ticks;
        self.max_step_ticks = self.max_step_ticks.max(o.max_step_ticks);
    }

    /// Combine stats from runs that executed CONCURRENTLY on separate
    /// devices — the fleet's per-replica composition, distinct from the
    /// serial `merge` above. Work counters and tick totals still ADD
    /// (they are device work, wherever it ran), and the denominator
    /// contract survives: with equal slot widths, summed
    /// `occupied + idle` still equals summed `decode_steps * slots`. The
    /// differences are the parallel-time fields:
    ///
    /// * `modeled_makespan_ticks` takes the MAX — the fleet finishes when
    ///   its slowest replica does (serial `merge` sums, because one lane
    ///   ran the pieces back-to-back);
    /// * `workers` SUMS — the fleet's total lane count (serial `merge`
    ///   maxes, because the same lanes ran every piece);
    /// * residency peaks (`max_reserved_kv`, `max_used_pages`,
    ///   `peak_live_slots`, `async_prefill_inflight_peak`) stay MAX: each
    ///   replica owns a private wall, so the meaningful fleet number is
    ///   the worst single-device high-water, never a cross-device sum.
    ///
    /// Every field combine is commutative and associative with
    /// `RolloutStats::default()` as identity, so fleet folds are
    /// order-independent — the parallel-merge propcheck pins this.
    pub fn merge_parallel(&mut self, o: &RolloutStats) {
        let (workers, makespan) = (self.workers + o.workers, self.modeled_makespan_ticks);
        self.merge(o);
        self.workers = workers;
        self.modeled_makespan_ticks = makespan.max(o.modeled_makespan_ticks);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck;

    #[test]
    fn latency_histogram_nearest_rank_quantiles_are_exact() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.99), 0, "empty histogram quantiles are 0");
        assert!(h.is_empty());
        // insertion order must not matter (nearest-rank over the sorted set)
        for t in [40u64, 10, 30, 20, 50] {
            h.record(t);
        }
        assert_eq!(h.len(), 5);
        assert_eq!(h.p50(), 30, "upper median of 5 samples");
        assert_eq!(h.quantile(0.0), 10, "q=0 clamps to the min rank");
        assert_eq!(h.quantile(1.0), 50);
        assert_eq!(h.p99(), 50, "ceil(0.99 * 5) = 5 -> the max");
        assert_eq!(h.max(), 50);
        assert!((h.mean() - 30.0).abs() < 1e-12);
        // merge pools samples: quantiles over the union
        let mut o = LatencyHistogram::new();
        o.record(60);
        o.record(70);
        h.merge(&o);
        assert_eq!(h.len(), 7);
        assert_eq!(h.p50(), 40, "upper median shifts with the pooled set");
        assert_eq!(h.p99(), 70);
    }

    #[test]
    fn stats_merge_sums_work_and_maxes_peaks() {
        let a = RolloutStats {
            chunks: 1,
            decode_steps: 10,
            occupied_slot_steps: 30,
            idle_slot_steps: 10,
            refills: 2,
            prefills: 1,
            slot_prefills: 2,
            shared_prefill_attaches: 3,
            prefill_chunks: 4,
            max_reserved_kv: 100,
            max_used_pages: 5,
            peak_live_slots: 4,
            preemptions: 1,
            steals: 1,
            async_prefills_submitted: 3,
            async_prefills_completed: 3,
            async_prefill_inflight_peak: 2,
            retries: 2,
            requeues: 1,
            failed_tasks: 1,
            replica_deaths: 0,
            workers: 1,
            decode_busy_ticks: 100,
            prefill_blocked_ticks: 40,
            sched_stall_ticks: 0,
            modeled_makespan_ticks: 140,
            max_step_ticks: 50,
        };
        let b = RolloutStats {
            chunks: 1,
            decode_steps: 5,
            occupied_slot_steps: 15,
            idle_slot_steps: 5,
            max_reserved_kv: 80,
            max_used_pages: 9,
            peak_live_slots: 2,
            async_prefills_submitted: 1,
            async_prefills_completed: 1,
            async_prefill_inflight_peak: 1,
            retries: 1,
            replica_deaths: 1,
            workers: 1,
            prefill_chunks: 2,
            decode_busy_ticks: 50,
            prefill_blocked_ticks: 40,
            sched_stall_ticks: 7,
            modeled_makespan_ticks: 97,
            max_step_ticks: 37,
            ..RolloutStats::default()
        };
        let mut m = a;
        m.merge(&b);
        // work counters sum (serial composition)...
        assert_eq!(m.decode_steps, 15);
        assert_eq!(m.device_slot_steps(), 60);
        assert_eq!(m.decode_busy_ticks, 150);
        assert_eq!(m.prefill_blocked_ticks, 80);
        assert_eq!(m.sched_stall_ticks, 7);
        assert_eq!(m.modeled_makespan_ticks, 237);
        assert_eq!(m.steals, 1);
        // prefill-executor counters: submitted/completed sum...
        assert_eq!(m.async_prefills_submitted, 4);
        assert_eq!(m.async_prefills_completed, 4);
        assert_eq!(m.shared_prefill_attaches, 3);
        // fault-tolerance counters are work: they sum in both compositions
        assert_eq!(m.retries, 3);
        assert_eq!(m.requeues, 1);
        assert_eq!(m.failed_tasks, 1);
        assert_eq!(m.replica_deaths, 1);
        // chunked-prefill calls are work too
        assert_eq!(m.prefill_chunks, 6);
        // ...high-water marks take the max
        assert_eq!(m.max_step_ticks, 50, "per-step peak is a high-water, not a sum");
        assert_eq!(m.async_prefill_inflight_peak, 2);
        assert_eq!(m.max_reserved_kv, 100);
        assert_eq!(m.max_used_pages, 9);
        assert_eq!(m.peak_live_slots, 4);
        // denominator contract: slot-steps stay per-device-step, so the
        // merged occupancy is the slot-step-weighted mean
        assert!((m.occupancy() - 45.0 / 60.0).abs() < 1e-12);
        assert!((m.idle_frac() - 15.0 / 60.0).abs() < 1e-12);
    }

    #[test]
    fn prop_merge_preserves_denominator_contract_and_sums_exactly() {
        // Merging N per-lane stats — each individually satisfying the
        // audited invariant `occupied + idle == decode_steps * slots` —
        // must preserve it exactly, sum every work counter exactly
        // (preemptions, steals, refills, admission-side prefill counts),
        // and take exact maxima of the high-water marks. This is the
        // documented serial-composition contract the pipelined joiner and
        // the static queue driver both lean on.
        propcheck::quick("stats-merge-invariants", |rng, size| {
            let slots = 1 + rng.below(16);
            let n = 1 + rng.below(2 + size / 4);
            let mut lanes = Vec::with_capacity(n);
            for _ in 0..n {
                let decode_steps = rng.below(200);
                let occupied = if decode_steps == 0 {
                    0
                } else {
                    rng.below(decode_steps * slots + 1)
                };
                lanes.push(RolloutStats {
                    chunks: 1,
                    decode_steps,
                    occupied_slot_steps: occupied,
                    idle_slot_steps: decode_steps * slots - occupied,
                    refills: rng.below(20),
                    prefills: rng.below(4),
                    slot_prefills: rng.below(20),
                    shared_prefill_attaches: rng.below(20),
                    prefill_chunks: rng.below(40),
                    max_reserved_kv: rng.below(4096),
                    max_used_pages: rng.below(256),
                    peak_live_slots: rng.below(slots + 1),
                    preemptions: rng.below(16),
                    steals: rng.below(8),
                    async_prefills_submitted: rng.below(24),
                    async_prefills_completed: rng.below(24),
                    async_prefill_inflight_peak: rng.below(12),
                    retries: rng.below(10),
                    requeues: rng.below(6),
                    failed_tasks: rng.below(6),
                    replica_deaths: rng.below(3),
                    workers: 1,
                    decode_busy_ticks: rng.below(10_000) as u64,
                    prefill_blocked_ticks: rng.below(10_000) as u64,
                    sched_stall_ticks: rng.below(10_000) as u64,
                    modeled_makespan_ticks: rng.below(30_000) as u64,
                    max_step_ticks: rng.below(200) as u64,
                });
            }
            let mut merged = RolloutStats::default();
            for lane in &lanes {
                merged.merge(lane);
            }
            let steps: usize = lanes.iter().map(|l| l.decode_steps).sum();
            if merged.device_slot_steps() != steps * slots {
                return Err(format!(
                    "denominator broken after merge: {} + {} != {} * {slots}",
                    merged.occupied_slot_steps, merged.idle_slot_steps, steps
                ));
            }
            let sum = |f: fn(&RolloutStats) -> usize| lanes.iter().map(f).sum::<usize>();
            if merged.decode_steps != steps
                || merged.preemptions != sum(|l| l.preemptions)
                || merged.steals != sum(|l| l.steals)
                || merged.refills != sum(|l| l.refills)
                || merged.prefills != sum(|l| l.prefills)
                || merged.slot_prefills != sum(|l| l.slot_prefills)
                || merged.shared_prefill_attaches != sum(|l| l.shared_prefill_attaches)
                || merged.prefill_chunks != sum(|l| l.prefill_chunks)
                || merged.async_prefills_submitted != sum(|l| l.async_prefills_submitted)
                || merged.async_prefills_completed != sum(|l| l.async_prefills_completed)
                || merged.retries != sum(|l| l.retries)
                || merged.requeues != sum(|l| l.requeues)
                || merged.failed_tasks != sum(|l| l.failed_tasks)
                || merged.replica_deaths != sum(|l| l.replica_deaths)
                || merged.chunks != n
            {
                return Err("a work counter did not sum exactly".into());
            }
            let ticks = |f: fn(&RolloutStats) -> u64| lanes.iter().map(f).sum::<u64>();
            if merged.decode_busy_ticks != ticks(|l| l.decode_busy_ticks)
                || merged.prefill_blocked_ticks != ticks(|l| l.prefill_blocked_ticks)
                || merged.sched_stall_ticks != ticks(|l| l.sched_stall_ticks)
                || merged.modeled_makespan_ticks != ticks(|l| l.modeled_makespan_ticks)
            {
                return Err("a tick counter did not sum exactly".into());
            }
            let max = |f: fn(&RolloutStats) -> usize| lanes.iter().map(f).max().unwrap_or(0);
            if merged.max_reserved_kv != max(|l| l.max_reserved_kv)
                || merged.max_used_pages != max(|l| l.max_used_pages)
                || merged.peak_live_slots != max(|l| l.peak_live_slots)
                || merged.async_prefill_inflight_peak != max(|l| l.async_prefill_inflight_peak)
                || merged.workers != max(|l| l.workers)
            {
                return Err("a high-water mark is not the exact max".into());
            }
            let step_max = lanes.iter().map(|l| l.max_step_ticks).max().unwrap_or(0);
            if merged.max_step_ticks != step_max {
                return Err("max_step_ticks is not the exact max".into());
            }
            // merge is order-independent for every audited field
            let mut rev = RolloutStats::default();
            for lane in lanes.iter().rev() {
                rev.merge(lane);
            }
            if rev != merged {
                return Err("merge is not order-independent".into());
            }
            Ok(())
        });
    }

    #[test]
    fn stats_merge_parallel_maxes_makespan_and_sums_lanes() {
        let a = RolloutStats {
            chunks: 2,
            decode_steps: 10,
            occupied_slot_steps: 30,
            idle_slot_steps: 10,
            max_reserved_kv: 100,
            peak_live_slots: 4,
            workers: 2,
            decode_busy_ticks: 100,
            prefill_blocked_ticks: 40,
            modeled_makespan_ticks: 140,
            ..RolloutStats::default()
        };
        let b = RolloutStats {
            chunks: 1,
            decode_steps: 5,
            occupied_slot_steps: 15,
            idle_slot_steps: 5,
            max_reserved_kv: 80,
            peak_live_slots: 2,
            workers: 1,
            decode_busy_ticks: 50,
            sched_stall_ticks: 7,
            modeled_makespan_ticks: 97,
            ..RolloutStats::default()
        };
        let mut p = a;
        p.merge_parallel(&b);
        // work and tick totals still sum (device work, wherever it ran)
        assert_eq!(p.decode_steps, 15);
        assert_eq!(p.device_slot_steps(), 60);
        assert_eq!(p.decode_busy_ticks, 150);
        assert_eq!(p.sched_stall_ticks, 7);
        // the parallel-time fields differ from serial merge: the fleet
        // finishes with its slowest replica, and its lanes add up
        assert_eq!(p.modeled_makespan_ticks, 140, "makespan is the replica max");
        assert_eq!(p.workers, 3, "fleet lanes sum across replicas");
        // per-device residency peaks never sum across private walls
        assert_eq!(p.max_reserved_kv, 100);
        assert_eq!(p.peak_live_slots, 4);
    }

    #[test]
    fn prop_merge_parallel_is_order_independent_and_keeps_denominators() {
        // The fleet composition contract (satellite of the replica tier):
        // per-replica stats — each satisfying the audited denominator
        // invariant `occupied + idle == decode_steps * slots` — compose
        // ORDER-INDEPENDENTLY under `merge_parallel`, the invariant holds
        // fleet-wide (equal slot widths), the makespan is the exact
        // replica max, lanes sum, and per-device peaks are exact maxima.
        propcheck::quick("stats-merge-parallel-invariants", |rng, size| {
            let slots = 1 + rng.below(16);
            let n = 1 + rng.below(2 + size / 4);
            let mut reps = Vec::with_capacity(n);
            for _ in 0..n {
                let decode_steps = rng.below(200);
                let occupied = if decode_steps == 0 {
                    0
                } else {
                    rng.below(decode_steps * slots + 1)
                };
                reps.push(RolloutStats {
                    chunks: 1 + rng.below(4),
                    decode_steps,
                    occupied_slot_steps: occupied,
                    idle_slot_steps: decode_steps * slots - occupied,
                    refills: rng.below(20),
                    prefills: rng.below(4),
                    slot_prefills: rng.below(20),
                    shared_prefill_attaches: rng.below(20),
                    prefill_chunks: rng.below(40),
                    max_reserved_kv: rng.below(4096),
                    max_used_pages: rng.below(256),
                    peak_live_slots: rng.below(slots + 1),
                    preemptions: rng.below(16),
                    steals: rng.below(8),
                    async_prefills_submitted: rng.below(24),
                    async_prefills_completed: rng.below(24),
                    async_prefill_inflight_peak: rng.below(12),
                    retries: rng.below(10),
                    requeues: rng.below(6),
                    failed_tasks: rng.below(6),
                    replica_deaths: rng.below(3),
                    workers: 1 + rng.below(4),
                    decode_busy_ticks: rng.below(10_000) as u64,
                    prefill_blocked_ticks: rng.below(10_000) as u64,
                    sched_stall_ticks: rng.below(10_000) as u64,
                    modeled_makespan_ticks: rng.below(30_000) as u64,
                    max_step_ticks: rng.below(200) as u64,
                });
            }
            // every replica individually upholds the denominator contract;
            // the fleet-wide fold must too (equal slots per replica)
            let mut fleet = RolloutStats::default();
            for rep in &reps {
                fleet.merge_parallel(rep);
            }
            let steps: usize = reps.iter().map(|r| r.decode_steps).sum();
            if fleet.device_slot_steps() != steps * slots {
                return Err(format!(
                    "fleet denominator broken: {} + {} != {} * {slots}",
                    fleet.occupied_slot_steps, fleet.idle_slot_steps, steps
                ));
            }
            if fleet.decode_steps != steps {
                return Err("decode steps did not sum".into());
            }
            let sum = |f: fn(&RolloutStats) -> usize| reps.iter().map(f).sum::<usize>();
            if fleet.retries != sum(|r| r.retries)
                || fleet.requeues != sum(|r| r.requeues)
                || fleet.failed_tasks != sum(|r| r.failed_tasks)
                || fleet.replica_deaths != sum(|r| r.replica_deaths)
            {
                return Err("a fault counter did not sum fleet-wide".into());
            }
            let makespan = reps.iter().map(|r| r.modeled_makespan_ticks).max().unwrap_or(0);
            if fleet.modeled_makespan_ticks != makespan {
                return Err(format!(
                    "fleet makespan {} != replica max {makespan}",
                    fleet.modeled_makespan_ticks
                ));
            }
            let lanes: usize = reps.iter().map(|r| r.workers).sum();
            if fleet.workers != lanes {
                return Err(format!("fleet lanes {} != summed {lanes}", fleet.workers));
            }
            let max = |f: fn(&RolloutStats) -> usize| reps.iter().map(f).max().unwrap_or(0);
            if fleet.max_reserved_kv != max(|r| r.max_reserved_kv)
                || fleet.max_used_pages != max(|r| r.max_used_pages)
                || fleet.peak_live_slots != max(|r| r.peak_live_slots)
                || fleet.async_prefill_inflight_peak != max(|r| r.async_prefill_inflight_peak)
            {
                return Err("a per-device peak is not the exact max".into());
            }
            let step_max = reps.iter().map(|r| r.max_step_ticks).max().unwrap_or(0);
            if fleet.max_step_ticks != step_max {
                return Err("fleet max_step_ticks is not the exact max".into());
            }
            // order independence: every field combine is commutative +
            // associative with the default as identity
            let mut rev = RolloutStats::default();
            for rep in reps.iter().rev() {
                rev.merge_parallel(rep);
            }
            if rev != fleet {
                return Err("merge_parallel is not order-independent".into());
            }
            Ok(())
        });
    }
}
