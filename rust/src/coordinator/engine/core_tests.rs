//! Unit tests for the shared decode core (child module of
//! `engine::core`, split out to keep the core source focused; a
//! child module sees the parent's private items as usual).

use super::*;


    fn cfg(t: f32, p: f32) -> SamplingConfig {
        SamplingConfig { temperature: t, top_p: p, max_response: 16 }
    }

    #[test]
    fn sample_token_records_exact_logp_at_unit_temp() {
        let mut rng = Rng::new(1);
        let logp = [-0.5f32, -1.5, -3.0];
        for _ in 0..50 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(1.0, 1.0));
            assert_eq!(lp, logp[tok]);
        }
    }

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(2);
        let logp = [-2.0f32, -0.1, -5.0];
        for _ in 0..20 {
            let (tok, _) = sample_token(&mut rng, &logp, &cfg(0.0, 1.0));
            assert_eq!(tok, 1);
        }
    }

    #[test]
    fn tempered_logp_is_normalized() {
        let mut rng = Rng::new(3);
        let logp = [-0.7f32, -1.1, -2.0, -2.5];
        // collect the modified distribution empirically
        let mut mass = [0.0f64; 4];
        let n = 30_000;
        for _ in 0..n {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(0.7, 0.95));
            mass[tok] += 1.0;
            // recorded logp must be a valid log-probability
            assert!(lp <= 0.0 && lp.is_finite());
        }
        let total: f64 = mass.iter().sum();
        assert_eq!(total as usize, n);
        // last token should be rarer than first under sharpening
        assert!(mass[0] > mass[3]);
    }

    #[test]
    fn nan_logits_do_not_panic_and_carry_no_mass() {
        let mut rng = Rng::new(4);
        let logp = [f32::NAN, -1.0, f32::NAN, -2.0];
        for _ in 0..200 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(0.8, 0.9));
            assert!(tok == 1 || tok == 3, "sampled NaN token {tok}");
            assert!(lp.is_finite() && lp <= 0.0);
        }
        // the T=1/top-p=1 default config must be just as hardened (it
        // normally takes the exact-logp fast path)
        for _ in 0..200 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(1.0, 1.0));
            assert!(tok == 1 || tok == 3, "fast path sampled NaN token {tok}");
            assert!(lp.is_finite() && lp <= 0.0);
        }
        // fully degenerate input: uniform fallback, still no panic
        let bad = [f32::NAN; 5];
        for _ in 0..50 {
            let (tok, lp) = sample_token(&mut rng, &bad, &cfg(0.8, 0.9));
            assert!(tok < 5);
            assert!((lp - (-(5f32).ln())).abs() < 1e-6);
        }
    }

    #[test]
    fn top1_exceeding_top_p_keeps_exactly_argmax() {
        let mut rng = Rng::new(5);
        // token 1 holds ~99% of the tempered mass, far beyond top_p = 0.5:
        // the nucleus must be {1} with renormalized mass 1 (log-prob 0)
        let logp = [-8.0f32, -0.01, -9.0, -10.0];
        for _ in 0..100 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(0.9, 0.5));
            assert_eq!(tok, 1);
            assert_eq!(lp, 0.0, "renormalized point mass must be exactly 1");
        }
    }

    #[test]
    fn task_rng_is_slot_and_order_independent() {
        // same (seed, task) => same stream; different task => different
        let mut a = task_rng(42, 7);
        let mut b = task_rng(42, 7);
        let mut c = task_rng(42, 8);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

