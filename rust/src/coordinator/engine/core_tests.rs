//! Unit tests for the shared decode core (child module of
//! `engine::core`, split out to keep the core source focused; a
//! child module sees the parent's private items as usual).

use super::*;


    fn cfg(t: f32, p: f32) -> SamplingConfig {
        SamplingConfig { temperature: t, top_p: p, max_response: 16 }
    }

    #[test]
    fn sample_token_records_exact_logp_at_unit_temp() {
        let mut rng = Rng::new(1);
        let logp = [-0.5f32, -1.5, -3.0];
        for _ in 0..50 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(1.0, 1.0));
            assert_eq!(lp, logp[tok]);
        }
    }

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(2);
        let logp = [-2.0f32, -0.1, -5.0];
        for _ in 0..20 {
            let (tok, _) = sample_token(&mut rng, &logp, &cfg(0.0, 1.0));
            assert_eq!(tok, 1);
        }
    }

    #[test]
    fn tempered_logp_is_normalized() {
        let mut rng = Rng::new(3);
        let logp = [-0.7f32, -1.1, -2.0, -2.5];
        // collect the modified distribution empirically
        let mut mass = [0.0f64; 4];
        let n = 30_000;
        for _ in 0..n {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(0.7, 0.95));
            mass[tok] += 1.0;
            // recorded logp must be a valid log-probability
            assert!(lp <= 0.0 && lp.is_finite());
        }
        let total: f64 = mass.iter().sum();
        assert_eq!(total as usize, n);
        // last token should be rarer than first under sharpening
        assert!(mass[0] > mass[3]);
    }

    #[test]
    fn nan_logits_do_not_panic_and_carry_no_mass() {
        let mut rng = Rng::new(4);
        let logp = [f32::NAN, -1.0, f32::NAN, -2.0];
        for _ in 0..200 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(0.8, 0.9));
            assert!(tok == 1 || tok == 3, "sampled NaN token {tok}");
            assert!(lp.is_finite() && lp <= 0.0);
        }
        // the T=1/top-p=1 default config must be just as hardened (it
        // normally takes the exact-logp fast path)
        for _ in 0..200 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(1.0, 1.0));
            assert!(tok == 1 || tok == 3, "fast path sampled NaN token {tok}");
            assert!(lp.is_finite() && lp <= 0.0);
        }
        // fully degenerate input: uniform fallback, still no panic
        let bad = [f32::NAN; 5];
        for _ in 0..50 {
            let (tok, lp) = sample_token(&mut rng, &bad, &cfg(0.8, 0.9));
            assert!(tok < 5);
            assert!((lp - (-(5f32).ln())).abs() < 1e-6);
        }
    }

    #[test]
    fn top1_exceeding_top_p_keeps_exactly_argmax() {
        let mut rng = Rng::new(5);
        // token 1 holds ~99% of the tempered mass, far beyond top_p = 0.5:
        // the nucleus must be {1} with renormalized mass 1 (log-prob 0)
        let logp = [-8.0f32, -0.01, -9.0, -10.0];
        for _ in 0..100 {
            let (tok, lp) = sample_token(&mut rng, &logp, &cfg(0.9, 0.5));
            assert_eq!(tok, 1);
            assert_eq!(lp, 0.0, "renormalized point mass must be exactly 1");
        }
    }

    #[test]
    fn packed_chunk_len_never_exceeds_budget_and_floors_at_one() {
        for budget in 0..12usize {
            for occupied in 0..12usize {
                for remaining in 1..20usize {
                    let len = packed_chunk_len(budget, occupied, remaining);
                    assert!(len >= 1, "progress floor violated");
                    assert!(len <= remaining, "chunk past the prompt end");
                    // the budget bound only binds when leftover >= 1; a
                    // saturated batch still advances by exactly one token
                    if budget > occupied {
                        assert!(len <= budget - occupied, "budget exceeded");
                    } else {
                        assert_eq!(len, 1.min(remaining));
                    }
                }
            }
        }
    }

    #[test]
    fn prefill_chunk_step_respects_budget_and_matches_monolithic() {
        use crate::coordinator::mock::MockModelBackend;
        let costs = CostModel::representative();
        let mut b = MockModelBackend::dense(4, 32, 64, 16).with_costs(costs);
        let mut mono = b.clone();
        let geom = Geometry::of(&b);
        let prompt: Vec<i32> = (0..23).map(|i| 3 + (i * 5) % 11).collect();
        let (budget, occupied) = (8usize, 3usize);
        let mut c = ChunkInProgress { pos: 0, slot: 1, offset: 0 };
        let mut stats = RolloutStats::default();
        let mut final_row = None;
        let mut chunks = 0usize;
        while final_row.is_none() {
            let before = c.offset;
            let (row, ticks) =
                prefill_chunk_step(&mut b, &geom, &mut c, &prompt, budget, occupied, 0, &mut stats)
                    .unwrap();
            let len = c.offset - before;
            assert!(len >= 1 && len <= budget - occupied, "packed len {len} out of bounds");
            assert_eq!(ticks, costs.chunk_token_ticks * len as u64);
            chunks += 1;
            final_row = row;
        }
        assert_eq!(c.offset, prompt.len());
        assert_eq!(chunks, prompt.len().div_ceil(budget - occupied));
        assert_eq!(stats.prefill_chunks, chunks);
        assert_eq!(
            stats.prefill_blocked_ticks,
            costs.chunk_token_ticks * prompt.len() as u64
        );
        // completion row is bit-identical to the monolithic slot prefill
        let mono_row = mono.prefill_slot(1, &prompt).unwrap();
        assert_eq!(final_row.unwrap(), mono_row);
        // a budget covering the whole prompt degenerates to one chunk
        let mut c1 = ChunkInProgress { pos: 0, slot: 2, offset: 0 };
        let (row1, _) = prefill_chunk_step(
            &mut b,
            &geom,
            &mut c1,
            &prompt,
            prompt.len() + occupied,
            occupied,
            0,
            &mut stats,
        )
        .unwrap();
        assert_eq!(c1.offset, prompt.len());
        assert_eq!(row1.unwrap(), mono_row);
    }

    #[test]
    fn chunk_resumes_at_recorded_offset_across_unrelated_slot_traffic() {
        use crate::coordinator::mock::MockModelBackend;
        let mut b =
            MockModelBackend::dense(4, 32, 64, 16).with_costs(CostModel::representative());
        let mut mono = b.clone();
        let geom = Geometry::of(&b);
        let prompt: Vec<i32> = (0..17).map(|i| 4 + (i * 7) % 9).collect();
        let other: Vec<i32> = vec![6; 12];
        let mut c = ChunkInProgress { pos: 3, slot: 0, offset: 0 };
        let mut stats = RolloutStats::default();
        let (row, _) =
            prefill_chunk_step(&mut b, &geom, &mut c, &prompt, 6, 0, 0, &mut stats).unwrap();
        assert!(row.is_none());
        assert_eq!(c.offset, 6);
        // steal/preemption traffic elsewhere: a full prefill into another
        // slot and a victim eviction must not disturb the partial prefix
        b.prefill_slot(2, &other).unwrap();
        // resume exactly at the recorded offset until done
        let mut done = None;
        while done.is_none() {
            let (row, _) =
                prefill_chunk_step(&mut b, &geom, &mut c, &prompt, 6, 0, 0, &mut stats).unwrap();
            done = row;
        }
        assert_eq!(done.unwrap(), mono.prefill_slot(0, &prompt).unwrap());
    }

    #[test]
    fn task_rng_is_slot_and_order_independent() {
        // same (seed, task) => same stream; different task => different
        let mut a = task_rng(42, 7);
        let mut b = task_rng(42, 7);
        let mut c = task_rng(42, 8);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

