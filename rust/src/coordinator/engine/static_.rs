//! Static chunked engine shell: a chunk of ≤ R sequences is prefilled
//! together and decodes until the *slowest* sequence finishes. Every slot
//! whose sequence terminates early sits idle (PAD-fed) until the chunk
//! drains — the long-tail bubble the continuous engine removes. All
//! per-token semantics live in the shared decode core.

use anyhow::{bail, Result};

use crate::config::AdmissionOrder;
use crate::data::task::Task;
use crate::data::tokenizer::PAD;

use super::super::backend::RolloutBackend;
use super::core::{admission_costs, DecodeCore, GenSeq, Geometry, PrefillWave, StreamHub};
use super::stats::RolloutStats;
use super::{RolloutCtx, RolloutPolicy};

/// Quarantine every live member of a static chunk after a batch backend
/// call (wave prefill / compress / decode) exhausted its retry budget:
/// all members shared the failed call, so all are recorded failed. No
/// scheduler release happens here — static reservations are chunk-scoped
/// and `finish_chunk` returns them as a unit, so the chunk ledger stays
/// balanced without touching the sequence-level conservation counters.
fn quarantine_chunk(
    core: &mut DecodeCore,
    results: &mut [Option<GenSeq>],
    stats: &mut RolloutStats,
) {
    for slot in 0..core.geom.slots {
        let Some(mut live) = core.slots[slot].take() else { continue };
        core.tokens[slot] = PAD;
        live.gen.failed = true;
        stats.failed_tasks += 1;
        results[live.pos] = Some(live.gen);
    }
}

impl RolloutPolicy {
    /// Static chunked rollout of ≤ R sequences (the scheduler guarantees
    /// admission). `tasks` pairs a caller-side index with the task
    /// occupying that slot. The chunk decodes until its slowest sequence
    /// finishes; early finishers vacate their slot but the chunk's KV
    /// reservations are only released by the caller when the whole chunk
    /// drains.
    pub fn rollout_static<B: RolloutBackend>(
        &self,
        b: &mut B,
        tasks: &[(usize, &Task)],
        seed: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        self.rollout_static_stream(b, tasks, seed, None, 0)
    }

    /// `rollout_static` with the streaming extras: a live token sink and
    /// the virtual-clock time this chunk starts at (the queue driver's
    /// accumulated makespan — chunks run serially on one lane, so chunk
    /// k's tokens are stamped after every earlier chunk's work).
    fn rollout_static_stream<B: RolloutBackend>(
        &self,
        b: &mut B,
        tasks: &[(usize, &Task)],
        seed: u64,
        stream: Option<StreamHub>,
        clock_base: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let geom = Geometry::of(b);
        let n = tasks.len();
        assert!(n <= geom.slots, "chunk of {} > {} slots", n, geom.slots);
        let mut stats = RolloutStats { chunks: 1, workers: 1, ..RolloutStats::default() };
        if n == 0 {
            return Ok((vec![], stats));
        }

        // ---- prefill: the whole chunk in one batched call ---------------
        let mut core = DecodeCore::new(geom, self.mode.is_sparse())
            .with_retries(self.fault_retries)
            .with_stream(stream);
        let mut results: Vec<Option<GenSeq>> = (0..n).map(|_| None).collect();
        let mut wave = PrefillWave::new(&geom);
        for (slot, (idx, task)) in tasks.iter().enumerate() {
            wave.push(&mut core, slot, *idx, &task.prompt_ids, seed);
        }
        let mut logp = match wave.prefill(&core, b, &mut stats) {
            Ok(l) => l,
            Err(e) if self.fault_policy.is_quarantine() => {
                let _ = e;
                quarantine_chunk(&mut core, &mut results, &mut stats);
                Vec::new() // no live slot remains; the decode loop is skipped
            }
            Err(e) => return Err(e),
        };
        // serial lane: the decode batch blocks on its own prefill
        stats.prefill_blocked_ticks += geom.costs.prefill_ticks;

        // ---- decode loop: run until the slowest sequence finishes -------
        while core.occupied() > 0 {
            // stamp streamed tokens with the lane's accumulated work (the
            // serial makespan so far): the logits being sampled were paid
            // for by everything already charged into this chunk's stats
            core.clock = clock_base
                + stats.decode_busy_ticks
                + stats.prefill_blocked_ticks
                + stats.sched_stall_ticks;
            for slot in 0..geom.slots {
                let dist = &logp[slot * geom.vocab..(slot + 1) * geom.vocab];
                if let Some(done) = core.sample(self, slot, dist) {
                    // no per-sequence release: the chunk's reservation
                    // drains as a unit (finish_chunk) — THE static-engine
                    // bubble. The freed slot just idles.
                    results[done.pos] = Some(done.gen);
                }
            }
            if core.occupied() == 0 {
                break; // chunk drained; trailing logits are never needed
            }
            // chunk reservations are worst-case/predicted bounds, so
            // compression never needs a scheduler shrink here
            if let Err(e) = core.compress_step(b, &mut stats) {
                if !self.fault_policy.is_quarantine() {
                    return Err(e);
                }
                quarantine_chunk(&mut core, &mut results, &mut stats);
                break;
            }
            logp = match core.decode_step(b, &mut stats) {
                Ok(l) => l,
                Err(e) if self.fault_policy.is_quarantine() => {
                    let _ = e;
                    quarantine_chunk(&mut core, &mut results, &mut stats);
                    break;
                }
                Err(e) => return Err(e),
            };
        }
        // serial engine: the lane's makespan is simply everything it did
        stats.modeled_makespan_ticks =
            stats.decode_busy_ticks + stats.prefill_blocked_ticks + stats.sched_stall_ticks;
        let out = results
            .into_iter()
            .map(|s| s.expect("every chunk member completed"))
            .collect();
        Ok((out, stats))
    }

    /// Drive the static chunked engine over a whole pending queue: admit
    /// a chunk against the wall, roll it out to completion, release, and
    /// repeat. THE single driver for queue-scale static rollouts — the
    /// trainer, the equivalence harness, and the benches all call this,
    /// so they exercise identical admission/ordering semantics. Under
    /// `admission-order = shortest-first` the pending queue is stably
    /// sorted by predicted residency before chunking, so chunks fill with
    /// the cheapest tasks first (the same order the dynamic engines pop
    /// in); results still come back in task order.
    pub fn rollout_static_queue<B: RolloutBackend>(
        &self,
        b: &mut B,
        tasks: &[(usize, &Task)],
        seed: u64,
        ctx: RolloutCtx,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let RolloutCtx { sched, kv, seq_id_base, stream } = ctx;
        let n = tasks.len();
        let mut pending: Vec<usize> = (0..n).collect();
        let mut results: Vec<Option<GenSeq>> = (0..n).map(|_| None).collect();
        let mut stats = RolloutStats::default();
        let mut base = seq_id_base;
        // Two views of the same oracle: the clamped predicted residency
        // sizes paged chunk reservations; the unclamped admission cost
        // orders shortest-first (cap ties break toward cheaper prompts,
        // exactly like the dynamic engines' queue picks). Worst-case
        // fifo ignores both.
        let residency: Vec<usize> = tasks
            .iter()
            .map(|(_, t)| sched.predicted_residency(t.prompt_ids.len(), self.sampling.max_response))
            .collect();
        if sched.order == AdmissionOrder::ShortestFirst {
            let cost = admission_costs(sched, tasks, self.sampling.max_response);
            // stable: equal-cost tasks keep their queue order
            pending.sort_by_key(|&i| cost[i]);
        }
        while !pending.is_empty() {
            let Some(chunk) = sched.next_chunk(&mut pending, kv, base, &residency) else {
                bail!(
                    "static rollout stalled: {} pending but nothing admissible \
                     (static batching drains synchronously)",
                    pending.len()
                );
            };
            stats.max_reserved_kv = stats.max_reserved_kv.max(kv.reserved());
            stats.max_used_pages = stats.max_used_pages.max(kv.used_pages());
            let chunk_tasks: Vec<(usize, &Task)> =
                chunk.items.iter().map(|&i| tasks[i]).collect();
            // chunk k starts at the serial merge's accumulated makespan
            // (chunks run back to back on this one lane)
            let (seqs, cstats) = self.rollout_static_stream(
                b,
                &chunk_tasks,
                seed,
                stream.clone(),
                stats.modeled_makespan_ticks,
            )?;
            stats.merge(&cstats);
            // rollout_static returns sequences in slot (= chunk) order
            for (&pos, seq) in chunk.items.iter().zip(seqs) {
                results[pos] = Some(seq);
            }
            sched.finish_chunk(&chunk, kv, base);
            base += chunk.items.len() as u64;
        }
        let out = results
            .into_iter()
            .map(|s| s.expect("every queued task completed"))
            .collect();
        Ok((out, stats))
    }
}
