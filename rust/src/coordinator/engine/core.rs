//! The single decode-step state machine all rollout engines share.
//!
//! Everything that decides *what a sequence's next token is* lives here,
//! exactly once: per-task RNG streams, temperature/top-p sampling with
//! sampler log-prob recording (this *is* log π_sparse — Eq. 2), EOS and
//! length-cap handling, KV accounting, the compression trigger, paged
//! growth with lowest-progress preemption, and the decode invocation with
//! its slot-step denominator accounting. The engine shells (`static_`,
//! `continuous`, `pipelined`) only decide *scheduling*: which tasks are
//! admitted when, where freed capacity goes, and which thread drives which
//! lane. That split is what makes the token-identity contract a property
//! of ONE code path: an engine cannot drift on per-token semantics because
//! it does not implement any.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::compression::KvAccounting;
use crate::config::SamplingConfig;
use crate::data::task::Task;
use crate::data::tokenizer::{BOS, EOS, PAD};
use crate::util::rng::Rng;

use super::super::backend::{CostModel, RolloutBackend};
use super::super::kv_manager::KvMemoryManager;
use super::super::scheduler::{AdmissionQueue, Scheduler};
use super::stats::RolloutStats;
use super::RolloutPolicy;

/// One finished rollout.
#[derive(Debug, Clone)]
pub struct GenSeq {
    /// Caller-side identifier (index into the step's task list).
    pub task_idx: usize,
    pub prompt_ids: Vec<i32>,
    /// Generated tokens (includes the terminating EOS when finished).
    pub response_ids: Vec<i32>,
    /// log π_sparse(o_t | ·) of every generated token (the actual sampling
    /// distribution, i.e. after temperature/top-p modification).
    pub sampler_logp: Vec<f32>,
    /// True iff the model emitted EOS before the length cap.
    pub finished: bool,
    pub accounting: KvAccounting,
    /// True iff the task was quarantined after a backend call exhausted
    /// its retry budget (`fault-policy = quarantine`). The response holds
    /// whatever was generated before the fault — diagnostic only, never
    /// trainable: the trainer drops the whole GRPO group of any failed
    /// member. Always false on the fault-free path.
    pub failed: bool,
}

impl GenSeq {
    fn new(task_idx: usize, prompt_ids: Vec<i32>) -> GenSeq {
        GenSeq {
            task_idx,
            prompt_ids,
            response_ids: vec![],
            sampler_logp: vec![],
            finished: false,
            accounting: KvAccounting::new(),
            failed: false,
        }
    }

    /// A quarantined task that never produced a token (the fault hit its
    /// prefill): an empty, unfinished, `failed` rollout. Quarantines of
    /// already-decoding tasks instead mark the live `GenSeq` so the
    /// partial response survives for diagnostics.
    pub(crate) fn failed_seq(task_idx: usize, prompt_ids: Vec<i32>) -> GenSeq {
        let mut g = GenSeq::new(task_idx, prompt_ids);
        g.failed = true;
        g
    }

    /// Full sequence ids: prompt + response.
    pub fn full_ids(&self) -> Vec<i32> {
        let mut v = self.prompt_ids.clone();
        v.extend_from_slice(&self.response_ids);
        v
    }
}

/// One generated token, streamed live out of the decode core. `index` is
/// the token's 0-based position within the response (`index == 0` is the
/// first response token — its `tick` minus the request's arrival is the
/// TTFT); `tick` is the engine's virtual-clock time when the token was
/// produced. Tokens are the per-task-RNG tokens — identical to what the
/// closed-batch result returns — so streaming adds observability, never a
/// second token path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TokenEvent {
    /// Caller-side task identifier (same as `GenSeq::task_idx`).
    pub task_idx: usize,
    /// 0-based position within the response.
    pub index: usize,
    pub token: i32,
    /// Virtual-clock tick the token was produced at.
    pub tick: u64,
}

/// Per-sequence streaming sinks: one mpsc sender per subscribed task,
/// keyed by the caller-side task index. Cloning shares the sink table
/// (`Arc`), so one hub can be handed to every engine lane and replica
/// thread of a rollout. Emission is best-effort: unsubscribed tasks and
/// dropped receivers cost one map lookup and nothing else, so engines
/// never block (or fail) on a slow or departed consumer.
///
/// Preemption semantics: a preempted-and-rerun task re-emits its tokens
/// from index 0 — bit-identical by per-task RNG — so consumers keep the
/// FIRST event per index and treat repeats as replay, not new tokens.
#[derive(Debug, Clone, Default)]
pub struct StreamHub {
    sinks: Arc<Mutex<BTreeMap<usize, Sender<TokenEvent>>>>,
}

impl StreamHub {
    pub fn new() -> StreamHub {
        StreamHub::default()
    }

    /// Open a stream for `task_idx`; events for that task flow into the
    /// returned receiver until [`StreamHub::unsubscribe`] (or the hub
    /// itself) drops the sender.
    pub fn subscribe(&self, task_idx: usize) -> Receiver<TokenEvent> {
        let (tx, rx) = channel();
        self.sinks.lock().unwrap().insert(task_idx, tx);
        rx
    }

    /// Drop `task_idx`'s sink (its receiver sees the channel close).
    pub fn unsubscribe(&self, task_idx: usize) {
        self.sinks.lock().unwrap().remove(&task_idx);
    }

    pub(crate) fn emit(&self, task_idx: usize, index: usize, token: i32, tick: u64) {
        if let Some(tx) = self.sinks.lock().unwrap().get(&task_idx) {
            // a dropped receiver is a departed consumer, not an error
            let _ = tx.send(TokenEvent { task_idx, index, token, tick });
        }
    }
}

/// Best-effort human-readable panic payload: `panic!("...")` carries a
/// `String` (or `&'static str` for literal-only messages); anything else
/// is opaque. Used wherever a joined thread's panic is folded into an
/// error so injected-fault messages survive into the surfaced `Err`.
pub(crate) fn panic_msg(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "opaque panic payload"
    }
}

/// Which virtual-clock bucket a retried backend call's backoff is charged
/// to (the lane doing the retrying is busy for that time either way).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum TickBucket {
    Decode,
    Prefill,
}

/// Bounded retry around one backend call. Attempt k's failure charges a
/// linear backoff of `op_ticks * k` into `bucket` (the failed call plus
/// an increasing settle wait) and counts one `stats.retries`; after
/// `retries` failed re-attempts the last error surfaces to the caller,
/// which applies the fault policy (abort or quarantine). With
/// `retries = 0` this is exactly the bare call — the fault-free path adds
/// zero work and zero ticks, keeping default runs bit-exact with the
/// seed. Backend calls are fault-checked BEFORE any state mutation, so a
/// failed attempt has no side effects and the re-attempt is bit-identical
/// to a first try.
pub(crate) fn with_retries<T>(
    retries: usize,
    op_ticks: u64,
    bucket: TickBucket,
    stats: &mut RolloutStats,
    mut call: impl FnMut() -> Result<T>,
) -> Result<T> {
    let mut attempt = 0usize;
    loop {
        match call() {
            Ok(v) => return Ok(v),
            Err(e) if attempt < retries => {
                attempt += 1;
                stats.retries += 1;
                let backoff = op_ticks.saturating_mul(attempt as u64);
                match bucket {
                    TickBucket::Decode => stats.decode_busy_ticks += backoff,
                    TickBucket::Prefill => stats.prefill_blocked_ticks += backoff,
                }
                let _ = e;
            }
            Err(e) => return Err(e),
        }
    }
}

/// Per-task RNG stream: a pure function of (rollout seed, task index).
/// A given task therefore samples the identical token sequence no matter
/// which slot, chunk, worker, or engine runs it — or how often it is
/// preempted and rerun.
pub fn task_rng(seed: u64, task_idx: usize) -> Rng {
    Rng::new(seed ^ (task_idx as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Sample from log-probs with temperature/top-p; returns the token and the
/// log-prob of the token under the *modified* (actually sampled)
/// distribution. With temperature=1, top_p=1 this is exactly `logp[tok]`.
///
/// Robustness: non-finite logits (NaN from a diverged model, ±inf) carry
/// zero mass instead of poisoning the sort/normalization; if *every* logit
/// is non-finite the sampler falls back to a uniform draw. The top-p
/// nucleus always keeps at least one token — when the top-1 probability
/// alone exceeds `top_p`, the cut is exactly {argmax} and its renormalized
/// mass is 1 (recorded log-prob 0).
pub fn sample_token(rng: &mut Rng, logp: &[f32], s: &SamplingConfig) -> (usize, f32) {
    if s.temperature < 1e-3 {
        // greedy decoding: a point mass (NaN never wins a `>` comparison)
        let (mut best, mut bv) = (0usize, f32::NEG_INFINITY);
        for (i, &l) in logp.iter().enumerate() {
            if l > bv {
                best = i;
                bv = l;
            }
        }
        return (best, 0.0);
    }
    if (s.temperature - 1.0).abs() < 1e-6
        && s.top_p >= 1.0
        && logp.iter().all(|l| l.is_finite())
    {
        // unmodified distribution: record the artifact's own log-prob
        // bit-exactly (the finite guard keeps NaN inputs on the hardened
        // path below instead of this shortcut)
        let tok = rng.sample_logits(logp, 1.0, 1.0);
        return (tok, logp[tok]);
    }
    // general case: the shared temperature/top-p machinery (single
    // implementation for both samplers — util::rng::modified_probs)
    let Some(probs) = crate::util::rng::modified_probs(logp, s.temperature, s.top_p) else {
        // fully degenerate input: uniform fallback
        let tok = rng.below(logp.len());
        return (tok, -(logp.len() as f32).ln());
    };
    let r = rng.next_f32();
    let mut acc = 0.0;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if r < acc && p > 0.0 {
            return (i, p.ln());
        }
    }
    let last = probs.iter().rposition(|&p| p > 0.0).unwrap_or(0);
    (last, probs[last].ln())
}

impl RolloutPolicy {
    /// Sample one token into `gen` — recording the sampler log-prob and KV
    /// accounting — and report `(token, done)` where `done` means the
    /// sequence just terminated (EOS or a length cap). THE single
    /// implementation of per-token semantics: every engine's decode loop
    /// and refill path reaches it through `DecodeCore`, so EOS/cap/
    /// accounting rules cannot drift between engines (which would silently
    /// break the token-equivalence contract).
    ///
    /// `len` is the occupied cache length and `abs` the absolute position
    /// *before* this token's cache write.
    fn sample_step(
        &self,
        rng: &mut Rng,
        dist: &[f32],
        gen: &mut GenSeq,
        len: i32,
        abs: i32,
        capacity: usize,
        max_seq: usize,
    ) -> (i32, bool) {
        let (tok, lp) = sample_token(rng, dist, &self.sampling);
        gen.response_ids.push(tok as i32);
        gen.sampler_logp.push(lp);
        gen.accounting
            .step(((len + 1) as usize).min(capacity), abs as usize + 1);
        let mut done = false;
        if tok as i32 == EOS {
            gen.finished = true;
            done = true;
        }
        if gen.response_ids.len() >= self.sampling.max_response
            || (abs as usize + 1) >= max_seq
        {
            done = true;
        }
        (tok as i32, done)
    }
}

/// Geometry + latency snapshot of one backend, read once per rollout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Geometry {
    pub slots: usize,
    pub prompt_len: usize,
    pub max_seq: usize,
    pub vocab: usize,
    pub capacity: usize,
    pub budget: usize,
    pub costs: CostModel,
}

impl Geometry {
    pub fn of<B: RolloutBackend>(b: &B) -> Geometry {
        Geometry {
            slots: b.slots(),
            prompt_len: b.prompt_len(),
            max_seq: b.max_seq(),
            vocab: b.vocab(),
            capacity: b.capacity(),
            budget: b.budget(),
            costs: b.cost_model(),
        }
    }

    /// The model shape alone (pipelined workers must agree on it — they
    /// share one task queue and one wall; per-lane costs may differ).
    pub fn shape(&self) -> (usize, usize, usize, usize, usize, usize) {
        (self.slots, self.prompt_len, self.max_seq, self.vocab, self.capacity, self.budget)
    }
}

/// A sequence live in a decode slot.
pub(crate) struct LiveSeq {
    /// Position in the pending task list (== results index).
    pub pos: usize,
    pub rng: Rng,
    pub gen: GenSeq,
}

/// Per-task admission costs — the shortest-first ordering vector,
/// indexed by task position (the scheduler's single ordering oracle;
/// unclamped, so cap-tied tasks still order by prompt size).
pub(crate) fn admission_costs(
    sched: &Scheduler,
    tasks: &[(usize, &Task)],
    max_response: usize,
) -> Vec<usize> {
    tasks
        .iter()
        .map(|(_, t)| sched.admission_cost(t.prompt_ids.len(), max_response))
        .collect()
}

/// Order-aware single admission from a pending queue: peek the
/// [`AdmissionQueue`]'s next pick (fifo head, or stable first-min by
/// `admission_cost` through the sorted index), charge the wall, and
/// dequeue it. `None` means the queue is empty or the wall refused the
/// candidate (callers that care which must check the queue first). Under
/// shortest-first a refusal means nothing with a smaller prompt+response
/// prediction is pending (the unclamped cost key breaks residency-cap
/// ties toward cheaper prompts, i.e. smaller paged admission charges).
pub(crate) fn admit_next(
    sched: &mut Scheduler,
    kv: &mut KvMemoryManager,
    queue: &mut AdmissionQueue,
    tasks: &[(usize, &Task)],
    seq_id_base: u64,
) -> Option<usize> {
    let pos = queue.peek()?;
    // Prompt-aware admission: under `prefix-sharing = group` + paged
    // admission, identical prompts (a GRPO group) share their
    // page-aligned prompt prefix through the refcounted pool; in every
    // other configuration this is exactly the plain length-based admit.
    if !sched.try_admit_prompt(kv, seq_id_base + pos as u64, &tasks[pos].1.prompt_ids) {
        return None;
    }
    queue.pop();
    Some(pos)
}

/// Slot-refill prefill dispatch with prefix sharing's
/// prefill-once-attach-G optimization.
///
/// Disabled (`prefix-sharing = off`, and the async executor path, which
/// always full-prepares): every refill is a plain `prefill_slot`. Enabled
/// (sync engine paths under `prefix-sharing = group`): the FIRST refill
/// of a prompt prepares it once (`prepare_prefill`) and caches the
/// prepared payload; each later refill of the same prompt — a group
/// sibling — just clones and attaches it (`apply_prefill`), skipping the
/// model run entirely. Token-identical by the backend contract
/// (`apply_prefill(slot, prepare_prefill(p)) == prefill_slot(slot, p)`
/// bit-for-bit, slot-position-invariant); only the virtual-clock charge
/// differs, which is the hit flag the caller books (`slot_prefill_ticks`
/// on a miss, `attach_ticks` on a hit). Cached payloads live for one
/// rollout and are bounded by the number of distinct prompts.
pub(crate) struct PrefillCache<B: RolloutBackend> {
    enabled: bool,
    retries: usize,
    prepared: BTreeMap<Vec<i32>, B::Prepared>,
}

impl<B: RolloutBackend> PrefillCache<B> {
    pub fn new(enabled: bool) -> PrefillCache<B> {
        PrefillCache { enabled, retries: 0, prepared: BTreeMap::new() }
    }

    /// Bounded-retry budget for every refill backend call (see
    /// [`with_retries`]); 0 (the default) is the bare-call fault path.
    pub fn with_retries(mut self, retries: usize) -> Self {
        self.retries = retries;
        self
    }

    /// Prefill `slot` with `prompt`, through the share cache when
    /// enabled. Returns the slot's logits row and whether the refill was
    /// served by an attach (true) or a full prefill (false); counts it
    /// into `slot_prefills` or `shared_prefill_attaches` accordingly.
    pub fn slot_prefill(
        &mut self,
        b: &mut B,
        slot: usize,
        prompt: &[i32],
        stats: &mut RolloutStats,
    ) -> Result<(Vec<f32>, bool)> {
        let (retries, ticks) = (self.retries, b.cost_model().slot_prefill_ticks);
        if !self.enabled {
            let row = with_retries(retries, ticks, TickBucket::Prefill, stats, || {
                b.prefill_slot(slot, prompt)
            })?;
            stats.slot_prefills += 1;
            return Ok((row, false));
        }
        if let Some(p) = self.prepared.get(prompt) {
            let row = with_retries(retries, ticks, TickBucket::Prefill, stats, || {
                b.apply_prefill(slot, p.clone())
            })?;
            stats.shared_prefill_attaches += 1;
            return Ok((row, true));
        }
        let prep = with_retries(retries, ticks, TickBucket::Prefill, stats, || {
            b.prepare_prefill(prompt)
        })?;
        self.prepared.insert(prompt.to_vec(), prep.clone());
        let row = with_retries(retries, ticks, TickBucket::Prefill, stats, || {
            b.apply_prefill(slot, prep.clone())
        })?;
        stats.slot_prefills += 1;
        Ok((row, false))
    }
}

/// Record the wall's current residency high-water into a stats block.
pub(crate) fn snap_residency(kv: &KvMemoryManager, stats: &mut RolloutStats) {
    stats.max_reserved_kv = stats.max_reserved_kv.max(kv.reserved());
    stats.max_used_pages = stats.max_used_pages.max(kv.used_pages());
}

/// The decode-batch state machine: R slots of live sequences plus the
/// control vectors (`lens`, `abs_pos`, `tokens`) every backend call reads.
/// Engines own scheduling; this struct owns every per-token and per-step
/// semantic shared between them.
pub(crate) struct DecodeCore {
    pub geom: Geometry,
    sparse: bool,
    /// Bounded-retry budget for decode/compress/wave-prefill backend calls
    /// (see [`with_retries`]); 0 keeps the bare-call fault path.
    pub retries: usize,
    pub slots: Vec<Option<LiveSeq>>,
    /// Occupied cache length per slot (the next write position).
    pub lens: Vec<i32>,
    /// Absolute sequence position per slot.
    pub abs_pos: Vec<i32>,
    /// Token fed to the next decode step per slot (PAD when idle).
    pub tokens: Vec<i32>,
    do_mask: Vec<f32>,
    /// The engine's virtual-clock time, refreshed by the owning shell at
    /// every sampling point; stamps streamed [`TokenEvent`]s. Pure
    /// observability — no engine decision reads it.
    pub clock: u64,
    /// Live token sink, when a serving front-end subscribed one. `None`
    /// (every closed-batch path) makes streaming a strict no-op.
    pub stream: Option<StreamHub>,
}

impl DecodeCore {
    pub fn new(geom: Geometry, sparse: bool) -> DecodeCore {
        let r = geom.slots;
        DecodeCore {
            geom,
            sparse,
            retries: 0,
            slots: (0..r).map(|_| None).collect(),
            lens: vec![1i32; r],
            abs_pos: vec![1i32; r],
            tokens: vec![PAD; r],
            do_mask: vec![0.0f32; r],
            clock: 0,
            stream: None,
        }
    }

    pub fn with_retries(mut self, retries: usize) -> Self {
        self.retries = retries;
        self
    }

    /// Attach (or detach) the live token sink; `None` keeps streaming a
    /// strict no-op.
    pub fn with_stream(mut self, stream: Option<StreamHub>) -> Self {
        self.stream = stream;
        self
    }

    pub fn occupied(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// First free slot, if any.
    pub fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Install an admitted task into `slot`. The slot's cache must be (or
    /// be about to be) filled with exactly this prompt — by the batched
    /// prefill (`PrefillWave`) or a slot prefill (`join`).
    pub fn install(&mut self, slot: usize, pos: usize, task_idx: usize, prompt: &[i32], seed: u64) {
        assert!(
            prompt.len() <= self.geom.prompt_len,
            "prompt {} > {}",
            prompt.len(),
            self.geom.prompt_len
        );
        self.lens[slot] = prompt.len() as i32;
        self.abs_pos[slot] = prompt.len() as i32;
        self.slots[slot] = Some(LiveSeq {
            pos,
            rng: task_rng(seed, task_idx),
            gen: GenSeq::new(task_idx, prompt.to_vec()),
        });
    }

    /// Sample one token for `slot` from its fresh logits row `dist`.
    /// Returns the finished sequence when this token terminated it (EOS or
    /// a length cap): the slot is vacated and its token PADed — what the
    /// engine does with the vacancy (release + refill, or leave the chunk
    /// draining) is scheduling, not semantics. Empty slots are a no-op.
    pub fn sample(&mut self, policy: &RolloutPolicy, slot: usize, dist: &[f32]) -> Option<LiveSeq> {
        let Some(live) = self.slots[slot].as_mut() else {
            self.tokens[slot] = PAD;
            return None;
        };
        let (tok, done) = policy.sample_step(
            &mut live.rng,
            dist,
            &mut live.gen,
            self.lens[slot],
            self.abs_pos[slot],
            self.geom.capacity,
            self.geom.max_seq,
        );
        self.tokens[slot] = tok;
        if let Some(hub) = &self.stream {
            hub.emit(live.gen.task_idx, live.gen.response_ids.len() - 1, tok, self.clock);
        }
        if done {
            let live = self.slots[slot].take().expect("occupied");
            self.tokens[slot] = PAD;
            return Some(live);
        }
        None
    }

    /// Join a recycled slot: install the task and sample its first token
    /// from the slot-prefill logits `row` — the same logits (and the same
    /// per-token semantics, via `sample_step`) the batched-prefill path
    /// would have used. Returns the finished sequence for degenerate
    /// single-token rollouts (the slot is immediately free again).
    #[allow(clippy::too_many_arguments)]
    pub fn join(
        &mut self,
        policy: &RolloutPolicy,
        slot: usize,
        pos: usize,
        task_idx: usize,
        prompt: &[i32],
        row: &[f32],
        seed: u64,
    ) -> Option<LiveSeq> {
        self.install(slot, pos, task_idx, prompt, seed);
        // the slot's cache was just replaced, so the control vectors track
        // it even when the sequence dies immediately — a stale `lens`
        // would put the next decode write at an out-of-sync position
        self.sample(policy, slot, row)
    }

    /// Masked compression trigger: every occupied slot whose next write
    /// would overflow `capacity` is compacted back to `budget` in one
    /// backend call, with per-sequence accounting. Returns the task
    /// positions compressed so the engine can shrink their reservations
    /// (paged admission; chunk-level reservations ignore it). Empty when
    /// nothing triggered (dense runs never trigger).
    pub fn compress_step<B: RolloutBackend>(
        &mut self,
        b: &mut B,
        stats: &mut RolloutStats,
    ) -> Result<Vec<usize>> {
        if !self.sparse {
            return Ok(vec![]);
        }
        let (capacity, budget) = (self.geom.capacity, self.geom.budget);
        let mut any = false;
        for slot in 0..self.geom.slots {
            let need = self.slots[slot].is_some() && self.lens[slot] as usize >= capacity;
            self.do_mask[slot] = if need { 1.0 } else { 0.0 };
            if need {
                any = true;
            }
        }
        if !any {
            return Ok(vec![]);
        }
        // `do_mask` is recomputed from `lens` on entry, so a retried (or
        // quarantine-released) compress re-derives identical inputs.
        let (do_mask, retries, ticks) =
            (&self.do_mask, self.retries, self.geom.costs.compress_ticks);
        with_retries(retries, ticks, TickBucket::Decode, stats, || b.compress(do_mask))?;
        stats.decode_busy_ticks += self.geom.costs.compress_ticks;
        let mut compressed = Vec::new();
        for slot in 0..self.geom.slots {
            if self.do_mask[slot] > 0.0 {
                let live = self.slots[slot].as_mut().expect("masked slot occupied");
                live.gen.accounting.compression(capacity - budget);
                self.lens[slot] = budget as i32;
                compressed.push(live.pos);
            }
        }
        Ok(compressed)
    }

    /// Settle the reservations of just-compressed sequences with the wall.
    /// Unshared (or worst-case) this is a plain shrink and can never fail.
    /// A sequence still ATTACHED to a shared prompt prefix instead forks
    /// copy-on-write — compression is about to rewrite pages its group
    /// siblings still read — which must ALLOCATE private pages and so can
    /// stall at the wall exactly like a grow. A stalled fork preempts the
    /// lowest-progress live sequence of this batch (possibly the forker
    /// itself) and retries; per-task RNG makes every rerun
    /// token-identical. Returns the evicted `(slot, sequence)` pairs for
    /// the engine to requeue, exactly like [`DecodeCore::grow_step`].
    pub fn compress_finish(
        &mut self,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
        compressed: &[usize],
        stats: &mut RolloutStats,
    ) -> Result<Vec<(usize, LiveSeq)>> {
        let r = self.geom.slots;
        let mut evicted = Vec::new();
        'next: for &pos in compressed {
            loop {
                // an earlier stalled fork in this same pass may have
                // preempted this sequence as its victim — nothing to settle
                if !self.slots.iter().flatten().any(|l| l.pos == pos) {
                    continue 'next;
                }
                if sched.compressed(kv, seq_id_base + pos as u64, self.geom.budget)? {
                    snap_residency(kv, stats);
                    continue 'next;
                }
                let victim = (0..r)
                    .filter_map(|s| {
                        self.slots[s]
                            .as_ref()
                            .map(|l| (l.gen.response_ids.len(), l.pos, s))
                    })
                    .min()
                    .expect("the forker itself is live")
                    .2;
                let v = self.slots[victim].take().expect("victim occupied");
                sched.preempt(kv, seq_id_base + v.pos as u64)?;
                self.tokens[victim] = PAD;
                stats.preemptions += 1;
                let own = v.pos == pos;
                evicted.push((victim, v));
                if own {
                    continue 'next; // forker evicted: requeued, nothing to settle
                }
            }
        }
        debug_assert!(kv.check_invariants().is_ok(), "wall invariants broken mid-rollout");
        snap_residency(kv, stats);
        Ok(evicted)
    }

    /// Paged-growth pass: every occupied slot must hold pages for its next
    /// cache write. A grow refused by the wall preempts the
    /// lowest-progress live sequence of THIS batch (possibly the grower
    /// itself) — per-task RNG makes the rerun token-identical, so
    /// preemption costs decode steps but never changes outputs. Returns
    /// the evicted `(slot, sequence)` pairs for the engine to requeue.
    /// (Worst-case admission: grow is a no-op and this returns empty.)
    pub fn grow_step(
        &mut self,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
        stats: &mut RolloutStats,
    ) -> Result<Vec<(usize, LiveSeq)>> {
        let r = self.geom.slots;
        let mut evicted = Vec::new();
        for slot in 0..r {
            loop {
                let Some(live) = self.slots[slot].as_ref() else { break };
                let pos = live.pos;
                let need = self.lens[slot] as usize + 1;
                if sched.grow(kv, seq_id_base + pos as u64, need)? {
                    // snapshot after EVERY successful grow, not once per
                    // pass: a later stall in this same pass may preempt a
                    // victim and release pages, and an end-of-pass-only
                    // snapshot would under-record the true intra-pass peak
                    snap_residency(kv, stats);
                    break;
                }
                let victim = (0..r)
                    .filter_map(|s| {
                        self.slots[s]
                            .as_ref()
                            .map(|l| (l.gen.response_ids.len(), l.pos, s))
                    })
                    .min()
                    .expect("the grower itself is live")
                    .2;
                let v = self.slots[victim].take().expect("victim occupied");
                sched.preempt(kv, seq_id_base + v.pos as u64)?;
                self.tokens[victim] = PAD;
                stats.preemptions += 1;
                let own = victim == slot;
                evicted.push((victim, v));
                if own {
                    break; // grower evicted: its slot is free now
                }
            }
        }
        debug_assert!(kv.check_invariants().is_ok(), "wall invariants broken mid-rollout");
        snap_residency(kv, stats);
        Ok(evicted)
    }

    /// One decode invocation over the mixed batch, plus the slot-step
    /// denominator accounting (`occupied + idle == decode_steps * slots`)
    /// and the control-vector advance. Callers guarantee at least one
    /// occupied slot. Returns the fresh logits `[R * V]`.
    pub fn decode_step<B: RolloutBackend>(
        &mut self,
        b: &mut B,
        stats: &mut RolloutStats,
    ) -> Result<Vec<f32>> {
        let r = self.geom.slots;
        let occupied = self.occupied();
        debug_assert!(occupied > 0, "decode_step over an empty batch");
        stats.peak_live_slots = stats.peak_live_slots.max(occupied);
        // control vectors only advance AFTER a successful call, so a
        // retried decode re-runs with bit-identical inputs
        let (lens, abs_pos, tokens) = (&self.lens, &self.abs_pos, &self.tokens);
        let (retries, ticks) = (self.retries, self.geom.costs.decode_ticks);
        let logp = with_retries(retries, ticks, TickBucket::Decode, stats, || {
            b.decode(lens, abs_pos, tokens)
        })?;
        stats.decode_steps += 1;
        stats.decode_busy_ticks += self.geom.costs.decode_ticks;
        stats.occupied_slot_steps += occupied;
        stats.idle_slot_steps += r - occupied;
        for slot in 0..r {
            if self.slots[slot].is_some() {
                self.lens[slot] += 1;
                self.abs_pos[slot] += 1;
            }
        }
        Ok(logp)
    }

    /// Quarantine every live sequence of this core after a BATCH backend
    /// call (decode / compress / wave prefill) exhausted its retry budget:
    /// the whole batch shared the failed call, so no member's next token
    /// is trustworthy. Each sequence's KV reservation is released through
    /// the scheduler's quarantine ledger (conservation:
    /// `admissions == finishes + preemptions + quarantined` still holds),
    /// its slot vacated and PADed, and its partial `GenSeq` returned
    /// marked `failed` for the engine to record in place of a result.
    pub fn quarantine_live(
        &mut self,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
        stats: &mut RolloutStats,
    ) -> Result<Vec<LiveSeq>> {
        let mut out = Vec::new();
        for slot in 0..self.geom.slots {
            let Some(mut live) = self.slots[slot].take() else { continue };
            sched.quarantine_seq(kv, seq_id_base + live.pos as u64)?;
            self.tokens[slot] = PAD;
            live.gen.failed = true;
            stats.failed_tasks += 1;
            out.push(live);
        }
        snap_residency(kv, stats);
        Ok(out)
    }
}

/// Builder for the initial batched prefill: stages admitted prompts into
/// consecutive slots (installing each in the core), BOS-fills the rest,
/// and fires the one `prefill` call every engine opens with.
pub(crate) struct PrefillWave {
    ids: Vec<i32>,
    plens: Vec<i32>,
    w: usize,
}

impl PrefillWave {
    pub fn new(geom: &Geometry) -> PrefillWave {
        PrefillWave {
            ids: vec![PAD; geom.slots * geom.prompt_len],
            plens: vec![1i32; geom.slots],
            w: 0,
        }
    }

    /// Slots staged so far (== the slot the next push lands in).
    pub fn count(&self) -> usize {
        self.w
    }

    /// Stage one admitted task into the next slot and install it.
    pub fn push(&mut self, core: &mut DecodeCore, pos: usize, task_idx: usize, prompt: &[i32], seed: u64) {
        let p_len = core.geom.prompt_len;
        core.install(self.w, pos, task_idx, prompt, seed);
        self.ids[self.w * p_len..self.w * p_len + prompt.len()].copy_from_slice(prompt);
        self.plens[self.w] = prompt.len() as i32;
        self.w += 1;
    }

    /// Fire the batched prefill over the staged head (BOS rows keep the
    /// unstaged slots well-formed). Returns last-prompt-token logits
    /// `[R * V]`; tick accounting stays with the engine (serial lanes
    /// block on it, the pipelined lane schedules it).
    pub fn prefill<B: RolloutBackend>(
        mut self,
        core: &DecodeCore,
        b: &mut B,
        stats: &mut RolloutStats,
    ) -> Result<Vec<f32>> {
        let p_len = core.geom.prompt_len;
        for slot in self.w..core.geom.slots {
            self.ids[slot * p_len] = BOS;
        }
        let (ids, plens) = (&self.ids, &self.plens);
        let (retries, ticks) = (core.retries, core.geom.costs.prefill_ticks);
        let logp = with_retries(retries, ticks, TickBucket::Prefill, stats, || {
            b.prefill(ids, plens)
        })?;
        stats.prefills += 1;
        Ok(logp)
    }
}

/// Batched prefill of ONE prompt at a specific slot (BOS rows keep every
/// other slot well-formed), returning just that slot's logits row. The
/// pipelined engine's first-wave-refused join fallback uses this: a lane
/// whose entire initial wave was refused has no live cache, so the real
/// backend's `prefill_slot` would reject — batch-row independence makes
/// the slot's logits identical under the batched entry. Lives here so
/// the BOS idle-row convention exists in exactly one module.
pub(crate) fn prefill_single_row<B: RolloutBackend>(
    geom: &Geometry,
    b: &mut B,
    slot: usize,
    prompt: &[i32],
    retries: usize,
    stats: &mut RolloutStats,
) -> Result<Vec<f32>> {
    let p_len = geom.prompt_len;
    let mut ids = vec![PAD; geom.slots * p_len];
    let mut plens = vec![1i32; geom.slots];
    ids[slot * p_len..slot * p_len + prompt.len()].copy_from_slice(prompt);
    plens[slot] = prompt.len() as i32;
    for (s, chunk) in ids.chunks_mut(p_len).enumerate() {
        if s != slot {
            chunk[0] = BOS;
        }
    }
    let (ids_r, plens_r) = (&ids, &plens);
    let all = with_retries(retries, geom.costs.prefill_ticks, TickBucket::Prefill, stats, || {
        b.prefill(ids_r, plens_r)
    })?;
    stats.prefills += 1;
    Ok(all[slot * geom.vocab..(slot + 1) * geom.vocab].to_vec())
}

/// Bookkeeping for a prompt mid-way through chunked prefill: which task it
/// is, which slot owns its partially written cache, and how many prompt
/// tokens earlier chunks already wrote. The next chunk MUST resume at
/// `offset` on the same backend (the partial KV lives in that backend's
/// slot), so engines keep this lane-local: pending refills that have not
/// started chunking remain stealable, but a chunk in progress is pinned to
/// the lane that started it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct ChunkInProgress {
    /// Position in the pending task list (== results index).
    pub pos: usize,
    /// Slot whose KV planes hold the partial prefix.
    pub slot: usize,
    /// Prompt tokens already written; the next chunk starts here.
    pub offset: usize,
}

/// Tokens the next chunk may write under a per-step token budget shared
/// with the decode batch: the budget's leftover after `occupied` decode
/// lanes, floored at 1 so a fully occupied batch still makes progress
/// (without the floor, `occupied >= budget` would starve the chunk
/// forever and deadlock engines that wait for it), capped at what remains
/// of the prompt.
pub(crate) fn packed_chunk_len(budget: usize, occupied: usize, remaining: usize) -> usize {
    budget.saturating_sub(occupied).max(1).min(remaining)
}

/// Advance one chunk of `prompt` into its owning slot: size the chunk by
/// [`packed_chunk_len`], fire the backend's `prefill_chunk` under the
/// bounded-retry wrapper, charge `chunk_token_ticks` per token into the
/// prefill bucket, and bump the offset. Returns the slot's logits row
/// exactly when this chunk completed the prompt (bit-identical to a
/// monolithic `prefill_slot` by the backend contract) plus the ticks
/// charged, so the caller can fold them into its step clock.
pub(crate) fn prefill_chunk_step<B: RolloutBackend>(
    b: &mut B,
    geom: &Geometry,
    c: &mut ChunkInProgress,
    prompt: &[i32],
    budget: usize,
    occupied: usize,
    retries: usize,
    stats: &mut RolloutStats,
) -> Result<(Option<Vec<f32>>, u64)> {
    let len = packed_chunk_len(budget, occupied, prompt.len() - c.offset);
    let ticks = geom.costs.chunk_token_ticks * len as u64;
    let (slot, offset) = (c.slot, c.offset);
    let row = with_retries(retries, ticks, TickBucket::Prefill, stats, || {
        b.prefill_chunk(slot, prompt, offset, len)
    })?;
    stats.prefill_chunks += 1;
    stats.prefill_blocked_ticks += ticks;
    c.offset += len;
    debug_assert_eq!(row.is_some(), c.offset == prompt.len());
    Ok((row, ticks))
}

#[cfg(test)]
#[path = "core_tests.rs"]
mod tests;
