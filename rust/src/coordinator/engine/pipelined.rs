//! Pipelined multi-worker engine shell: a pool of worker threads drives
//! one in-flight decode batch each against a SHARED scheduler/KV wall,
//! with slot prefills issued to a dedicated prefill lane so recycling
//! overlaps decode instead of stalling it. On top of the shared decode
//! core it adds two scheduling features the monolith blocked:
//!
//! * **Cross-worker work stealing** (`steal = on`, default): a drained
//!   lane adopts queued tasks from the shared queue *and*, when the queue
//!   cannot feed it, steals a not-yet-prefilled refill from the
//!   most-loaded peer instead of parking on the condvar — the Sparrow
//!   late-binding move. Stolen refills are safe by construction: their KV
//!   admission is already charged globally, the actual `prefill_slot`
//!   device call only happens at join time on whichever lane owns the
//!   refill then, and per-task RNG keeps the tokens identical wherever
//!   the task lands. A peer is only robbed while it has ≥ 2 pending
//!   refills (or ≥ 1 while it still decodes a live batch), so a lone
//!   about-to-join refill can never ping-pong between two drained lanes.
//! * **Makespan-aware admission order**: the shared queue pops through
//!   `Scheduler::pick_next` (fifo, or shortest-predicted-residency-first)
//!   — see `scheduler.rs`.
//!
//! The modeled hardware (virtual clock, `CostModel` ticks) is
//! disaggregated serving: one decode lane per worker plus a single shared
//! prefill lane. The continuous engine on the same cost model is the
//! serial baseline — one lane that pays every slot prefill inline.
//! `bench_rollout` holds the pipelined makespan strictly below it.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::data::task::Task;

use super::super::backend::RolloutBackend;
use super::super::kv_manager::KvMemoryManager;
use super::super::scheduler::Scheduler;
use super::core::{
    self, admission_costs, admit_next, prefill_single_row, DecodeCore, GenSeq, Geometry,
    PrefillWave,
};
use super::stats::RolloutStats;
use super::RolloutPolicy;

/// A slot refill admitted to the wall and issued to the dedicated prefill
/// lane, but not yet joined into a worker's decode batch. Its KV
/// reservation is already held; the owning lane joins it (or a drained
/// peer steals it) once that lane's virtual clock reaches `ready_at`.
struct PendingRefill {
    /// Position in the pending task list (== results index).
    pos: usize,
    /// Virtual time at which the prefill lane finishes this prefill.
    ready_at: u64,
}

/// State the pipelined worker threads coordinate on, behind one mutex:
/// the shared task queue, the shared scheduler + KV wall, the result
/// table, the per-lane pending-refill registries (the steal surface), and
/// the virtual clocks that tie the lanes' timelines together.
struct PipeShared<'s> {
    queue: VecDeque<usize>,
    /// Admission cost per task position (the shortest-first oracle).
    cost: Vec<usize>,
    sched: &'s mut Scheduler,
    kv: &'s mut KvMemoryManager,
    results: Vec<Option<GenSeq>>,
    /// Admitted-but-not-yet-joined refills, one registry per lane, each
    /// ascending in `ready_at` (the shared lane clock is monotone). A
    /// drained lane pops its own front to join; `steal` lets it pop a
    /// loaded peer's back instead of parking.
    refills: Vec<VecDeque<PendingRefill>>,
    /// Live decode-batch occupancy per lane (steal victim selection: a
    /// lane that still decodes will not join its refills for a while).
    lane_live: Vec<usize>,
    /// Virtual clock of the single shared prefill lane.
    lane_clock: u64,
    /// Latest virtual time any lane released KV — the earliest honest
    /// timestamp for an admission that had to wait on the wall.
    release_floor: u64,
    /// Sequences currently admitted across all lanes (live + pending).
    live_now: usize,
    /// Peak of `live_now`: the globally admitted width.
    peak_live: usize,
    /// First worker error, if any — parked peers bail instead of waiting
    /// for releases that will never come.
    failed: Option<String>,
}

impl PipeShared<'_> {
    /// Admit the scheduler's next queue pick: wall charge + global width
    /// accounting, in one place so the admission sites (initial wave,
    /// slot refills, parked retry) cannot drift. `None` means the queue
    /// is empty or the wall refused.
    fn admit_next(&mut self, tasks: &[(usize, &Task)], seq_id_base: u64) -> Option<usize> {
        let pos = admit_next(
            self.sched,
            self.kv,
            &mut self.queue,
            &self.cost,
            tasks,
            seq_id_base,
        )?;
        self.live_now += 1;
        self.peak_live = self.peak_live.max(self.live_now);
        Some(pos)
    }

    /// Issue one prefill on the shared lane, starting no earlier than the
    /// caller's local time `now`; returns its completion time.
    fn lane_issue(&mut self, now: u64, ticks: u64) -> u64 {
        self.lane_clock = self.lane_clock.max(now) + ticks;
        self.lane_clock
    }

    /// Account a release/preemption happening at the caller's local time
    /// `now` — the floor a peer's stalled admission jumps its clock to.
    fn release_at(&mut self, now: u64) {
        self.live_now -= 1;
        self.release_floor = self.release_floor.max(now);
    }

    /// Record the wall's current residency into a lane's stats (exact
    /// global peaks: every admission/grow site snapshots under the mutex).
    fn snap_residency(&self, stats: &mut RolloutStats) {
        core::snap_residency(self.kv, stats);
    }

    /// Steal one pending refill for drained lane `me`: rob the back of
    /// the most-loaded peer registry (latest `ready_at` — the entry its
    /// owner would reach last). A peer qualifies with ≥ 2 pending
    /// refills, or ≥ 1 while its decode batch is still live — so a lone
    /// refill on an otherwise-drained peer stays put (it is that lane's
    /// only way forward, and robbing it back and forth could livelock
    /// two idle lanes).
    fn steal_for(&mut self, me: usize) -> Option<PendingRefill> {
        let victim = (0..self.refills.len())
            .filter(|&w| {
                w != me
                    && (self.refills[w].len() >= 2
                        || (self.refills[w].len() == 1 && self.lane_live[w] > 0))
            })
            .max_by_key(|&w| self.refills[w].len())?;
        self.refills[victim].pop_back()
    }
}

impl RolloutPolicy {
    /// Pipelined rollout: `backends.len()` worker threads, each driving a
    /// continuous-style decode batch over its own backend against the
    /// shared scheduler/KV wall; slot prefills are deferred to the shared
    /// prefill lane; drained lanes adopt queued work and (with `steal`)
    /// rob loaded peers instead of parking.
    ///
    /// Token identity with `continuous` holds by construction: per-task
    /// RNG plus batch-row independence make a task's tokens a pure
    /// function of (seed, task) regardless of worker, slot, join step,
    /// steal, admission order, or preemption —
    /// `tests/engine_equivalence.rs` enforces it for worker counts 1/2/4
    /// across the {steal} × {admission-order} grid. Results come back in
    /// task order. Work counters in the merged stats sum over lanes;
    /// `modeled_makespan_ticks` is the lane max and `peak_live_slots` the
    /// peak globally admitted width.
    pub fn rollout_pipelined<B: RolloutBackend + Send>(
        &self,
        backends: &mut [B],
        tasks: &[(usize, &Task)],
        seed: u64,
        sched: &mut Scheduler,
        kv: &mut KvMemoryManager,
        seq_id_base: u64,
    ) -> Result<(Vec<GenSeq>, RolloutStats)> {
        let workers = backends.len();
        if workers == 0 {
            bail!("pipelined rollout needs at least one worker backend");
        }
        let n = tasks.len();
        if n == 0 {
            return Ok((vec![], RolloutStats { workers, ..RolloutStats::default() }));
        }
        // every worker must see the same model geometry — they share one
        // task queue and one wall
        let shape = Geometry::of(&backends[0]).shape();
        for b in backends.iter() {
            let g = Geometry::of(b).shape();
            if g != shape {
                bail!("pipelined worker backends disagree on geometry: {g:?} vs {shape:?}");
            }
        }
        // same progress guarantee as the continuous engine: a lone
        // sequence must be able to grow to its worst-case residency
        if kv.pages_for(sched.reserve_per_seq) > kv.total_pages() {
            bail!(
                "pipelined rollout deadlock: one sequence may need {} KV tokens \
                 but the wall holds only {}",
                sched.reserve_per_seq,
                kv.capacity()
            );
        }

        let cost = admission_costs(sched, tasks, self.sampling.max_response);
        let shared = Mutex::new(PipeShared {
            queue: (0..n).collect(),
            cost,
            sched,
            kv,
            results: (0..n).map(|_| None).collect(),
            refills: (0..workers).map(|_| VecDeque::new()).collect(),
            lane_live: vec![0; workers],
            lane_clock: 0,
            release_floor: 0,
            live_now: 0,
            peak_live: 0,
            failed: None,
        });
        let cv = Condvar::new();
        let (shared, cv) = (&shared, &cv);
        let policy = *self;

        let joined = std::thread::scope(|scope| {
            let handles: Vec<_> = backends
                .iter_mut()
                .enumerate()
                .map(|(me, b)| {
                    scope.spawn(move || {
                        let out = policy
                            .pipelined_worker(b, tasks, seed, seq_id_base, me, shared, cv);
                        if let Err(e) = &out {
                            // poison the run so parked peers bail out
                            // instead of waiting on releases that will
                            // never come
                            if let Ok(mut sh) = shared.lock() {
                                if sh.failed.is_none() {
                                    sh.failed = Some(e.to_string());
                                }
                            }
                            cv.notify_all();
                        }
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join())
                .collect::<Vec<_>>()
        });

        let mut stats = RolloutStats::default();
        let mut makespan = 0u64;
        for res in joined {
            let (ws, finish) =
                res.unwrap_or_else(|_| Err(anyhow::anyhow!("pipelined worker panicked")))?;
            stats.merge(&ws);
            makespan = makespan.max(finish);
        }
        stats.workers = workers;
        stats.modeled_makespan_ticks = makespan;
        let mut sh = shared
            .lock()
            .map_err(|_| anyhow::anyhow!("pipelined shared state poisoned"))?;
        stats.peak_live_slots = stats.peak_live_slots.max(sh.peak_live);
        let mut out = Vec::with_capacity(n);
        for (pos, seq) in sh.results.iter_mut().enumerate() {
            match seq.take() {
                Some(s) => out.push(s),
                None => bail!("pipelined rollout dropped task at position {pos}"),
            }
        }
        Ok((out, stats))
    }

    /// One pipelined worker lane: a continuous-style decode loop over its
    /// own backend, coordinating admission/release/growth/stealing
    /// through the shared state and deferring slot prefills to the shared
    /// prefill lane. Returns its stats and its final virtual clock.
    #[allow(clippy::too_many_arguments)]
    fn pipelined_worker<B: RolloutBackend>(
        &self,
        b: &mut B,
        tasks: &[(usize, &Task)],
        seed: u64,
        seq_id_base: u64,
        me: usize,
        shared: &Mutex<PipeShared<'_>>,
        cv: &Condvar,
    ) -> Result<(RolloutStats, u64)> {
        let geom = Geometry::of(b);
        let r = geom.slots;
        let lock = || {
            shared
                .lock()
                .map_err(|_| anyhow::anyhow!("pipelined shared state poisoned"))
        };

        let mut stats = RolloutStats { chunks: 1, workers: 1, ..RolloutStats::default() };
        // this lane's virtual clock (ticks on the backend's cost model)
        let mut now = 0u64;
        let mut core = DecodeCore::new(geom, self.mode.is_sparse());
        // slots whose row in `logp` is fresh (sampled at the loop top);
        // freshly joined slots carry an already-sampled token instead
        let mut decoded = vec![false; r];
        let mut logp: Vec<f32> = Vec::new();

        // ---- initial wave: admit a batch head, one batched prefill ------
        let mut wave = PrefillWave::new(&geom);
        {
            let mut guard = lock()?;
            while wave.count() < r {
                let Some(pos) = guard.admit_next(tasks, seq_id_base) else { break };
                let (idx, task) = tasks[pos];
                wave.push(&mut core, pos, idx, &task.prompt_ids, seed);
            }
            guard.lane_live[me] = wave.count();
            guard.snap_residency(&mut stats);
        }
        let w0 = wave.count();
        if w0 > 0 {
            // the batched prefill shares the single modeled prefill lane
            // with every other worker's; the decode lane blocks on it
            // (nothing to decode before the first logits anyway)
            let ready = lock()?.lane_issue(now, geom.costs.prefill_ticks);
            logp = wave.prefill(&core, b, &mut stats)?;
            stats.prefill_blocked_ticks += ready - now;
            now = ready;
            for d in decoded.iter_mut().take(w0) {
                *d = true;
            }
        }

        loop {
            // ---- sample from fresh logits; release finishers ------------
            let mut released = false;
            for slot in 0..r {
                if !decoded[slot] {
                    continue;
                }
                decoded[slot] = false;
                let dist = &logp[slot * geom.vocab..(slot + 1) * geom.vocab];
                if let Some(done) = core.sample(self, slot, dist) {
                    let mut guard = lock()?;
                    let sh = &mut *guard;
                    sh.sched.release_seq(sh.kv, seq_id_base + done.pos as u64)?;
                    sh.release_at(now);
                    sh.lane_live[me] = core.occupied();
                    sh.results[done.pos] = Some(done.gen);
                    released = true;
                }
            }
            if released {
                cv.notify_all();
            }

            // ---- join refills whose lane prefill has completed ----------
            let mut joins: Vec<PendingRefill> = Vec::new();
            {
                let mut guard = lock()?;
                while guard.refills[me].front().is_some_and(|p| p.ready_at <= now) {
                    joins.push(guard.refills[me].pop_front().expect("checked front"));
                }
            }
            let mut joined_any = false;
            for p in joins {
                let slot = core
                    .free_slot()
                    .expect("a free slot exists per pending refill (registry invariant)");
                let (idx, task) = tasks[p.pos];
                let pi = &task.prompt_ids;
                let row = if stats.prefills == 0 {
                    // this lane's whole first wave was refused at the wall,
                    // so it has no live cache yet and the real backend's
                    // prefill_slot would reject: run the batched entry with
                    // just this prompt instead — batch-row independence
                    // makes the slot's logits identical either way
                    prefill_single_row(&geom, b, slot, pi, &mut stats)?
                } else {
                    stats.slot_prefills += 1;
                    b.prefill_slot(slot, pi)?
                };
                stats.refills += 1;
                // identical per-token semantics to the continuous refill
                // path: first token from the slot-prefill logits
                if let Some(done) = core.join(self, slot, p.pos, idx, pi, &row, seed) {
                    // degenerate single-token sequence: release; the slot
                    // frees for the next admission pass below
                    let mut guard = lock()?;
                    let sh = &mut *guard;
                    sh.sched.release_seq(sh.kv, seq_id_base + done.pos as u64)?;
                    sh.release_at(now);
                    sh.results[done.pos] = Some(done.gen);
                    drop(guard);
                    cv.notify_all();
                    continue;
                }
                decoded[slot] = false;
                joined_any = true;
            }
            if joined_any {
                lock()?.lane_live[me] = core.occupied();
            }

            // ---- issue refills: admit + queue on the prefill lane -------
            {
                let mut guard = lock()?;
                while core.occupied() + guard.refills[me].len() < r {
                    let Some(pos) = guard.admit_next(tasks, seq_id_base) else {
                        break; // queue empty, or wall: retry after releases
                    };
                    let ready_at = guard.lane_issue(now, geom.costs.slot_prefill_ticks);
                    guard.refills[me].push_back(PendingRefill { pos, ready_at });
                    guard.snap_residency(&mut stats);
                }
            }

            // ---- empty lane: wait, steal, or drain ----------------------
            if core.occupied() == 0 {
                let mut guard = lock()?;
                if let Some(t) = guard.refills[me].front().map(|p| p.ready_at) {
                    // nothing decodable while the lane prefills: the
                    // decode lane waits for the earliest join
                    drop(guard);
                    stats.prefill_blocked_ticks += t.saturating_sub(now);
                    now = now.max(t);
                    continue;
                }
                // The queue has work this lane cannot admit (a peer holds
                // the wall), or is empty while peers still hold pending
                // refills. Adopt queue work when it fits, steal a pending
                // refill from the most-loaded peer, or park until a
                // release (releases notify; the timeout re-checks
                // `failed` and the deadlock predicate, never aborting a
                // merely-slow run).
                let stall_start = now;
                let got_work = loop {
                    if let Some(e) = &guard.failed {
                        bail!("pipelined peer failed: {e}");
                    }
                    if let Some(pos) = guard.admit_next(tasks, seq_id_base) {
                        // honest virtual time: this admission only became
                        // possible when a peer released KV
                        now = now.max(guard.release_floor);
                        let ready_at = guard.lane_issue(now, geom.costs.slot_prefill_ticks);
                        guard.refills[me].push_back(PendingRefill { pos, ready_at });
                        guard.snap_residency(&mut stats);
                        break true;
                    }
                    if self.steal {
                        if let Some(p) = guard.steal_for(me) {
                            // adopt the refill: its admission charge and
                            // its prefill-lane slot travel with it, so the
                            // thief just inherits the wait for `ready_at`
                            guard.refills[me].push_back(p);
                            stats.steals += 1;
                            break true;
                        }
                    }
                    if guard.queue.is_empty() {
                        break false; // drained: worker done
                    }
                    // state-based deadlock check (NOT wall-clock based — a
                    // slow real backend may take arbitrarily long between
                    // releases): with no sequence admitted anywhere, no
                    // future release can ever free room, so a refusal now
                    // is a refusal forever.
                    if guard.live_now == 0 {
                        bail!(
                            "pipelined rollout stalled: {} pending but nothing \
                             admissible on an idle wall (reserve {} > free KV {})",
                            guard.queue.len(),
                            guard.sched.reserve_per_seq,
                            guard.kv.available()
                        );
                    }
                    let (g, _) = cv
                        .wait_timeout(guard, Duration::from_millis(2))
                        .map_err(|_| anyhow::anyhow!("pipelined shared state poisoned"))?;
                    guard = g;
                };
                drop(guard);
                if !got_work {
                    break; // queue drained: worker done (peers drain their own)
                }
                stats.sched_stall_ticks += now - stall_start;
                continue; // the pending refill joins via the lane
            }

            // ---- compression trigger (the shared per-sequence rule) -----
            {
                let compressed = core.compress_step(b, &mut stats)?;
                if !compressed.is_empty() {
                    now += geom.costs.compress_ticks;
                    let mut guard = lock()?;
                    let sh = &mut *guard;
                    for pos in compressed {
                        sh.sched.compressed(sh.kv, seq_id_base + pos as u64, geom.budget)?;
                    }
                }
            }

            // ---- paged growth; stalls preempt from the OWN batch --------
            // (cross-worker caches are untouchable; freed pages help every
            // lane, so preemptions notify the pool)
            {
                let mut guard = lock()?;
                let sh = &mut *guard;
                let evicted = core.grow_step(sh.sched, sh.kv, seq_id_base, &mut stats)?;
                let preempted = !evicted.is_empty();
                for (slot, v) in evicted {
                    sh.release_at(now);
                    sh.queue.push_front(v.pos);
                    decoded[slot] = false;
                }
                sh.lane_live[me] = core.occupied();
                drop(guard);
                if preempted {
                    cv.notify_all();
                }
            }

            // ---- one decode step over the mixed batch -------------------
            if core.occupied() == 0 {
                continue; // growth evicted the whole batch: re-admit/wait
            }
            logp = core.decode_step(b, &mut stats)?;
            now += geom.costs.decode_ticks;
            for slot in 0..r {
                decoded[slot] = core.slots[slot].is_some();
            }
        }

        Ok((stats, now))
    }
}
